//! END-TO-END DRIVER (DESIGN.md §5, recorded in EXPERIMENTS.md):
//! loads the build-time-trained tiny DiT artifact through the PJRT
//! runtime, starts the sampling server, replays a Poisson request trace
//! through real TCP clients, and reports latency/throughput/batching
//! metrics plus sample quality vs. the DiT's training distribution.
//!
//! Prereq: `make artifacts` (trains the DiT and lowers the HLO).
//!
//! ```bash
//! cargo run --release --example serve_e2e
//! ```

use sadiff::config::{SamplerConfig, ServerConfig};
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::SampleRequest;
use sadiff::exps::table3;
use sadiff::util::timing::Stopwatch;
use sadiff::workloads;

fn main() {
    // Fail early with a clear message if artifacts are missing.
    let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let (reference, dim) = match table3::load_reference(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve_e2e needs the DiT artifact: {e}");
            std::process::exit(1);
        }
    };

    // 1. Start the server on an ephemeral port with dynamic batching.
    // §Perf iteration 5: the DiT artifact solve takes ~100 ms per group,
    // so a 4 ms batching window leaves occupancy near 1 under Poisson
    // arrivals; a 25 ms window trades a little head-of-line latency for a
    // ~2× higher occupancy (amortizing the fixed-B artifact call).
    let server_cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        batch_deadline_ms: 25,
        workers: 2,
        queue_cap: 512,
        threads: 0, // lane-parallel executor: auto-size to the cores
        max_inflight: 4,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(server_cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();
    println!("server on {addr}; DiT artifact dim={dim}");

    // 2. Replay a Poisson trace from a handful of concurrent clients.
    let trace = workloads::poisson_trace(40.0, 4.0, &[4, 8], &[12, 12, 24], 99);
    let n_requests = trace.len();
    println!("replaying {n_requests} requests over 4s (Poisson, mixed n/nfe)...");

    let sw = Stopwatch::start();
    let mut handles = Vec::new();
    let n_clients = 4;
    let chunks: Vec<Vec<workloads::TraceRequest>> = (0..n_clients)
        .map(|c| trace.iter().skip(c).step_by(n_clients).cloned().collect())
        .collect();
    for (cid, chunk) in chunks.into_iter().enumerate() {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut latencies = Vec::new();
            let mut samples_done = 0usize;
            let t0 = Stopwatch::start();
            for tr in chunk {
                // Honor arrival times (coarsely).
                let now = t0.secs();
                if tr.arrival_s > now {
                    std::thread::sleep(std::time::Duration::from_secs_f64(tr.arrival_s - now));
                }
                let req = SampleRequest {
                    id: tr.seed,
                    workload: "latent_analog".into(), // schedule source; model overrides
                    model: "artifact:dit_denoiser".into(),
                    cfg: SamplerConfig {
                        nfe: tr.nfe,
                        tau: 1.0,
                        ..SamplerConfig::sa_default()
                    },
                    n: tr.n,
                    seed: tr.seed,
                    return_samples: samples_done < 512,
                    want_metrics: false,
                    preset: None,
                    deadline_ms: None,
                    priority: 0,
                };
                let sw_req = Stopwatch::start();
                let resp = client.request(&req).expect("request");
                latencies.push(sw_req.millis());
                assert!(resp.ok, "client {cid}: {:?}", resp.error);
                samples_done += resp.n;
            }
            latencies
        }));
    }
    let mut latencies: Vec<f64> = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let wall = sw.secs();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // 3. Serving report.
    let total_samples: usize = trace.iter().map(|t| t.n).sum();
    println!("\n== serving report ==");
    println!("requests          : {n_requests}");
    println!("wall time         : {wall:.2}s");
    println!("throughput        : {:.1} req/s, {:.1} samples/s",
        n_requests as f64 / wall, total_samples as f64 / wall);
    println!("latency p50 / p95 : {:.1} ms / {:.1} ms",
        sadiff::util::percentile_sorted(&latencies, 0.5),
        sadiff::util::percentile_sorted(&latencies, 0.95));
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    println!("server stats      : {}", sadiff::jsonlite::to_string(&stats));

    // 4. Quality: one direct batch of DiT samples vs the training data.
    let req = SampleRequest {
        id: 0,
        workload: "latent_analog".into(),
        model: "artifact:dit_denoiser".into(),
        cfg: SamplerConfig { nfe: 24, tau: 1.0, ..SamplerConfig::sa_default() },
        n: 256,
        seed: 7,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    };
    let resp = client.request(&req).unwrap();
    let samples = resp.samples.expect("samples");
    let n_ref = reference.len() / dim;
    let take = 256usize.min(n_ref) * dim;
    let fid = sadiff::metrics::sim_fid(&samples[..take], &reference[..take], dim).unwrap();
    let sw2 = sadiff::metrics::sliced_w2(&samples[..take], &reference[..take], dim, 32, 0);
    println!("\n== quality vs DiT training distribution ==");
    println!("sim-FID = {fid:.3}   sliced-W2 = {sw2:.3}   (n=256, NFE=24, tau=1)");

    handle.shutdown();
    println!("\nserve_e2e OK");
}
