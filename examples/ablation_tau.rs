//! τ-ablation example (Figure 1 in miniature): how the stochasticity scale
//! trades off against the NFE budget on one workload.
//!
//! ```bash
//! cargo run --release --example ablation_tau            # full grid
//! cargo run --release --example ablation_tau -- --quick # small grid
//! ```

use sadiff::exps::{fig1, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = Scale::from_quick_flag(quick);
    let table = fig1::run_one("cifar_analog", scale);
    table.print();
    println!(
        "\nReading guide: each column is an NFE budget; rows are τ. The per-column\n\
         minimum moves to larger τ as NFE grows — the paper's core Figure-1 shape."
    );
}
