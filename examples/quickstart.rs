//! Quickstart: sample a workload with SA-Solver and score the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sadiff::config::SamplerConfig;
use sadiff::coordinator::engine::evaluate;
use sadiff::workloads;

fn main() {
    // 1. Pick a workload analog (schedule + target distribution).
    let wl = workloads::latent_analog();
    let model = wl.model();

    // 2. Configure SA-Solver: NFE budget 20, τ = 1 (full SDE), 3-step
    //    predictor + 3-step corrector (the paper's §E defaults).
    let cfg = SamplerConfig { nfe: 20, tau: 1.0, ..SamplerConfig::sa_default() };

    // 3. Sample and compare against the exact reference distribution.
    println!("sampling {} with SA-Solver (nfe={}, tau={})...", wl.name, cfg.nfe, cfg.tau);
    let row = evaluate(&*model, &wl, &cfg, 1024, 0);
    println!(
        "  sim-FID = {:.4}   sliced-W2 = {:.4}   NFE used = {}   wall = {:.2}s",
        row.sim_fid, row.sliced_w2, row.nfe, row.wall_s
    );

    // 4. The same budget with the deterministic ODE limit (τ = 0) — at
    //    moderate NFE the SDE setting should win (paper Fig. 1).
    let ode = SamplerConfig { tau: 0.0, ..cfg.clone() };
    let row0 = evaluate(&*model, &wl, &ode, 1024, 0);
    println!(
        "ODE limit (tau=0): sim-FID = {:.4}   sliced-W2 = {:.4}",
        row0.sim_fid, row0.sliced_w2
    );

    // 5. NFE sweep: quality improves with budget.
    println!("\nNFE sweep (tau=1):");
    for nfe in [5usize, 10, 20, 40] {
        let c = SamplerConfig { nfe, ..cfg.clone() };
        let r = evaluate(&*model, &wl, &c, 1024, 0);
        println!("  NFE {nfe:>3}: sim-FID {:.4}", r.sim_fid);
    }
}
