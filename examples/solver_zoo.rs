//! Solver-zoo example (Figure 2 in miniature): every implemented solver
//! head-to-head at equal NFE budgets on one workload.
//!
//! ```bash
//! cargo run --release --example solver_zoo            # full sweep
//! cargo run --release --example solver_zoo -- --quick # small sweep
//! ```

use sadiff::exps::{fig2, Scale};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = Scale::from_quick_flag(quick);
    let table = fig2::run_one("imagenet64_analog", scale);
    table.print();
    println!(
        "\nReading guide: SA-Solver should match the best ODE solvers at the\n\
         smallest budgets and strictly win from moderate NFE on; EDM(SDE)\n\
         needs far more steps (paper Fig. 2)."
    );
}
