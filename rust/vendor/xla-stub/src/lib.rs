//! API stub for the `xla` PJRT bindings used by `sadiff`'s `pjrt` feature.
//!
//! The offline build environment does not carry the real XLA/PJRT shared
//! libraries, but `sadiff --features pjrt` must still *compile* so CI can
//! gate the feature-enabled code path. This crate mirrors exactly the API
//! surface `sadiff::runtime::artifact` consumes; every operation that would
//! need a real PJRT client returns [`Error::Unavailable`] at runtime.
//!
//! Deployments that do have the real bindings swap this crate out by
//! re-pointing the `xla` *path dependency* in the root `Cargo.toml`
//! (`[patch]` does not apply to path dependencies):
//!
//! ```toml
//! [dependencies]
//! xla = { path = "third_party/xla-rs", optional = true }   # real bindings
//! ```

use std::fmt;

/// Stub error: any PJRT operation is unavailable without the real bindings.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(op) => write!(
                f,
                "xla stub: '{op}' requires the real XLA/PJRT bindings \
                 (this build vendors rust/vendor/xla-stub; see README \"PJRT feature\")"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(op: &str) -> Result<T> {
    Err(Error::Unavailable(op.to_string()))
}

/// Parsed HLO module (stub: never constructed successfully).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("real XLA/PJRT bindings"), "{err}");
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
