//! One compiled HLO artifact: load text → compile → execute f32 buffers.
//!
//! NOT Send/Sync (the `xla` crate wrappers are `Rc`-based): construct and
//! use only on the runtime thread (`host::RuntimeHost`) or in
//! single-threaded tools/benches.

use crate::util::error::{Error, Result};

/// A compiled PJRT executable plus its I/O metadata.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Input shapes, row-major dims per argument (from the manifest).
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shapes per tuple element.
    pub output_shapes: Vec<Vec<usize>>,
}

thread_local! {
    /// One PJRT CPU client per thread that compiles artifacts (in practice
    /// only the runtime thread and single-threaded tests).
    static CLIENT: std::result::Result<xla::PjRtClient, String> =
        xla::PjRtClient::cpu().map_err(|e| e.to_string());
}

impl Artifact {
    /// Load an HLO-text file and compile it.
    pub fn load(
        name: &str,
        hlo_path: &str,
        input_shapes: Vec<Vec<usize>>,
        output_shapes: Vec<Vec<usize>>,
    ) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .map_err(|e| Error::runtime(format!("parse {hlo_path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = CLIENT.with(|c| match c {
            Ok(client) => client
                .compile(&comp)
                .map_err(|e| Error::runtime(format!("compile {name}: {e}"))),
            Err(e) => Err(Error::runtime(format!("PJRT CPU client: {e}"))),
        })?;
        Ok(Artifact { name: name.to_string(), exe, input_shapes, output_shapes })
    }

    /// Execute with f32 inputs (row-major, matching `input_shapes`);
    /// returns the flattened f32 outputs per tuple element.
    pub fn execute_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.input_shapes.len() {
            return Err(Error::runtime(format!(
                "{}: got {} inputs, artifact wants {}",
                self.name,
                inputs.len(),
                self.input_shapes.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.input_shapes) {
            let want: usize = shape.iter().product();
            if data.len() != want {
                return Err(Error::runtime(format!(
                    "{}: input size {} != shape {:?}",
                    self.name,
                    data.len(),
                    shape
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| Error::runtime(format!("{}: reshape: {e}", self.name)))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::runtime(format!("{}: execute: {e}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("{}: fetch: {e}", self.name)))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| Error::runtime(format!("{}: tuple: {e}", self.name)))?;
        let mut outs = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| Error::runtime(format!("{}: output {i}: {e}", self.name)))?;
            if let Some(shape) = self.output_shapes.get(i) {
                let want: usize = shape.iter().product();
                if v.len() != want {
                    return Err(Error::runtime(format!(
                        "{}: output {i} size {} != manifest shape {:?}",
                        self.name,
                        v.len(),
                        shape
                    )));
                }
            }
            outs.push(v);
        }
        Ok(outs)
    }

    /// Declared batch size (first dim of the first input).
    pub fn batch_size(&self) -> usize {
        self.input_shapes.first().and_then(|s| s.first()).copied().unwrap_or(1)
    }
}
