//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → HLO *text*) and executes them on the PJRT
//! CPU client via the `xla` crate. This is the only module that touches
//! XLA; everything above it sees `ModelEval`.
//!
//! **Feature gate:** real artifact execution requires building with
//! `--features pjrt`. The default build is hermetic — it compiles a stub
//! [`artifact`] module with the same API whose `Artifact::load` fails with
//! a clear runtime error, so the registry, host thread, and everything
//! above them build and test without any XLA/PJRT shared libraries. The
//! `pjrt` feature itself links the `xla` dependency (vendored API stub at
//! `rust/vendor/xla-stub`; deployments patch in the real bindings).
//!
//! Two constraints shape the design:
//! * HLO **text** — not serialized HloModuleProto — is the interchange
//!   format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
//!   crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! * The crate's PJRT wrappers are `Rc`-based (neither `Send` nor `Sync`),
//!   so all client/executable state is confined to one dedicated runtime
//!   thread ([`host::RuntimeHost`]); the rest of the system talks to it
//!   over channels. `HloModel` (a `ModelEval`) is a thin Send+Sync handle.

#[cfg(feature = "pjrt")]
pub mod artifact;
#[cfg(not(feature = "pjrt"))]
#[path = "artifact_stub.rs"]
pub mod artifact;
pub mod hlo_model;
pub mod host;
pub mod registry;

pub use artifact::Artifact;
pub use hlo_model::HloModel;
pub use host::RuntimeHost;
pub use registry::{ManifestEntry, Registry};
