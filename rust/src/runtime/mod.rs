//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → HLO *text*) and executes them on the PJRT
//! CPU client via the `xla` crate. This is the only module that touches
//! XLA; everything above it sees `ModelEval`.
//!
//! Two constraints shape the design:
//! * HLO **text** — not serialized HloModuleProto — is the interchange
//!   format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
//!   crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! * The crate's PJRT wrappers are `Rc`-based (neither `Send` nor `Sync`),
//!   so all client/executable state is confined to one dedicated runtime
//!   thread ([`host::RuntimeHost`]); the rest of the system talks to it
//!   over channels. `HloModel` (a `ModelEval`) is a thin Send+Sync handle.

pub mod artifact;
pub mod hlo_model;
pub mod host;
pub mod registry;

pub use artifact::Artifact;
pub use hlo_model::HloModel;
pub use host::RuntimeHost;
pub use registry::{ManifestEntry, Registry};
