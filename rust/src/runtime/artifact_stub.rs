//! Hermetic stand-in for [`artifact`](self) when the `pjrt` feature is off.
//!
//! Same public API as the real module (the rest of `runtime` is compiled
//! unchanged against either), but `load` fails immediately: without the
//! feature there is no PJRT client to compile HLO with. Tests and servers
//! that never touch an `artifact:*` model are unaffected.

use crate::util::error::{Error, Result};

/// A compiled PJRT executable plus its I/O metadata (stub: never loads).
pub struct Artifact {
    pub name: String,
    /// Input shapes, row-major dims per argument (from the manifest).
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shapes per tuple element.
    pub output_shapes: Vec<Vec<usize>>,
}

fn feature_err(name: &str) -> Error {
    Error::runtime(format!(
        "artifact '{name}': sadiff was built without the `pjrt` feature; \
         rebuild with `cargo build --features pjrt` (and real XLA bindings) \
         to execute AOT artifacts"
    ))
}

impl Artifact {
    /// Always fails: artifact execution needs `--features pjrt`.
    pub fn load(
        name: &str,
        _hlo_path: &str,
        _input_shapes: Vec<Vec<usize>>,
        _output_shapes: Vec<Vec<usize>>,
    ) -> Result<Artifact> {
        Err(feature_err(name))
    }

    /// Unreachable in practice (`load` never returns an `Artifact`).
    pub fn execute_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        Err(feature_err(&self.name))
    }

    /// Declared batch size (first dim of the first input).
    pub fn batch_size(&self) -> usize {
        self.input_shapes.first().and_then(|s| s.first()).copied().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Artifact::load("gmm_denoiser", "x.hlo.txt", vec![], vec![]).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
