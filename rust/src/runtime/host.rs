//! The runtime host thread: owns the PJRT client and all compiled
//! executables (the `xla` crate's wrappers are `Rc`-based and must not
//! cross threads); serves execute requests over an mpsc channel.
//!
//! Latency note (§Perf): the channel round-trip adds ~1µs per call, which
//! is noise against any real model evaluation; in exchange every layer
//! above is free to be multi-threaded.

use super::registry::Registry;
use super::Artifact;
use crate::util::error::{Error, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

enum HostMsg {
    Exec {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: Sender<std::result::Result<Vec<Vec<f32>>, String>>,
    },
    Shutdown,
}

/// Send+Sync handle to the runtime thread. Cheap to clone.
pub struct RuntimeHost {
    tx: Mutex<Sender<HostMsg>>,
    /// Manifest metadata (shapes etc.) — plain data, readable anywhere.
    pub registry: Arc<Registry>,
}

impl RuntimeHost {
    /// Open the artifacts dir and start the runtime thread.
    pub fn open(dir: &str) -> Result<Arc<RuntimeHost>> {
        let registry = Arc::new(Registry::open(dir)?);
        let (tx, rx) = channel::<HostMsg>();
        let reg = registry.clone();
        let dir = dir.to_string();
        std::thread::Builder::new()
            .name("sadiff-pjrt".into())
            .spawn(move || {
                // All PJRT state lives and dies on this thread.
                let mut cache: HashMap<String, Artifact> = HashMap::new();
                while let Ok(msg) = rx.recv() {
                    match msg {
                        HostMsg::Shutdown => break,
                        HostMsg::Exec { name, inputs, reply } => {
                            let result = exec_on_thread(&reg, &dir, &mut cache, &name, &inputs);
                            let _ = reply.send(result.map_err(|e| e.to_string()));
                        }
                    }
                }
            })
            .map_err(|e| Error::runtime(format!("spawn runtime thread: {e}")))?;
        Ok(Arc::new(RuntimeHost { tx: Mutex::new(tx), registry }))
    }

    /// Open the default artifacts dir (`SADIFF_ARTIFACTS` or `artifacts`).
    pub fn open_default() -> Result<Arc<RuntimeHost>> {
        let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(&dir)
    }

    /// Execute artifact `name` with the given inputs (blocking).
    pub fn execute(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .lock()
            .expect("host tx lock")
            .send(HostMsg::Exec { name: name.to_string(), inputs, reply: reply_tx })
            .map_err(|_| Error::runtime("runtime thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| Error::runtime("runtime thread dropped the reply"))?
            .map_err(Error::Runtime)
    }

    /// Ask the runtime thread to exit (used by tests; dropping the host
    /// also works once all senders are gone).
    pub fn shutdown(&self) {
        let _ = self.tx.lock().expect("host tx lock").send(HostMsg::Shutdown);
    }
}

fn exec_on_thread(
    registry: &Registry,
    dir: &str,
    cache: &mut HashMap<String, Artifact>,
    name: &str,
    inputs: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>> {
    if !cache.contains_key(name) {
        let entry = registry
            .entry(name)
            .ok_or_else(|| Error::runtime(format!("unknown artifact '{name}'")))?;
        let path = format!("{dir}/{}", entry.file);
        let art = Artifact::load(name, &path, entry.inputs.clone(), entry.outputs.clone())?;
        cache.insert(name.to_string(), art);
    }
    let art = cache.get(name).expect("just inserted");
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    art.execute_f32(&refs)
}
