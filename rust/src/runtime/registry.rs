//! Artifact manifest: reads `artifacts/manifest.json` (written by
//! `python/compile/aot.py`). Pure metadata — Send+Sync; compilation and
//! execution happen on the runtime-host thread.
//!
//! Manifest schema:
//! ```json
//! {"artifacts": [
//!   {"name": "gmm_denoiser", "file": "gmm_denoiser.hlo.txt",
//!    "inputs": [[64, 16], [1], [1]], "outputs": [[64, 16]],
//!    "meta": {"dim": 16, "batch": 64, "time_convention": "alpha_sigma"}}
//! ]}
//! ```

use crate::jsonlite::{parse, Value};
use crate::util::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Declared artifact entry.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    pub meta: Value,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    entries: HashMap<String, ManifestEntry>,
}

impl Registry {
    /// Open `dir` containing `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let v = parse(&text)?;
        let mut entries = HashMap::new();
        let arts = v
            .get("artifacts")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::runtime("manifest: missing 'artifacts' array"))?;
        for a in arts {
            let entry = ManifestEntry {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                inputs: parse_shapes(a.get("inputs"))?,
                outputs: parse_shapes(a.get("outputs"))?,
                meta: a.get("meta").cloned().unwrap_or(Value::Object(vec![])),
            };
            entries.insert(entry.name.clone(), entry);
        }
        Ok(Registry { dir, entries })
    }

    /// Default artifacts directory (repo-root `artifacts/`), overridable
    /// via `SADIFF_ARTIFACTS`.
    pub fn open_default() -> Result<Registry> {
        let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Registry::open(dir)
    }

    /// Names declared in the manifest, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Manifest entry by name.
    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }
}

fn parse_shapes(v: Option<&Value>) -> Result<Vec<Vec<usize>>> {
    let arr = v
        .and_then(Value::as_array)
        .ok_or_else(|| Error::runtime("manifest: missing shape array"))?;
    arr.iter()
        .map(|shape| {
            shape
                .as_array()
                .ok_or_else(|| Error::runtime("manifest: shape must be an array"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::runtime("manifest: non-integer dim"))
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_manifest_errors_helpfully() {
        let err = Registry::open("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("sadiff_reg_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"name": "m", "file": "m.hlo.txt",
                "inputs": [[4, 2]], "outputs": [[4, 2]],
                "meta": {"dim": 2}}]}"#,
        )
        .unwrap();
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["m"]);
        let e = reg.entry("m").unwrap();
        assert_eq!(e.inputs, vec![vec![4, 2]]);
        assert_eq!(e.meta.req_usize("dim").unwrap(), 2);
        assert!(reg.entry("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_manifest_shapes_rejected() {
        let dir = std::env::temp_dir().join(format!("sadiff_reg_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"name": "m", "file": "f", "inputs": [["x"]], "outputs": []}]}"#,
        )
        .unwrap();
        assert!(Registry::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
