//! `ModelEval` over an AOT artifact, via the runtime-host thread.
//!
//! Artifact calling conventions (fixed by `python/compile/aot.py`):
//!
//! * GMM denoiser:  inputs `(x[B,D] f32, alpha[1] f32, sigma[1] f32)`,
//!   output `(x0hat[B,D] f32,)` — schedule-agnostic, the solver passes
//!   (α, σ) each call.
//! * DiT denoiser:  inputs `(x[B,D] f32, t[B] f32)`, output `(x0hat[B,D],)`
//!   — schedule baked at training time (VP-linear), t is physical time.
//!
//! Batch padding: artifacts have a fixed batch B; smaller batches are
//! zero-padded, larger ones chunked. Per-row models make this exact.

use super::RuntimeHost;
use crate::models::{EvalCtx, ModelEval};
use crate::util::error::{Error, Result};
use std::sync::Arc;

/// How the artifact wants its conditioning inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeConvention {
    /// (x, alpha, sigma) — the GMM artifact.
    AlphaSigma,
    /// (x, t) — the DiT artifact.
    PhysicalT,
}

/// A denoiser served from a PJRT artifact (Send+Sync handle).
pub struct HloModel {
    host: Arc<RuntimeHost>,
    artifact: String,
    dim: usize,
    batch: usize,
    convention: TimeConvention,
    label: String,
}

impl HloModel {
    /// Build from a manifest entry; the artifact compiles lazily on first
    /// use (on the runtime thread).
    pub fn new(
        host: Arc<RuntimeHost>,
        artifact: &str,
        convention: TimeConvention,
    ) -> Result<HloModel> {
        let entry = host
            .registry
            .entry(artifact)
            .ok_or_else(|| Error::runtime(format!("unknown artifact '{artifact}'")))?;
        let shape = entry.inputs.first().cloned().unwrap_or_default();
        let (batch, dim) = match shape.as_slice() {
            [b, d] => (*b, *d),
            other => {
                return Err(Error::runtime(format!(
                    "{artifact}: expected rank-2 x input, got {other:?}"
                )))
            }
        };
        let label = format!("hlo:{artifact}");
        Ok(HloModel { host, artifact: artifact.to_string(), dim, batch, convention, label })
    }

    /// Build with the convention recorded in the manifest's meta block.
    pub fn from_manifest(host: Arc<RuntimeHost>, artifact: &str) -> Result<HloModel> {
        let entry = host
            .registry
            .entry(artifact)
            .ok_or_else(|| Error::runtime(format!("unknown artifact '{artifact}'")))?;
        let convention = match entry.meta.opt_str("time_convention", "alpha_sigma") {
            "physical_t" => TimeConvention::PhysicalT,
            _ => TimeConvention::AlphaSigma,
        };
        Self::new(host, artifact, convention)
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Run one padded artifact call over `rows` (≤ batch) samples.
    fn run_chunk(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]) -> Result<()> {
        let rows = xs.len() / self.dim;
        debug_assert!(rows <= self.batch);
        let mut xf = vec![0.0f32; self.batch * self.dim];
        for (i, v) in xs.iter().enumerate() {
            xf[i] = *v as f32;
        }
        let inputs = match self.convention {
            TimeConvention::AlphaSigma => {
                vec![xf, vec![ctx.alpha as f32], vec![ctx.sigma as f32]]
            }
            TimeConvention::PhysicalT => vec![xf, vec![ctx.t as f32; self.batch]],
        };
        let outputs = self.host.execute(&self.artifact, inputs)?;
        let y = &outputs[0];
        for i in 0..rows * self.dim {
            out[i] = y[i] as f64;
        }
        Ok(())
    }
}

impl ModelEval for HloModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_batch(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]) {
        let n = xs.len() / self.dim;
        let mut start = 0usize;
        while start < n {
            let rows = (n - start).min(self.batch);
            let lo = start * self.dim;
            let hi = (start + rows) * self.dim;
            if let Err(e) = self.run_chunk(&xs[lo..hi], ctx, &mut out[lo..hi]) {
                // ModelEval is infallible by design (solvers are math, not
                // I/O); artifact failure is a deployment error worth dying
                // loudly for rather than silently corrupting samples.
                panic!("HLO model '{}' failed: {e}", self.label);
            }
            start += rows;
        }
    }

    fn name(&self) -> &str {
        &self.label
    }
}
