//! Minimal JSON implementation (parser + writer) for the config system and
//! the sampling server's newline-delimited JSON protocol. serde is not in
//! the offline vendor set; the subset here is full JSON minus `\u` surrogate
//! pairs outside the BMP.

mod parse;
mod write;

pub use parse::parse;
pub use write::to_string;

use crate::util::error::{Error, Result};

/// A JSON value. Object order is preserved (Vec of pairs) — cheap and keeps
/// protocol output deterministic for tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Typed field accessors with path-style error messages.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::json(format!("missing/invalid number field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| Error::json(format!("missing/invalid string field '{key}'")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| Error::json(format!("missing/invalid integer field '{key}'")))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Builder helpers.
    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|x| Value::Num(*x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1.5, "b": [true, null, "x\"y"], "c": {"d": -2e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.5);
        assert_eq!(v.get("b").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().req_f64("d").unwrap(), -2000.0);
        let s = to_string(&v);
        let v2 = parse(&s).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors_and_defaults() {
        let v = parse(r#"{"n": 4, "s": "hi", "flag": true}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(v.opt_bool("flag", false));
        assert_eq!(v.opt_f64("missing", 9.5), 9.5);
        assert_eq!(v.opt_str("missing", "d"), "d");
        assert!(v.req_f64("s").is_err());
    }

    #[test]
    fn builders() {
        let v = Value::obj(vec![("x", Value::Num(1.0)), ("ys", Value::arr_f64(&[1.0, 2.0]))]);
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }
}
