//! Recursive-descent JSON parser.

use super::Value;
use crate::util::error::{Error, Result};

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(fields)),
                _ => {
                    return Err(self.err("expected ',' or '}' in object"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex in \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Collect the full UTF-8 sequence starting at c.
                    let width = utf8_width(c);
                    let start = self.pos - 1;
                    for _ in 1..width {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -3.25e2 ").unwrap(), Value::Num(-325.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"[1, [2, {"k": [3]}], []]"#).unwrap();
        match v {
            Value::Array(items) => assert_eq!(items.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo ∂\"").unwrap();
        assert_eq!(v, Value::Str("héllo ∂".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }
}
