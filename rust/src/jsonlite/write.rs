//! JSON serializer (compact form).

use super::Value;

/// Serialize compactly. f64s that are integral print without a fraction so
/// ids survive round-trips through other JSON implementations.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Object(fields) => {
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; represent as null (documented protocol rule).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.007199254740992e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn integral_floats_compact() {
        assert_eq!(to_string(&Value::Num(4.0)), "4");
        assert_eq!(to_string(&Value::Num(4.5)), "4.5");
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
    }

    #[test]
    fn escapes() {
        let s = to_string(&Value::Str("a\"b\\c\nd\u{1}".into()));
        assert!(s.contains("\\u0001"), "control char must be escaped: {s}");
        assert_eq!(parse(&s).unwrap(), Value::Str("a\"b\\c\nd\u{1}".into()));
    }

    #[test]
    fn roundtrip_nested() {
        let v = Value::obj(vec![
            ("xs", Value::arr_f64(&[1.0, -0.5])),
            ("name", Value::Str("q".into())),
            ("inner", Value::obj(vec![("flag", Value::Bool(false))])),
        ]);
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }
}
