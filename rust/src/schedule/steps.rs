//! Timestep selectors: map (schedule, M) to the decreasing grid
//! t_0 = t_max > t_1 > … > t_M = t_min the solvers integrate over.

use super::NoiseSchedule;

/// How to place the M+1 timesteps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepSelector {
    /// Uniform in t.
    UniformT,
    /// Uniform in λ (log-SNR) — DPM-Solver's default.
    UniformLambda,
    /// EDM's ρ-schedule over σ^{EDM} = σ/α: σ_i = (σmax^{1/ρ} + i/M (σmin^{1/ρ} − σmax^{1/ρ}))^ρ.
    EdmRho { rho: f64 },
    /// Quadratic in t (denser near t_min).
    QuadraticT,
}

impl StepSelector {
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "uniform_t" => Some(StepSelector::UniformT),
            "uniform_lambda" => Some(StepSelector::UniformLambda),
            "edm_rho" => Some(StepSelector::EdmRho { rho: 7.0 }),
            "quadratic_t" => Some(StepSelector::QuadraticT),
            _ => None,
        }
    }

    /// Canonical name (inverse of [`Self::by_name`] up to the ρ parameter,
    /// which `SamplerConfig` serializes separately).
    pub fn name(&self) -> &'static str {
        match self {
            StepSelector::UniformT => "uniform_t",
            StepSelector::UniformLambda => "uniform_lambda",
            StepSelector::EdmRho { .. } => "edm_rho",
            StepSelector::QuadraticT => "quadratic_t",
        }
    }

    /// Every selector, for grid-kind sweeps (ρ at its EDM default).
    pub fn all() -> &'static [StepSelector] {
        &[
            StepSelector::UniformT,
            StepSelector::UniformLambda,
            StepSelector::EdmRho { rho: 7.0 },
            StepSelector::QuadraticT,
        ]
    }
}

/// Produce the M+1 decreasing timesteps for `m` solver steps.
pub fn timesteps(sch: &NoiseSchedule, sel: StepSelector, m: usize) -> Vec<f64> {
    assert!(m >= 1);
    let n = m + 1;
    match sel {
        StepSelector::UniformT => (0..n)
            .map(|i| sch.t_max + (sch.t_min - sch.t_max) * i as f64 / m as f64)
            .collect(),
        StepSelector::UniformLambda => {
            let (lam_lo, lam_hi) = sch.lambda_range();
            (0..n)
                .map(|i| {
                    let lam = lam_lo + (lam_hi - lam_lo) * i as f64 / m as f64;
                    sch.t_of_lambda(lam)
                })
                .collect()
        }
        StepSelector::EdmRho { rho } => {
            // σ^{EDM}(t) = σ_t/α_t = e^{−λ_t}; endpoints from the schedule.
            let (lam_lo, lam_hi) = sch.lambda_range();
            let smax = (-lam_lo).exp();
            let smin = (-lam_hi).exp();
            (0..n)
                .map(|i| {
                    let u = i as f64 / m as f64;
                    let lo = smin.powf(1.0 / rho);
                    let hi = smax.powf(1.0 / rho);
                    let s = (hi + u * (lo - hi)).powf(rho);
                    sch.t_of_lambda(-s.ln())
                })
                .collect()
        }
        StepSelector::QuadraticT => (0..n)
            .map(|i| {
                let u = i as f64 / m as f64;
                // Quadratic ramp from t_max down to t_min.
                sch.t_max + (sch.t_min - sch.t_max) * (2.0 * u - u * u)
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    fn check_grid(ts: &[f64], sch: &NoiseSchedule, m: usize) {
        assert_eq!(ts.len(), m + 1);
        assert!(close(ts[0], sch.t_max, 1e-9, 1e-12), "t0={} want {}", ts[0], sch.t_max);
        assert!(close(ts[m], sch.t_min, 1e-6, 1e-9), "tM={} want {}", ts[m], sch.t_min);
        for w in ts.windows(2) {
            assert!(w[1] < w[0], "not strictly decreasing: {w:?}");
        }
    }

    #[test]
    fn all_selectors_produce_valid_grids() {
        for sch in [
            NoiseSchedule::vp_linear(),
            NoiseSchedule::vp_cosine(),
            NoiseSchedule::ve(),
            NoiseSchedule::edm(),
        ] {
            for sel in [
                StepSelector::UniformT,
                StepSelector::UniformLambda,
                StepSelector::EdmRho { rho: 7.0 },
                StepSelector::QuadraticT,
            ] {
                for m in [1usize, 4, 20] {
                    let ts = timesteps(&sch, sel, m);
                    check_grid(&ts, &sch, m);
                }
            }
        }
    }

    #[test]
    fn selector_name_roundtrip() {
        for sel in StepSelector::all() {
            assert_eq!(StepSelector::by_name(sel.name()), Some(*sel));
        }
        assert!(StepSelector::by_name("nope").is_none());
    }

    #[test]
    fn uniform_lambda_is_uniform_in_lambda() {
        let sch = NoiseSchedule::vp_linear();
        let ts = timesteps(&sch, StepSelector::UniformLambda, 8);
        let lams: Vec<f64> = ts.iter().map(|t| sch.lambda(*t)).collect();
        let h0 = lams[1] - lams[0];
        for w in lams.windows(2) {
            assert!(close(w[1] - w[0], h0, 1e-4, 1e-7), "steps: {lams:?}");
        }
    }

    #[test]
    fn edm_rho_matches_edm_formula_on_ve() {
        // On the VE schedule σ^{EDM} = σ, so the grid must hit the EDM σ_i.
        let sch = NoiseSchedule::ve();
        let m = 10;
        let rho = 7.0;
        let ts = timesteps(&sch, StepSelector::EdmRho { rho }, m);
        for (i, t) in ts.iter().enumerate() {
            let u = i as f64 / m as f64;
            let (lo, hi) = (0.02f64.powf(1.0 / rho), 80f64.powf(1.0 / rho));
            let want = (hi + u * (lo - hi)).powf(rho);
            assert!(close(sch.sigma(*t), want, 1e-6, 1e-9), "i={i}");
        }
    }
}
