//! Noise schedules (forward process parameterizations) and timestep
//! selectors.
//!
//! A schedule defines α_t, σ_t with x_t | x_0 ~ N(α_t x_0, σ_t² I) and the
//! log-SNR λ_t = log(α_t/σ_t) (Kingma et al. 2021 notation, as used by the
//! paper's §3). All solvers work on the λ grid; Euler–Maruyama additionally
//! needs the drift/diffusion coefficients f(t) = d log α_t/dt and
//! g²(t) = dσ²/dt − 2 f σ² (Eq. (2)).
//!
//! Implemented schedules mirror the paper's evaluation set:
//! * `VpLinear`  — DDPM linear-β (LSUN / LDM experiments)
//! * `VpCosine`  — iDDPM cosine (ADM ImageNet-64)
//! * `Ve`        — SMLD geometric σ (EDM baseline-VE CIFAR10)
//! * `Edm`       — σ(t) = t, α = 1 (EDM preconditioning time)

pub mod steps;

pub use steps::{timesteps, StepSelector};

/// Which analytic schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleKind {
    /// β(t) = β0 + (β1−β0) t on t ∈ (0, 1].
    VpLinear { beta0: f64, beta1: f64 },
    /// α_t = cos(π/2 · (t+s)/(1+s)) / cos(π/2 · s/(1+s)) on t ∈ (0, 1].
    VpCosine { s: f64 },
    /// σ_t = σ_min (σ_max/σ_min)^t, α = 1, on t ∈ [0, 1].
    Ve { sigma_min: f64, sigma_max: f64 },
    /// σ_t = t, α = 1, t ∈ [σ_min, σ_max].
    Edm { sigma_min: f64, sigma_max: f64 },
}

/// A concrete noise schedule with its sampling time range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NoiseSchedule {
    pub kind: ScheduleKind,
    /// Smallest time we integrate down to (avoids the λ→∞ endpoint).
    pub t_min: f64,
    /// Largest time (the prior end).
    pub t_max: f64,
}

impl NoiseSchedule {
    /// DDPM linear-β defaults (β0=0.1, β1=20 in continuous time).
    pub fn vp_linear() -> Self {
        NoiseSchedule {
            kind: ScheduleKind::VpLinear { beta0: 0.1, beta1: 20.0 },
            t_min: 1e-3,
            t_max: 1.0,
        }
    }

    /// iDDPM cosine defaults (s = 0.008).
    pub fn vp_cosine() -> Self {
        NoiseSchedule {
            kind: ScheduleKind::VpCosine { s: 0.008 },
            t_min: 1e-3,
            t_max: 1.0 - 1e-3,
        }
    }

    /// EDM baseline-VE defaults (σ ∈ [0.02, 80] as in the paper's §E.2).
    pub fn ve() -> Self {
        NoiseSchedule {
            kind: ScheduleKind::Ve { sigma_min: 0.02, sigma_max: 80.0 },
            t_min: 0.0,
            t_max: 1.0,
        }
    }

    /// EDM time = σ ∈ [0.002, 80].
    pub fn edm() -> Self {
        NoiseSchedule {
            kind: ScheduleKind::Edm { sigma_min: 0.002, sigma_max: 80.0 },
            t_min: 0.002,
            t_max: 80.0,
        }
    }

    /// Build from a config name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "vp_linear" => Some(Self::vp_linear()),
            "vp_cosine" => Some(Self::vp_cosine()),
            "ve" => Some(Self::ve()),
            "edm" => Some(Self::edm()),
            _ => None,
        }
    }

    /// log α_t.
    pub fn log_alpha(&self, t: f64) -> f64 {
        match self.kind {
            ScheduleKind::VpLinear { beta0, beta1 } => {
                -0.25 * t * t * (beta1 - beta0) - 0.5 * t * beta0
            }
            ScheduleKind::VpCosine { s } => {
                let f = |u: f64| ((u + s) / (1.0 + s) * std::f64::consts::FRAC_PI_2).cos();
                (f(t) / f(0.0)).ln()
            }
            ScheduleKind::Ve { .. } | ScheduleKind::Edm { .. } => 0.0,
        }
    }

    /// α_t.
    pub fn alpha(&self, t: f64) -> f64 {
        self.log_alpha(t).exp()
    }

    /// σ_t.
    pub fn sigma(&self, t: f64) -> f64 {
        match self.kind {
            ScheduleKind::VpLinear { .. } | ScheduleKind::VpCosine { .. } => {
                // σ² = 1 − α² (VP); stable via expm1 for small t.
                (-(2.0 * self.log_alpha(t)).exp_m1()).max(1e-300).sqrt()
            }
            ScheduleKind::Ve { sigma_min, sigma_max } => {
                sigma_min * (sigma_max / sigma_min).powf(t)
            }
            ScheduleKind::Edm { .. } => t,
        }
    }

    /// λ_t = log(α_t/σ_t), strictly decreasing in t.
    pub fn lambda(&self, t: f64) -> f64 {
        self.log_alpha(t) - self.sigma(t).ln()
    }

    /// Invert λ → t (closed form per schedule).
    pub fn t_of_lambda(&self, lam: f64) -> f64 {
        match self.kind {
            ScheduleKind::VpLinear { beta0, beta1 } => {
                // α² = sigmoid(2λ) ⇒ logα = −½ log(1 + e^{−2λ})
                let log_alpha = -0.5 * ln_1p_exp(-2.0 * lam);
                // Solve (β1−β0)/4 t² + β0/2 t + logα = 0 for t ≥ 0.
                let a = 0.25 * (beta1 - beta0);
                let b = 0.5 * beta0;
                let c = log_alpha;
                (-b + (b * b - 4.0 * a * c).sqrt()) / (2.0 * a)
            }
            ScheduleKind::VpCosine { s } => {
                let log_alpha = -0.5 * ln_1p_exp(-2.0 * lam);
                let f0 = (s / (1.0 + s) * std::f64::consts::FRAC_PI_2).cos();
                let arg = (log_alpha + f0.ln()).exp().clamp(-1.0, 1.0);
                let t = arg.acos() * 2.0 * (1.0 + s) / std::f64::consts::PI - s;
                t.clamp(0.0, 1.0)
            }
            ScheduleKind::Ve { sigma_min, sigma_max } => {
                let sigma = (-lam).exp();
                (sigma / sigma_min).ln() / (sigma_max / sigma_min).ln()
            }
            ScheduleKind::Edm { .. } => (-lam).exp(),
        }
    }

    /// f(t) = d log α_t / dt (drift coefficient, Eq. (2)).
    pub fn dlog_alpha_dt(&self, t: f64) -> f64 {
        match self.kind {
            ScheduleKind::VpLinear { beta0, beta1 } => -0.5 * (beta0 + (beta1 - beta0) * t),
            ScheduleKind::VpCosine { s } => {
                let c = std::f64::consts::FRAC_PI_2 / (1.0 + s);
                -c * ((t + s) / (1.0 + s) * std::f64::consts::FRAC_PI_2).tan()
            }
            ScheduleKind::Ve { .. } | ScheduleKind::Edm { .. } => 0.0,
        }
    }

    /// dλ/dt (negative: SNR decreases with t).
    pub fn dlambda_dt(&self, t: f64) -> f64 {
        match self.kind {
            ScheduleKind::VpLinear { .. } | ScheduleKind::VpCosine { .. } => {
                // λ = logα − ½ log(1−α²) ⇒ dλ/dt = f · (1 + α²/σ²) = f/σ².
                self.dlog_alpha_dt(t) / self.sigma(t).powi(2)
            }
            ScheduleKind::Ve { sigma_min, sigma_max } => -(sigma_max / sigma_min).ln(),
            ScheduleKind::Edm { .. } => -1.0 / t,
        }
    }

    /// g²(t) = dσ²/dt − 2 f σ² = −2 σ² dλ/dt (Eq. (8)).
    pub fn g2(&self, t: f64) -> f64 {
        -2.0 * self.sigma(t).powi(2) * self.dlambda_dt(t)
    }

    /// λ range over the sampling interval: (λ(t_max), λ(t_min)) = (low, high).
    pub fn lambda_range(&self) -> (f64, f64) {
        (self.lambda(self.t_max), self.lambda(self.t_min))
    }
}

/// Numerically stable log(1 + e^x).
fn ln_1p_exp(x: f64) -> f64 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    fn all_schedules() -> Vec<NoiseSchedule> {
        vec![
            NoiseSchedule::vp_linear(),
            NoiseSchedule::vp_cosine(),
            NoiseSchedule::ve(),
            NoiseSchedule::edm(),
        ]
    }

    #[test]
    fn lambda_monotone_decreasing_in_t() {
        for sch in all_schedules() {
            let mut prev = f64::INFINITY;
            for i in 0..=50 {
                let t = sch.t_min + (sch.t_max - sch.t_min) * i as f64 / 50.0;
                let lam = sch.lambda(t);
                assert!(lam < prev, "{:?}: λ({t}) = {lam} !< {prev}", sch.kind);
                prev = lam;
            }
        }
    }

    #[test]
    fn lambda_inversion_roundtrip() {
        for sch in all_schedules() {
            for i in 1..20 {
                let t = sch.t_min + (sch.t_max - sch.t_min) * i as f64 / 20.0;
                let lam = sch.lambda(t);
                let t2 = sch.t_of_lambda(lam);
                assert!(
                    close(t2, t, 1e-6, 1e-8),
                    "{:?}: t={t} -> λ={lam} -> t'={t2}",
                    sch.kind
                );
            }
        }
    }

    #[test]
    fn vp_alpha_sigma_pythagorean() {
        for sch in [NoiseSchedule::vp_linear(), NoiseSchedule::vp_cosine()] {
            for i in 0..=10 {
                let t = sch.t_min + (sch.t_max - sch.t_min) * i as f64 / 10.0;
                let a = sch.alpha(t);
                let s = sch.sigma(t);
                assert!(close(a * a + s * s, 1.0, 1e-10, 0.0), "t={t}");
            }
        }
    }

    #[test]
    fn derivative_matches_finite_difference() {
        for sch in all_schedules() {
            for i in 1..10 {
                let t = sch.t_min + (sch.t_max - sch.t_min) * i as f64 / 10.0;
                let eps = 1e-6 * (sch.t_max - sch.t_min).max(1.0);
                let fd_la = (sch.log_alpha(t + eps) - sch.log_alpha(t - eps)) / (2.0 * eps);
                assert!(
                    close(sch.dlog_alpha_dt(t), fd_la, 1e-4, 1e-7),
                    "{:?} dlogα t={t}: {} vs fd {}",
                    sch.kind,
                    sch.dlog_alpha_dt(t),
                    fd_la
                );
                let fd_lam = (sch.lambda(t + eps) - sch.lambda(t - eps)) / (2.0 * eps);
                assert!(
                    close(sch.dlambda_dt(t), fd_lam, 1e-4, 1e-6),
                    "{:?} dλ t={t}: {} vs fd {}",
                    sch.kind,
                    sch.dlambda_dt(t),
                    fd_lam
                );
            }
        }
    }

    #[test]
    fn g2_positive() {
        for sch in all_schedules() {
            for i in 1..10 {
                let t = sch.t_min + (sch.t_max - sch.t_min) * i as f64 / 10.0;
                assert!(sch.g2(t) > 0.0, "{:?} g²({t}) = {}", sch.kind, sch.g2(t));
            }
        }
    }

    #[test]
    fn ve_matches_edm_sigma_convention() {
        let ve = NoiseSchedule::ve();
        assert!(close(ve.sigma(0.0), 0.02, 1e-12, 0.0));
        assert!(close(ve.sigma(1.0), 80.0, 1e-9, 0.0));
    }

    #[test]
    fn by_name_lookup() {
        assert!(NoiseSchedule::by_name("vp_linear").is_some());
        assert!(NoiseSchedule::by_name("nope").is_none());
    }
}
