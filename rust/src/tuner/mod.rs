//! Autotuner: budget-aware search over the solver zoo, producing a
//! persisted preset registry the server can serve from.
//!
//! SA-Solver's quality hinges on choices the paper ablates by hand —
//! predictor/corrector orders, the τ(t) stochasticity schedule, and the
//! timestep grid per NFE budget. Following the solver-searching line of
//! work (Liu et al.'s unified sampling framework; Wang et al.'s adaptive
//! stochastic coefficients), this subsystem searches that space per
//! `(workload, NFE budget)` cell instead of fixing one recipe:
//!
//! * [`space`] — the candidate grid (coarse sweep) and the local
//!   neighborhood an incumbent is refined within;
//! * [`search`] — coarse-then-refine search, scored against
//!   `Workload::reference` via `metrics::{sim_fid, sliced_w2}`, fanned out
//!   across candidates on `exec::Executor` (deterministic for any thread
//!   count — the same lane-keying contract the serving path relies on);
//! * [`registry`] — the versioned JSON registry (`schema_version`,
//!   provenance) written by `sadiff tune`, loaded by `sadiff serve
//!   --presets`, and resolved per request via the `"preset"` field
//!   (`"auto"` = workload + nearest budget).
//!
//! Resolution happens at server ingress, so a preset request and a manual
//! request with the same concrete config land in the same dynamic batch.

pub mod registry;
pub mod search;
pub mod space;

pub use registry::{Preset, PresetRegistry, Provenance, SCHEMA_VERSION};
pub use search::{tune, tune_cell, CellResult, Scored, TuneOptions};
pub use space::SearchSpace;
