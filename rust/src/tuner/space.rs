//! The tuner's candidate space: which `(solver, order, τ, grid)` points the
//! coarse sweep enumerates, and how local refinement perturbs an incumbent.
//!
//! Candidates are plain [`SamplerConfig`]s (the NFE budget is stamped on by
//! the search), deduplicated by their canonical JSON — the same string the
//! batcher keys on, so "distinct candidate" and "distinct serving batch"
//! mean the same thing.

use crate::config::{SamplerConfig, SolverKind, TauKind};
use crate::jsonlite::to_string;
use crate::schedule::StepSelector;

/// Canonical dedup/ordering key for a candidate (batcher-compatible JSON).
pub fn cfg_key(cfg: &SamplerConfig) -> String {
    to_string(&cfg.to_json())
}

/// The coarse grid the search sweeps, one axis per ablated choice.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Solver families in contention.
    pub solvers: Vec<SolverKind>,
    /// SA/UniPC predictor orders s.
    pub predictor_steps: Vec<usize>,
    /// SA/UniPC corrector orders ŝ (0 disables the corrector).
    pub corrector_steps: Vec<usize>,
    /// τ magnitudes for the stochastic solvers (also DDIM η candidates,
    /// clamped to η's [0, 2] domain).
    pub taus: Vec<f64>,
    /// τ(t) families: constant and/or the EDM-style σ band.
    pub tau_kinds: Vec<TauKind>,
    /// Timestep-grid kinds.
    pub selectors: Vec<StepSelector>,
    /// τ step tried (±) around an incumbent during refinement.
    pub tau_delta: f64,
}

impl Default for SearchSpace {
    /// The production sweep: every axis the paper ablates by hand, at
    /// coarse spacing (refinement closes the gap).
    fn default() -> Self {
        SearchSpace {
            solvers: vec![
                SolverKind::Sa,
                SolverKind::DpmSolverPp2m,
                SolverKind::UniPc,
                SolverKind::Heun,
                SolverKind::Ddim,
            ],
            predictor_steps: vec![2, 3],
            corrector_steps: vec![0, 2],
            taus: vec![0.0, 0.6, 1.0, 1.4],
            tau_kinds: vec![
                TauKind::Constant,
                TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 },
            ],
            selectors: vec![
                StepSelector::UniformLambda,
                StepSelector::EdmRho { rho: 7.0 },
                StepSelector::UniformT,
            ],
            tau_delta: 0.2,
        }
    }
}

impl SearchSpace {
    /// A minimal space for tests and the CI smoke bench: two solver
    /// families, one grid kind, a couple of τ points.
    pub fn tiny() -> Self {
        SearchSpace {
            solvers: vec![SolverKind::Sa, SolverKind::Ddim],
            predictor_steps: vec![2],
            corrector_steps: vec![0, 1],
            taus: vec![0.0, 1.0],
            tau_kinds: vec![TauKind::Constant],
            selectors: vec![StepSelector::UniformLambda],
            tau_delta: 0.25,
        }
    }

    /// Enumerate the coarse candidates at one NFE budget, deterministic
    /// order, no duplicates. Invalid combinations are skipped rather than
    /// erroring so users can put sloppy axes in a config.
    pub fn candidates(&self, budget: usize) -> Vec<SamplerConfig> {
        let mut out = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        let mut push = |cfg: SamplerConfig, out: &mut Vec<SamplerConfig>| {
            if cfg.validate().is_ok() && seen.insert(cfg_key(&cfg)) {
                out.push(cfg);
            }
        };
        for &solver in &self.solvers {
            for &selector in &self.selectors {
                let base = SamplerConfig {
                    nfe: budget,
                    selector,
                    ..SamplerConfig::for_solver(solver)
                };
                match solver {
                    SolverKind::Sa => {
                        for &predictor_steps in &self.predictor_steps {
                            for &corrector_steps in &self.corrector_steps {
                                for &tau in &self.taus {
                                    for &tau_kind in &self.tau_kinds {
                                        // A zero-magnitude band is the ODE
                                        // limit regardless of family; keep
                                        // the constant form only.
                                        if tau == 0.0 && tau_kind != TauKind::Constant {
                                            continue;
                                        }
                                        push(
                                            SamplerConfig {
                                                predictor_steps,
                                                corrector_steps,
                                                tau,
                                                tau_kind,
                                                ..base.clone()
                                            },
                                            &mut out,
                                        );
                                    }
                                }
                            }
                        }
                    }
                    SolverKind::UniPc => {
                        for &predictor_steps in &self.predictor_steps {
                            for &corrector_steps in &self.corrector_steps {
                                push(
                                    SamplerConfig {
                                        predictor_steps: predictor_steps.max(1),
                                        corrector_steps,
                                        ..base.clone()
                                    },
                                    &mut out,
                                );
                            }
                        }
                    }
                    SolverKind::Ddim => {
                        for &tau in &self.taus {
                            if tau > 2.0 {
                                continue; // η domain is [0, 2]
                            }
                            push(SamplerConfig { eta: tau, ..base.clone() }, &mut out);
                        }
                    }
                    SolverKind::EulerMaruyama => {
                        for &tau in &self.taus {
                            push(SamplerConfig { tau, ..base.clone() }, &mut out);
                        }
                    }
                    // Fixed-recipe baselines: one candidate per grid kind.
                    _ => push(base, &mut out),
                }
            }
        }
        out
    }

    /// Local neighbors of an incumbent: one knob nudged one notch, same
    /// solver family and grid kind. Deterministic order; the search layer
    /// handles dedup against already-scored candidates.
    pub fn neighbors(&self, cfg: &SamplerConfig) -> Vec<SamplerConfig> {
        let mut out = Vec::new();
        let mut push = |c: SamplerConfig| {
            if c.validate().is_ok() && cfg_key(&c) != cfg_key(cfg) {
                out.push(c);
            }
        };
        match cfg.solver {
            SolverKind::Sa => {
                for tau in [cfg.tau - self.tau_delta, cfg.tau + self.tau_delta] {
                    if (0.0..=16.0).contains(&tau) {
                        let mut c = SamplerConfig { tau, ..cfg.clone() };
                        // τ = 0 is the ODE limit whatever the family;
                        // canonicalize to the constant form (mirrors the
                        // coarse sweep) so the zero-magnitude band
                        // duplicate never enters the pool or a registry.
                        if tau == 0.0 {
                            c.tau_kind = TauKind::Constant;
                        }
                        push(c);
                    }
                }
                for predictor_steps in
                    [cfg.predictor_steps.saturating_sub(1), cfg.predictor_steps + 1]
                {
                    push(SamplerConfig { predictor_steps, ..cfg.clone() });
                }
                for corrector_steps in
                    [cfg.corrector_steps.saturating_sub(1), cfg.corrector_steps + 1]
                {
                    push(SamplerConfig { corrector_steps, ..cfg.clone() });
                }
            }
            SolverKind::UniPc => {
                for predictor_steps in
                    [cfg.predictor_steps.saturating_sub(1).max(1), cfg.predictor_steps + 1]
                {
                    push(SamplerConfig { predictor_steps, ..cfg.clone() });
                }
                for corrector_steps in
                    [cfg.corrector_steps.saturating_sub(1), cfg.corrector_steps + 1]
                {
                    push(SamplerConfig { corrector_steps, ..cfg.clone() });
                }
            }
            SolverKind::Ddim => {
                for eta in [cfg.eta - self.tau_delta, cfg.eta + self.tau_delta] {
                    if (0.0..=2.0).contains(&eta) {
                        push(SamplerConfig { eta, ..cfg.clone() });
                    }
                }
            }
            SolverKind::EulerMaruyama => {
                for tau in [cfg.tau - self.tau_delta, cfg.tau + self.tau_delta] {
                    if (0.0..=16.0).contains(&tau) {
                        push(SamplerConfig { tau, ..cfg.clone() });
                    }
                }
            }
            // Fixed-recipe baselines have no local knobs worth nudging.
            _ => {}
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coarse_candidates_valid_unique_and_budgeted() {
        for space in [SearchSpace::default(), SearchSpace::tiny()] {
            let cands = space.candidates(10);
            assert!(!cands.is_empty());
            let mut keys = std::collections::BTreeSet::new();
            for c in &cands {
                c.validate().unwrap();
                assert_eq!(c.nfe, 10);
                assert!(keys.insert(cfg_key(c)), "duplicate candidate {c:?}");
            }
        }
    }

    #[test]
    fn candidates_deterministic_order() {
        let space = SearchSpace::default();
        let a: Vec<String> = space.candidates(8).iter().map(cfg_key).collect();
        let b: Vec<String> = space.candidates(8).iter().map(cfg_key).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn tiny_space_is_small() {
        let n = SearchSpace::tiny().candidates(5).len();
        assert!(n <= 12, "tiny space has {n} candidates");
        assert!(n < SearchSpace::default().candidates(5).len());
    }

    #[test]
    fn neighbors_differ_and_validate() {
        let space = SearchSpace::default();
        for cfg in space.candidates(10).iter().take(20) {
            for nb in space.neighbors(cfg) {
                nb.validate().unwrap();
                assert_ne!(cfg_key(&nb), cfg_key(cfg));
                assert_eq!(nb.solver, cfg.solver, "refinement must stay in-family");
                assert_eq!(nb.nfe, cfg.nfe);
            }
        }
    }

    #[test]
    fn zero_tau_neighbor_canonicalizes_to_constant() {
        // Refining an interval-τ incumbent down to τ = 0 must emit the
        // constant form (same rule as the coarse sweep), not a
        // zero-magnitude band duplicate with a distinct batch key.
        let space = SearchSpace { tau_delta: 0.5, ..SearchSpace::default() };
        let cfg = SamplerConfig {
            nfe: 10,
            tau: 0.5,
            tau_kind: TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 },
            ..SamplerConfig::sa_default()
        };
        let nbs = space.neighbors(&cfg);
        let zero: Vec<_> = nbs.iter().filter(|c| c.tau == 0.0).collect();
        assert!(!zero.is_empty(), "τ−δ neighbor missing");
        assert!(zero.iter().all(|c| c.tau_kind == TauKind::Constant));
    }

    #[test]
    fn sa_neighbors_cover_every_knob() {
        let space = SearchSpace::default();
        let cfg = SamplerConfig { nfe: 10, ..SamplerConfig::sa_default() };
        let nbs = space.neighbors(&cfg);
        assert!(nbs.iter().any(|c| c.tau != cfg.tau));
        assert!(nbs.iter().any(|c| c.predictor_steps != cfg.predictor_steps));
        assert!(nbs.iter().any(|c| c.corrector_steps != cfg.corrector_steps));
    }
}
