//! Budget-aware search: coarse grid sweep + local refinement per
//! `(workload, NFE budget)` cell, scored against the workload reference.
//!
//! Candidates fan out across [`Executor`] workers (`exec.map` preserves
//! item order and each candidate is scored with a sequential inner
//! executor), so tuning throughput scales with threads while the selected
//! winner — and the emitted registry — is bit-identical for any thread
//! count and a fixed seed. Every scoring batch across the whole sweep +
//! refinement loop dispatches onto the caller's one persistent executor
//! pool; no threads are created or torn down between batches. Ranking is
//! a total order (NaN-hostile score, then the canonical config JSON) so
//! ties cannot flap between runs.

use super::registry::{Preset, PresetRegistry, Provenance, SCHEMA_VERSION};
use super::space::{cfg_key, SearchSpace};
use crate::config::SamplerConfig;
use crate::exec::Executor;
use crate::util::error::{Error, Result};
use crate::workloads::{self, Workload};
use std::collections::BTreeSet;

/// Tuning knobs (everything that affects the result is provenance).
#[derive(Debug, Clone)]
pub struct TuneOptions {
    /// Samples drawn per candidate evaluation.
    pub n: usize,
    /// Scoring seed (prior/noise draws and the reference set).
    pub seed: u64,
    /// Local-refinement rounds after the coarse sweep.
    pub refine_rounds: usize,
    /// Incumbents whose neighborhoods each refinement round explores.
    pub top_k: usize,
    pub space: SearchSpace,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { n: 512, seed: 7, refine_rounds: 1, top_k: 3, space: SearchSpace::default() }
    }
}

impl TuneOptions {
    /// Small-but-real settings for tests and the CI smoke bench.
    pub fn quick() -> Self {
        TuneOptions { n: 96, space: SearchSpace::tiny(), ..TuneOptions::default() }
    }
}

/// A scored candidate.
#[derive(Debug, Clone)]
pub struct Scored {
    pub cfg: SamplerConfig,
    pub sim_fid: f64,
    pub sliced_w2: f64,
}

impl Scored {
    /// NaN sorts last: a config that blows up must never win on a NaN
    /// comparison quirk.
    fn rank(&self) -> (f64, f64) {
        let nn = |x: f64| if x.is_nan() { f64::INFINITY } else { x };
        (nn(self.sim_fid), nn(self.sliced_w2))
    }
}

/// Deterministic total order: sim-FID, then sliced-W2, then config JSON.
fn cmp_scored(a: &Scored, b: &Scored) -> std::cmp::Ordering {
    let (a0, a1) = a.rank();
    let (b0, b1) = b.rank();
    a0.total_cmp(&b0)
        .then(a1.total_cmp(&b1))
        .then_with(|| cfg_key(&a.cfg).cmp(&cfg_key(&b.cfg)))
}

/// Result of tuning one `(workload, budget)` cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub best: Scored,
    /// Candidate evaluations spent on this cell.
    pub evals: usize,
}

fn score_batch(
    wl: &Workload,
    cands: &[SamplerConfig],
    opts: &TuneOptions,
    exec: &Executor,
) -> Vec<Scored> {
    // One model and one reference draw per cell, shared across candidate
    // workers (ModelEval is Send + Sync) — not one per candidate. Scores
    // match `engine::evaluate_with` exactly: same reference seed, same
    // metric parameters. Each candidate runs through the incremental
    // stepper driver (`solvers::run`) — the same code path the serving
    // scheduler steps — so a tuned preset is scored on exactly the
    // numerics it will serve with (bit-identical to the old
    // `engine::sample_with` path: single-member Philox batches coincide).
    let model = wl.model();
    let reference = wl.reference(opts.n, opts.seed ^ 0x5a5a);
    let dim = wl.dim();
    exec.map(cands, |_, cfg| {
        let out = crate::solvers::run(&*model, &wl.schedule, cfg, opts.n, opts.seed);
        let sim_fid = crate::metrics::sim_fid(&out.samples, &reference, dim).unwrap_or(f64::NAN);
        let sliced_w2 = crate::metrics::sliced_w2(&out.samples, &reference, dim, 32, opts.seed);
        Scored { cfg: cfg.clone(), sim_fid, sliced_w2 }
    })
}

/// Tune one `(workload, NFE budget)` cell: coarse sweep, then
/// `refine_rounds` rounds of neighborhood search around the `top_k`
/// incumbents. Deterministic for fixed options, any executor width.
pub fn tune_cell(
    wl: &Workload,
    budget: usize,
    opts: &TuneOptions,
    exec: &Executor,
) -> Result<CellResult> {
    let coarse = opts.space.candidates(budget);
    if coarse.is_empty() {
        return Err(Error::config(format!(
            "search space has no valid candidates at budget {budget}"
        )));
    }
    let mut visited: BTreeSet<String> = coarse.iter().map(cfg_key).collect();
    let mut pool = score_batch(wl, &coarse, opts, exec);
    let mut evals = pool.len();

    for _round in 0..opts.refine_rounds {
        let mut ranked: Vec<&Scored> = pool.iter().collect();
        ranked.sort_by(|a, b| cmp_scored(a, b));
        let mut frontier: Vec<SamplerConfig> = Vec::new();
        for inc in ranked.iter().take(opts.top_k) {
            for nb in opts.space.neighbors(&inc.cfg) {
                if visited.insert(cfg_key(&nb)) {
                    frontier.push(nb);
                }
            }
        }
        if frontier.is_empty() {
            break;
        }
        evals += frontier.len();
        pool.extend(score_batch(wl, &frontier, opts, exec));
    }

    let best = pool
        .iter()
        .min_by(|a, b| cmp_scored(a, b))
        .expect("non-empty pool")
        .clone();
    Ok(CellResult { best, evals })
}

/// Run the full search over `workload × budget` cells and assemble the
/// persisted registry. `workload_names` must all exist; budgets must be
/// valid NFE values.
pub fn tune(
    workload_names: &[String],
    budgets: &[usize],
    opts: &TuneOptions,
    exec: &Executor,
) -> Result<PresetRegistry> {
    if workload_names.is_empty() || budgets.is_empty() {
        return Err(Error::config("tune needs at least one workload and one budget"));
    }
    for &b in budgets {
        if !(2..=10_000).contains(&b) {
            return Err(Error::config(format!("budget {b} out of range (2..=10000)")));
        }
    }
    // Dedup (first occurrence wins) so `--workload a,a --budgets 5,5`
    // neither re-runs identical cells nor emits colliding preset names,
    // and resolve every workload *before* any search runs — a typo in the
    // last name must fail in milliseconds, not after hours of search.
    let mut seen_names = BTreeSet::new();
    let cells: Vec<(&str, Workload)> = workload_names
        .iter()
        .filter(|n| seen_names.insert(n.as_str()))
        .map(|name| {
            workloads::by_name(name)
                .map(|wl| (name.as_str(), wl))
                .ok_or_else(|| Error::config(format!("unknown workload '{name}'")))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut seen_budgets = BTreeSet::new();
    let budgets: Vec<usize> =
        budgets.iter().copied().filter(|b| seen_budgets.insert(*b)).collect();
    let mut presets = Vec::new();
    let mut evals = 0usize;
    for (name, wl) in &cells {
        for &budget in &budgets {
            let cell = tune_cell(wl, budget, opts, exec)?;
            crate::log_info!(
                "tuner",
                "{name}@{budget}: {} (sim_fid {:.4}, sliced_w2 {:.4}, {} evals)",
                cell.best.cfg.solver.name(),
                cell.best.sim_fid,
                cell.best.sliced_w2,
                cell.evals
            );
            evals += cell.evals;
            presets.push(Preset {
                name: format!("{name}@{budget}"),
                workload: name.to_string(),
                budget,
                cfg: cell.best.cfg,
                sim_fid: cell.best.sim_fid,
                sliced_w2: cell.best.sliced_w2,
            });
        }
    }
    Ok(PresetRegistry {
        schema_version: SCHEMA_VERSION,
        created_by: format!("sadiff {}", env!("CARGO_PKG_VERSION")),
        search: Provenance {
            seed: opts.seed,
            n: opts.n,
            refine_rounds: opts.refine_rounds,
            evals,
        },
        presets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> TuneOptions {
        // Keep unit tests fast: tiny space, few samples, modest budget.
        TuneOptions { n: 48, ..TuneOptions::quick() }
    }

    #[test]
    fn tune_cell_deterministic_across_threads() {
        let wl = workloads::latent_analog();
        let o = opts();
        let seq = tune_cell(&wl, 6, &o, &Executor::sequential()).unwrap();
        for threads in [2usize, 5] {
            let par = tune_cell(&wl, 6, &o, &Executor::new(threads)).unwrap();
            assert_eq!(cfg_key(&par.best.cfg), cfg_key(&seq.best.cfg), "threads={threads}");
            assert_eq!(par.best.sim_fid.to_bits(), seq.best.sim_fid.to_bits());
            assert_eq!(par.evals, seq.evals);
        }
    }

    #[test]
    fn refinement_never_hurts() {
        let wl = workloads::latent_analog();
        let coarse_only = TuneOptions { refine_rounds: 0, ..opts() };
        let refined = TuneOptions { refine_rounds: 2, ..opts() };
        let a = tune_cell(&wl, 6, &coarse_only, &Executor::sequential()).unwrap();
        let b = tune_cell(&wl, 6, &refined, &Executor::sequential()).unwrap();
        // The refined pool contains the coarse pool, so its winner can only
        // be at least as good under the same total order.
        assert!(cmp_scored(&b.best, &a.best) != std::cmp::Ordering::Greater);
        assert!(b.evals >= a.evals);
    }

    #[test]
    fn tune_builds_registry_with_provenance() {
        let reg = tune(
            &["latent_analog".to_string()],
            &[5, 8],
            &opts(),
            &Executor::sequential(),
        )
        .unwrap();
        assert_eq!(reg.schema_version, SCHEMA_VERSION);
        assert_eq!(reg.presets.len(), 2);
        assert_eq!(reg.presets[0].name, "latent_analog@5");
        assert_eq!(reg.presets[0].cfg.nfe, 5);
        assert_eq!(reg.presets[1].budget, 8);
        assert!(reg.search.evals > 0);
        assert_eq!(reg.search.n, opts().n);
        assert!(reg.created_by.starts_with("sadiff "));
    }

    #[test]
    fn tune_dedups_workloads_and_budgets() {
        let o = TuneOptions { refine_rounds: 0, ..opts() };
        let exec = Executor::sequential();
        let once = tune(&["latent_analog".to_string()], &[5], &o, &exec).unwrap();
        let duped = tune(
            &["latent_analog".to_string(), "latent_analog".to_string()],
            &[5, 5],
            &o,
            &exec,
        )
        .unwrap();
        assert_eq!(once.to_line(), duped.to_line(), "duplicate inputs changed the registry");
    }

    #[test]
    fn tune_rejects_bad_inputs() {
        let o = opts();
        let exec = Executor::sequential();
        assert!(tune(&[], &[5], &o, &exec).is_err());
        assert!(tune(&["latent_analog".to_string()], &[], &o, &exec).is_err());
        assert!(tune(&["latent_analog".to_string()], &[1], &o, &exec).is_err());
        assert!(tune(&["bogus".to_string()], &[5], &o, &exec).is_err());
        // A bad name anywhere in the list fails up front — valid earlier
        // entries must not trigger search work that gets discarded.
        let names = ["latent_analog".to_string(), "bogus".to_string()];
        assert!(tune(&names, &[5], &o, &exec).is_err());
    }

    #[test]
    fn registry_roundtrips_through_json() {
        let reg = tune(
            &["latent_analog".to_string()],
            &[5],
            &TuneOptions { refine_rounds: 0, ..opts() },
            &Executor::sequential(),
        )
        .unwrap();
        let parsed =
            PresetRegistry::from_json(&crate::jsonlite::parse(&reg.to_line()).unwrap()).unwrap();
        assert_eq!(reg.to_line(), parsed.to_line());
    }
}
