//! The persisted preset registry: the tuner's output, versioned JSON on
//! disk, loaded by the server to answer `"preset"` requests.
//!
//! Wire shape (schema_version 1):
//! ```json
//! {
//!   "schema_version": 1,
//!   "created_by": "sadiff 0.1.0",
//!   "search": {"seed": 7, "n": 512, "refine_rounds": 1, "evals": 452},
//!   "presets": [
//!     {"name": "cifar_analog@10", "workload": "cifar_analog", "budget": 10,
//!      "sim_fid": 0.41, "sliced_w2": 0.12, "solver": { ...SamplerConfig... }}
//!   ]
//! }
//! ```

use crate::config::SamplerConfig;
use crate::jsonlite::{to_string, Value};
use crate::util::error::{Error, Result};

/// Newest registry schema this build reads and writes. Older files load
/// (missing fields default); newer files are rejected loudly.
pub const SCHEMA_VERSION: u64 = 1;

/// One tuned `(workload, NFE budget)` cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Preset {
    /// Canonical name, `<workload>@<budget>`.
    pub name: String,
    pub workload: String,
    /// The NFE budget this cell was tuned for.
    pub budget: usize,
    /// The winning configuration (its `nfe` equals `budget`).
    pub cfg: SamplerConfig,
    /// Winning scores against the workload reference at tuning time.
    pub sim_fid: f64,
    pub sliced_w2: f64,
}

impl Preset {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("workload", Value::Str(self.workload.clone())),
            ("budget", Value::Num(self.budget as f64)),
            ("sim_fid", Value::Num(self.sim_fid)),
            ("sliced_w2", Value::Num(self.sliced_w2)),
            ("solver", self.cfg.to_json()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Preset> {
        let solver = v
            .get("solver")
            .ok_or_else(|| Error::config("preset missing 'solver' object"))?;
        let p = Preset {
            name: v.req_str("name")?.to_string(),
            workload: v.req_str("workload")?.to_string(),
            budget: v.req_usize("budget")?,
            cfg: SamplerConfig::from_json(solver)?,
            sim_fid: v.opt_f64("sim_fid", f64::NAN),
            sliced_w2: v.opt_f64("sliced_w2", f64::NAN),
        };
        // Auto-resolution matches on `budget`; serving then runs `cfg` — a
        // hand-edited registry where the two disagree would silently spend
        // a different NFE than the client asked for.
        if p.cfg.nfe != p.budget {
            return Err(Error::config(format!(
                "preset '{}': solver nfe {} != budget {}",
                p.name, p.cfg.nfe, p.budget
            )));
        }
        Ok(p)
    }
}

/// Search provenance recorded alongside the presets.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Scoring seed of the search.
    pub seed: u64,
    /// Samples per candidate evaluation.
    pub n: usize,
    /// Local-refinement rounds.
    pub refine_rounds: usize,
    /// Total candidate evaluations performed.
    pub evals: usize,
}

/// A versioned, persisted set of presets.
#[derive(Debug, Clone, PartialEq)]
pub struct PresetRegistry {
    pub schema_version: u64,
    /// Producing binary + version, e.g. `sadiff 0.1.0`.
    pub created_by: String,
    pub search: Provenance,
    pub presets: Vec<Preset>,
}

impl PresetRegistry {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("schema_version", Value::Num(self.schema_version as f64)),
            ("created_by", Value::Str(self.created_by.clone())),
            (
                "search",
                Value::obj(vec![
                    ("seed", Value::Num(self.search.seed as f64)),
                    ("n", Value::Num(self.search.n as f64)),
                    ("refine_rounds", Value::Num(self.search.refine_rounds as f64)),
                    ("evals", Value::Num(self.search.evals as f64)),
                ]),
            ),
            ("presets", Value::Array(self.presets.iter().map(Preset::to_json).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<PresetRegistry> {
        let version = v
            .get("schema_version")
            .and_then(Value::as_u64)
            .ok_or_else(|| Error::config("preset registry missing 'schema_version'"))?;
        if version > SCHEMA_VERSION {
            return Err(Error::config(format!(
                "preset registry schema_version {version} is newer than supported {SCHEMA_VERSION}"
            )));
        }
        let search = v.get("search");
        let presets = v
            .get("presets")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("preset registry missing 'presets' array"))?
            .iter()
            .map(Preset::from_json)
            .collect::<Result<Vec<_>>>()?;
        let g = |key: &str, d: usize| search.map_or(d, |s| s.opt_usize(key, d));
        Ok(PresetRegistry {
            schema_version: version,
            created_by: v.opt_str("created_by", "unknown").to_string(),
            search: Provenance {
                seed: search.and_then(|s| s.get("seed")).and_then(Value::as_u64).unwrap_or(0),
                n: g("n", 0),
                refine_rounds: g("refine_rounds", 0),
                evals: g("evals", 0),
            },
            presets,
        })
    }

    /// Serialize to the canonical one-line JSON (what `save` writes).
    pub fn to_line(&self) -> String {
        to_string(&self.to_json())
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_line()))
            .map_err(|e| Error::config(format!("cannot write {path}: {e}")))
    }

    pub fn load(path: &str) -> Result<PresetRegistry> {
        Self::from_json(&crate::config::load_json_file(path)?)
    }

    /// Resolve a request's `"preset"` field to a concrete preset.
    ///
    /// * `"auto"` — presets for `workload`, nearest `budget` to the
    ///   requested NFE (ties break toward the smaller budget).
    /// * anything else — exact preset-name match; the preset must be tuned
    ///   for the request's workload (configs do not transfer across
    ///   workloads, so a mismatch is an error, not a silent apply).
    pub fn resolve(&self, spec: &str, workload: &str, nfe: usize) -> Result<&Preset> {
        if spec == "auto" {
            return self
                .presets
                .iter()
                .filter(|p| p.workload == workload)
                .min_by_key(|p| (p.budget.abs_diff(nfe), p.budget))
                .ok_or_else(|| {
                    Error::protocol(format!("no presets for workload '{workload}' in registry"))
                });
        }
        let p = self.presets.iter().find(|p| p.name == spec).ok_or_else(|| {
            let names: Vec<&str> = self.presets.iter().map(|p| p.name.as_str()).collect();
            Error::protocol(format!(
                "unknown preset '{spec}' (available: {})",
                names.join(", ")
            ))
        })?;
        if p.workload != workload {
            return Err(Error::protocol(format!(
                "preset '{spec}' is tuned for workload '{}', not '{workload}'",
                p.workload
            )));
        }
        Ok(p)
    }

    /// Compact summary for the server's `presets` protocol command: no full
    /// solver configs, just enough to see what is loaded.
    pub fn summary(&self) -> Value {
        Value::obj(vec![
            ("ok", Value::Bool(true)),
            ("schema_version", Value::Num(self.schema_version as f64)),
            ("created_by", Value::Str(self.created_by.clone())),
            ("count", Value::Num(self.presets.len() as f64)),
            (
                "presets",
                Value::Array(
                    self.presets
                        .iter()
                        .map(|p| {
                            Value::obj(vec![
                                ("name", Value::Str(p.name.clone())),
                                ("workload", Value::Str(p.workload.clone())),
                                ("budget", Value::Num(p.budget as f64)),
                                ("solver", Value::Str(p.cfg.solver.name().into())),
                                ("sim_fid", Value::Num(p.sim_fid)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverKind;
    use crate::jsonlite::parse;

    fn preset(workload: &str, budget: usize) -> Preset {
        Preset {
            name: format!("{workload}@{budget}"),
            workload: workload.into(),
            budget,
            cfg: SamplerConfig { nfe: budget, ..SamplerConfig::sa_default() },
            sim_fid: 0.5,
            sliced_w2: 0.25,
        }
    }

    fn registry() -> PresetRegistry {
        PresetRegistry {
            schema_version: SCHEMA_VERSION,
            created_by: "sadiff test".into(),
            search: Provenance { seed: 7, n: 128, refine_rounds: 1, evals: 42 },
            presets: vec![
                preset("cifar_analog", 5),
                preset("cifar_analog", 10),
                preset("cifar_analog", 20),
                preset("latent_analog", 10),
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let reg = registry();
        let parsed = PresetRegistry::from_json(&parse(&reg.to_line()).unwrap()).unwrap();
        assert_eq!(reg, parsed);
    }

    #[test]
    fn save_load_roundtrip() {
        let reg = registry();
        let dir = std::env::temp_dir().join(format!("sadiff_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("presets.json");
        reg.save(path.to_str().unwrap()).unwrap();
        let loaded = PresetRegistry::load(path.to_str().unwrap()).unwrap();
        assert_eq!(reg, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn newer_schema_rejected() {
        let mut reg = registry();
        reg.schema_version = SCHEMA_VERSION + 1;
        let err = PresetRegistry::from_json(&parse(&reg.to_line()).unwrap());
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("newer"));
    }

    #[test]
    fn missing_version_rejected() {
        let v = parse(r#"{"presets": []}"#).unwrap();
        assert!(PresetRegistry::from_json(&v).is_err());
    }

    #[test]
    fn nfe_budget_mismatch_rejected() {
        let mut reg = registry();
        reg.presets[0].cfg.nfe = 25; // budget stays 5
        let err = PresetRegistry::from_json(&parse(&reg.to_line()).unwrap()).unwrap_err();
        assert!(err.to_string().contains("!= budget"), "{err}");
    }

    #[test]
    fn resolve_auto_picks_nearest_budget() {
        let reg = registry();
        assert_eq!(reg.resolve("auto", "cifar_analog", 11).unwrap().budget, 10);
        assert_eq!(reg.resolve("auto", "cifar_analog", 4).unwrap().budget, 5);
        assert_eq!(reg.resolve("auto", "cifar_analog", 100).unwrap().budget, 20);
        assert_eq!(reg.resolve("auto", "cifar_analog", 7).unwrap().budget, 5);
        // Tie: 15 is equidistant from 10 and 20 → smaller budget wins.
        assert_eq!(reg.resolve("auto", "cifar_analog", 15).unwrap().budget, 10);
        assert!(reg.resolve("auto", "bedroom_analog", 10).is_err());
    }

    #[test]
    fn resolve_by_name() {
        let reg = registry();
        assert_eq!(reg.resolve("latent_analog@10", "latent_analog", 0).unwrap().budget, 10);
        let err = reg.resolve("nope@1", "cifar_analog", 10).unwrap_err();
        assert!(err.to_string().contains("cifar_analog@5"), "{err}");
    }

    #[test]
    fn resolve_by_name_rejects_workload_mismatch() {
        // A named preset applied to the wrong workload is an error, not a
        // silent cross-workload config transplant.
        let reg = registry();
        let err = reg.resolve("latent_analog@10", "cifar_analog", 10).unwrap_err();
        assert!(err.to_string().contains("tuned for workload"), "{err}");
    }

    #[test]
    fn summary_shape() {
        let s = registry().summary();
        assert!(s.opt_bool("ok", false));
        assert_eq!(s.req_usize("count").unwrap(), 4);
        let first = &s.get("presets").unwrap().as_array().unwrap()[0];
        assert_eq!(first.req_str("name").unwrap(), "cifar_analog@5");
        assert_eq!(first.req_str("solver").unwrap(), SolverKind::Sa.name());
    }
}
