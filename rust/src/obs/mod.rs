//! Observability (Layer 3 cross-cutting): span tracing and trace export.
//!
//! This is the repo's third cross-cutting contract, after bit-identity
//! and allocation-freedom: **observable, and free when off**. The
//! serving stack is instrumented with spans (queue wait, admission,
//! solver step, model eval, checkpoint write, response write) that cost
//! one relaxed atomic load and zero allocations while tracing is
//! disabled — cheap enough to live inside the allocation-free per-step
//! hot path — and record into per-thread fixed-capacity ring buffers
//! while enabled. A capture exports as Chrome Trace Event JSON that
//! opens directly in Perfetto, with one lane per thread (accept loop,
//! workers, exec pool).
//!
//! * [`trace`] — the recorder: enable flag, spans, ring buffers, dump.
//! * [`chrome`] — Chrome Trace Event Format export and validation.
//!
//! Aggregate per-stage latency *histograms* (always on, independent of
//! the tracer) live in [`crate::coordinator::metrics`]; this module is
//! the event-level view. See docs/OBSERVABILITY.md for the span model,
//! the ring drop policy, and the overhead contract as gated in CI.
//!
//! ```
//! sadiff::obs::trace::start();
//! {
//!     let _s = sadiff::obs::trace::span("work", "demo");
//! }
//! sadiff::obs::trace::stop();
//! let lanes = sadiff::obs::trace::dump();
//! assert!(lanes.iter().flat_map(|l| &l.events).any(|e| e.name == "work"));
//! ```

pub mod chrome;
pub mod trace;

pub use trace::{span, Span, ThreadLane};
