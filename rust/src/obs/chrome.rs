//! Chrome Trace Event Format export: turns a [`trace::dump`] into JSON
//! that opens directly in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`.
//!
//! The export is the "JSON Object Format" variant: a top-level
//! `traceEvents` array of complete-duration events (`"ph":"X"`, `ts` and
//! `dur` in microseconds) preceded by `thread_name` metadata events
//! (`"ph":"M"`) so every recorded thread — the accept loop, each worker,
//! and the exec pool — gets a named lane in the viewer. Span categories
//! land in `cat`, so Perfetto can filter scheduler vs. engine vs. io
//! spans.

use crate::jsonlite::{parse, to_string, Value};
use crate::obs::trace::{self, ThreadLane};
use crate::util::error::{Error, Result};

/// The process id stamped on every event (single-process trace).
const PID: u64 = 1;

/// Build the Chrome Trace Event JSON object for a set of captured lanes.
pub fn export(lanes: &[ThreadLane]) -> Value {
    let mut events: Vec<Value> = Vec::new();
    events.push(Value::obj(vec![
        ("ph", Value::Str("M".into())),
        ("name", Value::Str("process_name".into())),
        ("pid", Value::Num(PID as f64)),
        ("tid", Value::Num(0.0)),
        ("args", Value::obj(vec![("name", Value::Str("sadiff".into()))])),
    ]));
    for lane in lanes {
        events.push(Value::obj(vec![
            ("ph", Value::Str("M".into())),
            ("name", Value::Str("thread_name".into())),
            ("pid", Value::Num(PID as f64)),
            ("tid", Value::Num(lane.tid as f64)),
            ("args", Value::obj(vec![("name", Value::Str(lane.label.clone()))])),
        ]));
        for ev in &lane.events {
            events.push(Value::obj(vec![
                ("ph", Value::Str("X".into())),
                ("name", Value::Str(ev.name.into())),
                ("cat", Value::Str(ev.cat.into())),
                ("ts", Value::Num(ev.start_us as f64)),
                ("dur", Value::Num(ev.dur_us as f64)),
                ("pid", Value::Num(PID as f64)),
                ("tid", Value::Num(lane.tid as f64)),
            ]));
        }
    }
    let dropped: u64 = lanes.iter().map(|l| l.dropped).sum();
    Value::obj(vec![
        ("traceEvents", Value::Array(events)),
        ("displayTimeUnit", Value::Str("ms".into())),
        ("otherData", Value::obj(vec![("dropped_events", Value::Num(dropped as f64))])),
    ])
}

/// [`export`] of the recorder's current capture ([`trace::dump`]).
pub fn export_current() -> Value {
    export(&trace::dump())
}

/// Write the current capture to `path` as Chrome Trace Event JSON.
/// Atomic (tmp file + rename) like server checkpoints, so a dump never
/// leaves a half-written file. Returns the number of span events written
/// (metadata events excluded).
pub fn write_file(path: &str) -> Result<usize> {
    let lanes = trace::dump();
    let n: usize = lanes.iter().map(|l| l.events.len()).sum();
    let v = export(&lanes);
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, format!("{}\n", to_string(&v)))
        .map_err(|e| Error::runtime(format!("cannot write {tmp}: {e}")))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| Error::runtime(format!("cannot rename {tmp} -> {path}: {e}")))?;
    Ok(n)
}

/// Validate a Chrome Trace Event JSON string and summarize it: total span
/// events, time extent, per-lane and per-name counts. This is what
/// `sadiff trace <path>` prints.
pub fn describe(text: &str) -> Result<Vec<String>> {
    let v = parse(text).map_err(|e| Error::config(format!("trace is not valid JSON: {e}")))?;
    let events = v
        .get("traceEvents")
        .and_then(Value::as_array)
        .ok_or_else(|| Error::config("not a Chrome Trace Event dump: missing 'traceEvents'"))?;

    let mut lane_names: Vec<(u64, String)> = Vec::new();
    // (name, cat) -> (count, total dur us)
    let mut by_name: Vec<(String, String, u64, f64)> = Vec::new();
    let mut spans = 0u64;
    let (mut t_min, mut t_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for ev in events {
        let ph = ev.get("ph").and_then(Value::as_str).unwrap_or("");
        if ph == "M" {
            if ev.get("name").and_then(Value::as_str) == Some("thread_name") {
                let tid = ev.get("tid").and_then(Value::as_u64).unwrap_or(0);
                let label = ev
                    .get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(Value::as_str)
                    .unwrap_or("?");
                lane_names.push((tid, label.to_string()));
            }
            continue;
        }
        if ph != "X" {
            return Err(Error::config(format!("unsupported event phase '{ph}' in trace")));
        }
        let ts = ev.req_f64("ts")?;
        let dur = ev.req_f64("dur")?;
        spans += 1;
        t_min = t_min.min(ts);
        t_max = t_max.max(ts + dur);
        let name = ev.get("name").and_then(Value::as_str).unwrap_or("?").to_string();
        let cat = ev.get("cat").and_then(Value::as_str).unwrap_or("?").to_string();
        match by_name.iter_mut().find(|(n, c, _, _)| *n == name && *c == cat) {
            Some(row) => {
                row.2 += 1;
                row.3 += dur;
            }
            None => by_name.push((name, cat, 1, dur)),
        }
    }

    let mut lines = Vec::new();
    let extent_ms = if spans > 0 { (t_max - t_min) / 1000.0 } else { 0.0 };
    lines.push(format!(
        "{spans} span events across {} lanes, {extent_ms:.3} ms extent",
        lane_names.len()
    ));
    lane_names.sort();
    for (tid, label) in &lane_names {
        lines.push(format!("  lane tid={tid}: {label}"));
    }
    by_name.sort_by(|a, b| (&a.1, &a.0).cmp(&(&b.1, &b.0)));
    for (name, cat, count, dur_us) in &by_name {
        let mean_us = dur_us / *count as f64;
        lines.push(format!(
            "  {cat}/{name}: {count} spans, total {:.3} ms, mean {mean_us:.1} us",
            dur_us / 1000.0
        ));
    }
    Ok(lines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Event;

    fn lane(tid: u64, label: &str, events: Vec<Event>) -> ThreadLane {
        ThreadLane { tid, label: label.to_string(), events, dropped: 0 }
    }

    #[test]
    fn export_emits_thread_metadata_and_complete_events() {
        let lanes = vec![
            lane(
                1,
                "sadiff-worker-0",
                vec![Event { name: "step", cat: "scheduler", start_us: 10, dur_us: 5 }],
            ),
            lane(2, "sadiff-accept", vec![]),
        ];
        let v = export(&lanes);
        let events = v.get("traceEvents").and_then(Value::as_array).unwrap();
        // process_name + 2 thread_name metadata + 1 span.
        assert_eq!(events.len(), 4);
        let span = events
            .iter()
            .find(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .expect("one complete event");
        assert_eq!(span.get("name").and_then(Value::as_str), Some("step"));
        assert_eq!(span.get("ts").and_then(Value::as_f64), Some(10.0));
        assert_eq!(span.get("dur").and_then(Value::as_f64), Some(5.0));
        assert_eq!(span.get("tid").and_then(Value::as_u64), Some(1));
        let meta_labels: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").and_then(Value::as_str) == Some("thread_name"))
            .map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Value::as_str).unwrap())
            .collect();
        assert_eq!(meta_labels, vec!["sadiff-worker-0", "sadiff-accept"]);
    }

    #[test]
    fn export_round_trips_through_describe() {
        let lanes = vec![lane(
            3,
            "sadiff-worker-1",
            vec![
                Event { name: "step", cat: "scheduler", start_us: 0, dur_us: 100 },
                Event { name: "step", cat: "scheduler", start_us: 200, dur_us: 100 },
                Event { name: "model_eval", cat: "engine", start_us: 10, dur_us: 40 },
            ],
        )];
        let text = to_string(&export(&lanes));
        let lines = describe(&text).expect("valid dump");
        assert!(lines[0].starts_with("3 span events across 1 lanes"));
        assert!(lines.iter().any(|l| l.contains("sadiff-worker-1")));
        assert!(lines.iter().any(|l| l.contains("scheduler/step: 2 spans")));
        assert!(lines.iter().any(|l| l.contains("engine/model_eval: 1 spans")));
    }

    #[test]
    fn describe_rejects_non_trace_json() {
        assert!(describe("{\"not_a_trace\": true}").is_err());
        assert!(describe("not json at all").is_err());
    }
}
