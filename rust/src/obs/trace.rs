//! Span recorder: per-thread fixed-capacity ring buffers behind one
//! process-global enable flag.
//!
//! The contract (docs/OBSERVABILITY.md) is "observable, and free when
//! off": a span on a disabled tracer costs exactly one relaxed atomic
//! load and zero allocations, so the recorder can sit inside the
//! allocation-free per-step hot path (`integration_alloc` proves the
//! zero-allocs-per-step contract with this module compiled in). When
//! enabled, recording is lock-cheap and allocation-free too *after* a
//! thread's first span (registration allocates that thread's ring once);
//! a full ring overwrites its oldest event and counts the overwrite in
//! [`ThreadLane::dropped`].
//!
//! Timestamps are microseconds on a process-wide monotonic epoch
//! (`std::time::Instant`), which is what the Chrome Trace Event export
//! ([`crate::obs::chrome`]) emits directly.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity, in events.
pub const DEFAULT_CAPACITY: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());

/// One recorded span: a named, categorized `[start, start+dur)` interval
/// on the thread that recorded it. Names and categories are `&'static
/// str` so recording never allocates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Span name (e.g. `"step"`, `"model_eval"`).
    pub name: &'static str,
    /// Coarse grouping for trace viewers (e.g. `"scheduler"`, `"io"`).
    pub cat: &'static str,
    /// Start, microseconds since the process trace epoch.
    pub start_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
}

/// Fixed-capacity event ring. Full ring → overwrite the oldest event
/// (newest events win; `dropped` counts the overwrites).
#[derive(Debug)]
struct Ring {
    buf: Vec<Event>,
    cap: usize,
    /// Write cursor once the ring has wrapped (`buf.len() == cap`).
    next: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { buf: Vec::with_capacity(cap), cap, next: 0, dropped: 0 }
    }

    /// Allocation-free: pushes within the preallocated capacity, then
    /// overwrites in place.
    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events oldest-first (un-wraps the ring).
    fn snapshot(&self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
        out
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.next = 0;
        self.dropped = 0;
    }
}

/// A registered thread's recorder state. Lives in the global registry for
/// the life of the process so a worker's events survive its thread.
#[derive(Debug)]
struct ThreadBuf {
    tid: u64,
    label: String,
    ring: Mutex<Ring>,
}

thread_local! {
    static LOCAL: RefCell<Option<Arc<ThreadBuf>>> = const { RefCell::new(None) };
}

/// One thread's captured events, as returned by [`dump`].
#[derive(Debug, Clone)]
pub struct ThreadLane {
    /// Stable per-process thread id (registration order, starting at 1).
    pub tid: u64,
    /// The OS thread name at registration (`"sadiff-worker-0"`,
    /// `"sadiff-accept"`, `"sadiff-exec-0"`, ...) or `"thread-{tid}"`.
    /// Exec pool workers live for their pool's lifetime, so each one
    /// registers a single lane that all of its dispatches share.
    pub label: String,
    /// Captured events, oldest first.
    pub events: Vec<Event>,
    /// Events overwritten because the ring was full (newest-wins policy).
    pub dropped: u64,
}

fn epoch() -> &'static Instant {
    EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (monotonic).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// True when the recorder is capturing. One relaxed load.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start a fresh capture: clears every registered ring, then enables
/// recording.
pub fn start() {
    epoch();
    for tb in REGISTRY.lock().unwrap().iter() {
        tb.ring.lock().unwrap().clear();
    }
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop capturing. Recorded events are kept until the next [`start`].
pub fn stop() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Set the per-thread ring capacity (events). Applies to threads that
/// register *after* the call; already-registered rings keep their size.
pub fn set_capacity(cap: usize) {
    CAPACITY.store(cap.max(16), Ordering::Relaxed);
}

/// The capacity newly registering threads will get.
pub fn capacity() -> usize {
    CAPACITY.load(Ordering::Relaxed)
}

fn register_thread() -> Arc<ThreadBuf> {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let label = std::thread::current()
        .name()
        .map(String::from)
        .unwrap_or_else(|| format!("thread-{tid}"));
    let tb = Arc::new(ThreadBuf { tid, label, ring: Mutex::new(Ring::new(capacity())) });
    REGISTRY.lock().unwrap().push(tb.clone());
    tb
}

fn record(ev: Event) {
    // `try_with` so a span dropped during thread teardown (TLS already
    // destroyed) degrades to a dropped event instead of a panic.
    let _ = LOCAL.try_with(|slot| {
        let mut slot = slot.borrow_mut();
        let tb = slot.get_or_insert_with(register_thread);
        tb.ring.lock().unwrap().push(ev);
    });
}

/// RAII span guard: records an [`Event`] from construction to drop on the
/// recording thread's lane. Constructed disabled, it is inert — see
/// [`span`].
#[must_use = "a span records its interval when dropped; binding it to _ drops it immediately"]
#[derive(Debug)]
pub struct Span {
    name: &'static str,
    cat: &'static str,
    /// `u64::MAX` marks a span created while disabled (records nothing).
    start_us: u64,
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.start_us == u64::MAX {
            return;
        }
        let dur_us = now_us().saturating_sub(self.start_us);
        record(Event { name: self.name, cat: self.cat, start_us: self.start_us, dur_us });
    }
}

/// Open a span. Disabled tracer: one relaxed load, no clock read, no
/// allocation, and the returned guard's drop is a single branch.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !is_enabled() {
        return Span { name, cat, start_us: u64::MAX };
    }
    Span { name, cat, start_us: now_us() }
}

/// Record a span that started at `start_us` (a [`now_us`] reading, possibly
/// taken on another thread) and ends now, on the *calling* thread's lane.
/// Used for cross-thread intervals like queue wait (enqueued on a
/// connection thread, admitted on a worker).
#[inline]
pub fn record_since(name: &'static str, cat: &'static str, start_us: u64) {
    if !is_enabled() {
        return;
    }
    let dur_us = now_us().saturating_sub(start_us);
    record(Event { name, cat, start_us, dur_us });
}

/// Snapshot every registered thread's captured events, ordered by thread
/// id. Does not clear the rings and does not stop the capture.
pub fn dump() -> Vec<ThreadLane> {
    let mut lanes: Vec<ThreadLane> = REGISTRY
        .lock()
        .unwrap()
        .iter()
        .map(|tb| {
            let ring = tb.ring.lock().unwrap();
            ThreadLane {
                tid: tb.tid,
                label: tb.label.clone(),
                events: ring.snapshot(),
                dropped: ring.dropped,
            }
        })
        .collect();
    lanes.sort_by_key(|l| l.tid);
    lanes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_fills_then_overwrites_oldest() {
        let mut r = Ring::new(3);
        let ev = |i: u64| Event { name: "e", cat: "t", start_us: i, dur_us: 1 };
        r.push(ev(1));
        r.push(ev(2));
        assert_eq!(r.dropped, 0);
        assert_eq!(r.snapshot().iter().map(|e| e.start_us).collect::<Vec<_>>(), vec![1, 2]);
        r.push(ev(3));
        r.push(ev(4)); // wraps: overwrites 1
        r.push(ev(5)); // overwrites 2
        assert_eq!(r.dropped, 2);
        assert_eq!(r.snapshot().iter().map(|e| e.start_us).collect::<Vec<_>>(), vec![3, 4, 5]);
        r.clear();
        assert_eq!(r.dropped, 0);
        assert!(r.snapshot().is_empty());
        r.push(ev(6));
        assert_eq!(r.snapshot().len(), 1);
    }

    // Single test for the global recorder (the enable flag and registry
    // are process-wide; keeping one test avoids cross-test interference
    // in the parallel harness).
    #[test]
    fn global_recorder_lifecycle() {
        // Disabled spans are inert.
        {
            let _s = span("obs_unit_disabled", "test");
        }
        start();
        {
            let _s = span("obs_unit_span", "test");
        }
        record_since("obs_unit_since", "test", now_us().saturating_sub(5));
        stop();
        let lanes = dump();
        let mine: Vec<&Event> = lanes
            .iter()
            .flat_map(|l| &l.events)
            .filter(|e| e.name.starts_with("obs_unit"))
            .collect();
        assert!(mine.iter().any(|e| e.name == "obs_unit_span"));
        assert!(mine.iter().any(|e| e.name == "obs_unit_since"));
        assert!(
            !mine.iter().any(|e| e.name == "obs_unit_disabled"),
            "a span opened while disabled must not be recorded"
        );
        // Spans opened after stop() record nothing.
        {
            let _s = span("obs_unit_after_stop", "test");
        }
        let after = dump();
        assert!(!after.iter().flat_map(|l| &l.events).any(|e| e.name == "obs_unit_after_stop"));
    }
}
