//! The **wide kernel tier**: explicitly vectorized (f64×4 via
//! `std::arch`) and cache-blocked implementations of the fused kernels,
//! behind a runtime-detected dispatch.
//!
//! Tier structure (normative reference: docs/KERNELS.md):
//!
//! * [`Dispatch::Scalar`] — the pinned-FP-order reference tier
//!   ([`crate::linalg::scalar`]). Never removed; every other tier is
//!   gated on producing **bit-identical** results to it.
//! * [`Dispatch::Portable`] — cache-blocked loops restructured into
//!   per-term streaming passes that LLVM autovectorizes to the widest
//!   lanes the build target allows (f64×8 under `-C target-cpu=native`
//!   on an AVX-512 host). Same per-element operation sequence as
//!   scalar, so bit-identical by construction.
//! * [`Dispatch::Avx2`] — `std::arch::x86_64` 4-lane `f64` kernels
//!   (256-bit loads, separate multiply and add — **never** a fused
//!   multiply-add, which would change the rounding) selected when the
//!   host supports AVX2: at compile time under `-C target-cpu`, by
//!   runtime CPUID detection otherwise. Each SIMD lane executes exactly
//!   the scalar per-element operation sequence, so this tier is also
//!   bit-identical to the reference.
//!
//! The only kernel that is *not* bit-identical across tiers is the
//! explicitly opt-in reduction [`dot_relaxed`], which reassociates the
//! accumulation into per-lane partial sums. It is the **tolerance
//! lane**: call sites choose it by name, never through the transparent
//! dispatch, and its error bound is documented on the function. Nothing
//! on a bit-identity path (steppers, `run_reference`, snapshot
//! fixtures) uses it.
//!
//! Dispatch is resolved once per process ([`dispatch`]) and cached; the
//! `SADIFF_SIMD` environment variable (`scalar` | `portable` | `avx2` |
//! `auto`) overrides detection for A/B testing and for forcing the
//! reference tier in benchmarks. The first call reads the environment
//! (which may allocate), so [`crate::solvers::stepper::make_stepper`]
//! warms the cache at construction time — keeping the per-step path's
//! zero-allocation contract intact.

use crate::linalg::scalar;
use std::sync::OnceLock;

/// Elements per cache block: every per-term pass re-reads the output
/// tile while it is still resident in L1 (16 KiB per `f64` tile, half a
/// typical 32 KiB L1d, leaving room for the streaming history operand).
pub const BLOCK: usize = 2048;

/// Lane width of the portable tier's reduction ([`dot_relaxed`]) —
/// f64×8: one AVX-512 vector, two AVX vectors, or four NEON vectors.
pub const PORTABLE_WIDTH: usize = 8;

/// Which kernel tier the transparent entry points in [`crate::linalg`]
/// route to. All variants produce bit-identical results for the fused
/// kernels; they differ only in speed ([`dot_relaxed`] is the lone,
/// opt-in exception).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// The pinned-FP-order reference tier ([`crate::linalg::scalar`]).
    Scalar,
    /// Cache-blocked autovectorizable streaming passes (any target).
    Portable,
    /// Explicit 4-lane `f64` kernels via `std::arch` (x86_64 + AVX2).
    Avx2,
}

impl Dispatch {
    /// Stable lowercase name, used in logs and `BENCH_perf.json`
    /// (`"scalar"` / `"portable"` / `"avx2"`).
    pub fn label(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Portable => "portable",
            Dispatch::Avx2 => "avx2",
        }
    }

    /// Whether this tier can run on the current host. `Scalar` and
    /// `Portable` always can; `Avx2` requires an x86_64 host with AVX2
    /// (compile-time enabled or CPUID-detected).
    pub fn available(self) -> bool {
        match self {
            Dispatch::Scalar | Dispatch::Portable => true,
            Dispatch::Avx2 => avx2_available(),
        }
    }

    /// Every tier that can run on this host, reference tier first.
    /// Tests sweep this to assert cross-tier bit-identity.
    pub fn all_available() -> Vec<Dispatch> {
        let mut tiers = vec![Dispatch::Scalar, Dispatch::Portable];
        if Dispatch::Avx2.available() {
            tiers.push(Dispatch::Avx2);
        }
        tiers
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    cfg!(target_feature = "avx2") || is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// (tier, how it was selected, why the wide tier was skipped, if it was).
static DISPATCH: OnceLock<(Dispatch, &'static str, Option<&'static str>)> = OnceLock::new();

fn resolve() -> (Dispatch, &'static str, Option<&'static str>) {
    if let Ok(forced) = std::env::var("SADIFF_SIMD") {
        match forced.as_str() {
            "scalar" => {
                return (Dispatch::Scalar, "env", Some("SADIFF_SIMD forced the reference tier"));
            }
            "portable" => return (Dispatch::Portable, "env", None),
            "avx2" => {
                if avx2_available() {
                    return (Dispatch::Avx2, "env", None);
                }
                return (Dispatch::Portable, "env", Some("SADIFF_SIMD=avx2 but host lacks AVX2"));
            }
            // Anything else (including "auto") falls through to detection.
            _ => {}
        }
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> (Dispatch, &'static str, Option<&'static str>) {
    if cfg!(target_feature = "avx2") {
        (Dispatch::Avx2, "compile-time", None)
    } else if is_x86_feature_detected!("avx2") {
        (Dispatch::Avx2, "runtime", None)
    } else {
        (Dispatch::Portable, "runtime", Some("x86_64 host without AVX2"))
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_arch() -> (Dispatch, &'static str, Option<&'static str>) {
    (Dispatch::Portable, "compile-time", Some("no std::arch wide tier for this target arch"))
}

/// The tier the transparent [`crate::linalg`] entry points route to on
/// this host, resolved once and cached for the process lifetime.
///
/// Selection order: the `SADIFF_SIMD` environment variable if set to a
/// tier name, else compile-time `target_feature` (a `-C
/// target-cpu=native` build dispatches statically), else runtime CPUID
/// detection, else the portable tier. The returned tier is always
/// [`Dispatch::available`].
///
/// ```
/// use sadiff::linalg::simd::{dispatch, Dispatch};
/// let d = dispatch();
/// assert!(d.available());
/// assert!(["scalar", "portable", "avx2"].contains(&d.label()));
/// assert!(Dispatch::all_available().contains(&d));
/// ```
pub fn dispatch() -> Dispatch {
    DISPATCH.get_or_init(resolve).0
}

/// How [`dispatch`] was decided: `"env"`, `"compile-time"` or
/// `"runtime"`. Logged into `BENCH_perf.json` so CI can prove the
/// selection was recorded, not silently defaulted.
pub fn dispatch_source() -> &'static str {
    DISPATCH.get_or_init(resolve).1
}

/// Why the widest tier was *not* selected, when it wasn't (e.g.
/// `"x86_64 host without AVX2"`). `None` when the AVX2 tier is active
/// or the portable tier was explicitly requested. CI fails the
/// kernel-bench lane if the dispatch fell back to a narrower tier
/// without this reason being logged.
pub fn fallback_reason() -> Option<&'static str> {
    DISPATCH.get_or_init(resolve).2
}

/// `y[k] += alpha · x[k]` on an explicit tier. Panics if `d` is not
/// [`Dispatch::available`] or on length mismatch.
pub fn axpy_into_with(d: Dispatch, alpha: f64, x: &[f64], y: &mut [f64]) {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(x.len(), y.len(), "axpy_into: length mismatch");
    match d {
        // The scalar form is already the optimal streaming shape for
        // the autovectorizer; the portable tier adds nothing here.
        Dispatch::Scalar | Dispatch::Portable => scalar::axpy_into(alpha, x, y),
        Dispatch::Avx2 => avx2_call::axpy_into(alpha, x, y),
    }
}

/// `out[k] = a[k] − b[k]` on an explicit tier. Panics if `d` is not
/// [`Dispatch::available`] or on length mismatch.
pub fn sub_into_with(d: Dispatch, a: &[f64], b: &[f64], out: &mut [f64]) {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(a.len(), b.len(), "sub_into: length mismatch");
    assert_eq!(a.len(), out.len(), "sub_into: length mismatch");
    match d {
        Dispatch::Scalar | Dispatch::Portable => scalar::sub_into(a, b, out),
        Dispatch::Avx2 => avx2_call::sub_into(a, b, out),
    }
}

/// `y[k] = a · y[k] + b · x[k]` on an explicit tier. Panics if `d` is
/// not [`Dispatch::available`] or on length mismatch.
pub fn scale_add_with(d: Dispatch, y: &mut [f64], a: f64, b: f64, x: &[f64]) {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(x.len(), y.len(), "scale_add: length mismatch");
    match d {
        Dispatch::Scalar | Dispatch::Portable => scalar::scale_add(y, a, b, x),
        Dispatch::Avx2 => avx2_call::scale_add(y, a, b, x),
    }
}

/// `x[k] += sigma · xi[k]` on an explicit tier. Panics if `d` is not
/// [`Dispatch::available`] or on length mismatch.
pub fn fma_noise_with(d: Dispatch, x: &mut [f64], sigma: f64, xi: &[f64]) {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(x.len(), xi.len(), "fma_noise: length mismatch");
    match d {
        Dispatch::Scalar | Dispatch::Portable => scalar::fma_noise(x, sigma, xi),
        Dispatch::Avx2 => avx2_call::fma_noise(x, sigma, xi),
    }
}

/// The fused stochastic-Adams combination
/// (`out[k] = c0·x[k] [+ σ·ξ[k]] + Σ_j b[j]·hist[offsets[j]+k]`) on an
/// explicit tier, bit-identical to
/// [`crate::linalg::scalar::lincomb_into`] on every tier. Panics if
/// `d` is not [`Dispatch::available`] or a precondition fails.
///
/// ```
/// use sadiff::linalg::{scalar, simd};
/// let hist = [1.0, 1.0, 10.0, 10.0]; // two slots of length 2
/// let x = [4.0, 8.0];
/// let (b, offs) = ([2.0, 3.0], [0usize, 2]);
/// let mut want = [0.0; 2];
/// scalar::lincomb_into(0.5, &x, None, &b, &hist, &offs, &mut want);
/// for d in simd::Dispatch::all_available() {
///     let mut got = [0.0; 2];
///     simd::lincomb_into_with(d, 0.5, &x, None, &b, &hist, &offs, &mut got);
///     assert_eq!(got, want, "tier {} must be bit-identical", d.label());
/// }
/// ```
#[allow(clippy::too_many_arguments)]
pub fn lincomb_into_with(
    d: Dispatch,
    c0: f64,
    x: &[f64],
    noise: Option<(f64, &[f64])>,
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(b.len(), offsets.len(), "lincomb_into: coefficient / offset mismatch");
    assert_eq!(x.len(), out.len(), "lincomb_into: length mismatch");
    if let Some((_, xi)) = noise {
        assert_eq!(xi.len(), out.len(), "lincomb_into: noise length mismatch");
    }
    for &o in offsets {
        assert!(o + out.len() <= hist.len(), "lincomb_into: history offset out of bounds");
    }
    match d {
        Dispatch::Scalar => scalar::lincomb_into(c0, x, noise, b, hist, offsets, out),
        Dispatch::Portable => portable::lincomb_into(c0, x, noise, b, hist, offsets, out),
        Dispatch::Avx2 => avx2_call::lincomb_into(c0, x, noise, b, hist, offsets, out),
    }
}

/// In-place fused combination
/// (`x[k] = c0·x[k] + Σ_j b[j]·hist[offsets[j]+k]`) on an explicit
/// tier, bit-identical to [`crate::linalg::scalar::lincomb_inplace`]
/// on every tier. Panics if `d` is not [`Dispatch::available`] or a
/// precondition fails.
pub fn lincomb_inplace_with(
    d: Dispatch,
    c0: f64,
    x: &mut [f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
) {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(b.len(), offsets.len(), "lincomb_inplace: coefficient / offset mismatch");
    for &o in offsets {
        assert!(o + x.len() <= hist.len(), "lincomb_inplace: history offset out of bounds");
    }
    match d {
        Dispatch::Scalar => scalar::lincomb_inplace(c0, x, b, hist, offsets),
        Dispatch::Portable => portable::lincomb_inplace(c0, x, b, hist, offsets),
        Dispatch::Avx2 => avx2_call::lincomb_inplace(c0, x, b, hist, offsets),
    }
}

/// **Tolerance-lane** dot product `Σ_k a[k] · b[k]` — the one wide
/// kernel that is *not* bit-identical to the reference tier.
///
/// The wide tiers accumulate into per-lane partial sums (4 lanes on
/// AVX2, [`PORTABLE_WIDTH`] on the portable tier) and combine them in
/// a fixed order, so the result is deterministic *per tier* but
/// differs from the sequential left-to-right sum of
/// [`crate::linalg::scalar::dot`] by reassociation error only. The
/// standard bound covers both orderings: for `n`-element inputs,
///
/// `|dot_relaxed(a, b) − dot(a, b)| ≤ 2 · γ(n) · Σ_k |a[k]·b[k]|`
/// with `γ(n) = n·ε / (1 − n·ε)`, `ε = 2⁻⁵³`
///
/// — a relative error (w.r.t. `Σ|a·b|`) below `1e-9` for any
/// `n ≤ 2²⁰`, and far smaller in practice. Call sites that feed a
/// bit-identity contract must use [`crate::linalg::dot`]; this lane is
/// for throughput-bound reductions that tolerate the bound above, and
/// is selected **by name at the call site**, never by the transparent
/// dispatch.
///
/// ```
/// use sadiff::linalg::{scalar, simd};
/// let a: Vec<f64> = (0..1000).map(|k| (k as f64 * 0.37).sin()).collect();
/// let b: Vec<f64> = (0..1000).map(|k| (k as f64 * 0.11).cos()).collect();
/// let exact = scalar::dot(&a, &b);
/// let relaxed = simd::dot_relaxed(&a, &b);
/// let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
/// assert!((relaxed - exact).abs() <= 1e-12 * scale.max(1.0));
/// ```
pub fn dot_relaxed(a: &[f64], b: &[f64]) -> f64 {
    dot_relaxed_with(dispatch(), a, b)
}

/// [`dot_relaxed`] on an explicit tier ([`Dispatch::Scalar`] gives the
/// exact sequential sum). Panics if `d` is not [`Dispatch::available`]
/// or on length mismatch.
pub fn dot_relaxed_with(d: Dispatch, a: &[f64], b: &[f64]) -> f64 {
    assert!(d.available(), "kernel tier {} unavailable on this host", d.label());
    assert_eq!(a.len(), b.len(), "dot_relaxed: length mismatch");
    match d {
        Dispatch::Scalar => scalar::dot(a, b),
        Dispatch::Portable => portable::dot_relaxed(a, b),
        Dispatch::Avx2 => avx2_call::dot_relaxed(a, b),
    }
}

/// Portable wide tier: the fused combination restructured into
/// cache-blocked per-term streaming passes. Each pass is a two-operand
/// unit-stride loop with no cross-iteration dependency — the shape LLVM
/// reliably autovectorizes — while the per-element operation sequence
/// (`c0·x`, noise, history terms in ascending `j`) is exactly the
/// scalar reference order, so results are bit-identical.
mod portable {
    use super::{BLOCK, PORTABLE_WIDTH};

    pub(super) fn lincomb_into(
        c0: f64,
        x: &[f64],
        noise: Option<(f64, &[f64])>,
        b: &[f64],
        hist: &[f64],
        offsets: &[usize],
        out: &mut [f64],
    ) {
        let n = out.len();
        let mut base = 0usize;
        while base < n {
            let end = (base + BLOCK).min(n);
            // Pass 1: out ← c0·x (+ σ·ξ) over the block.
            match noise {
                Some((sigma, xi)) => {
                    for k in base..end {
                        out[k] = c0 * x[k] + sigma * xi[k];
                    }
                }
                None => {
                    for k in base..end {
                        out[k] = c0 * x[k];
                    }
                }
            }
            // One streaming pass per history term; the out tile stays
            // in L1 across all of them. Ascending j preserves the
            // pinned per-element accumulation order.
            for (bj, oj) in b.iter().zip(offsets) {
                let h = &hist[oj + base..oj + end];
                let o = &mut out[base..end];
                for (ok, hk) in o.iter_mut().zip(h) {
                    *ok += bj * hk;
                }
            }
            base = end;
        }
    }

    pub(super) fn lincomb_inplace(
        c0: f64,
        x: &mut [f64],
        b: &[f64],
        hist: &[f64],
        offsets: &[usize],
    ) {
        let n = x.len();
        let mut base = 0usize;
        while base < n {
            let end = (base + BLOCK).min(n);
            // x[k]'s original value only feeds the c0·x term, so
            // scaling the block first is exact.
            for k in base..end {
                x[k] *= c0;
            }
            for (bj, oj) in b.iter().zip(offsets) {
                let h = &hist[oj + base..oj + end];
                let o = &mut x[base..end];
                for (ok, hk) in o.iter_mut().zip(h) {
                    *ok += bj * hk;
                }
            }
            base = end;
        }
    }

    /// Tolerance lane: `PORTABLE_WIDTH` interleaved partial sums, a
    /// left-to-right combine, then the tail terms in index order.
    pub(super) fn dot_relaxed(a: &[f64], b: &[f64]) -> f64 {
        let mut acc = [0.0f64; PORTABLE_WIDTH];
        let mut ca = a.chunks_exact(PORTABLE_WIDTH);
        let mut cb = b.chunks_exact(PORTABLE_WIDTH);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            for l in 0..PORTABLE_WIDTH {
                acc[l] += xa[l] * xb[l];
            }
        }
        let mut s = 0.0;
        for v in acc {
            s += v;
        }
        for (xa, xb) in ca.remainder().iter().zip(cb.remainder()) {
            s += xa * xb;
        }
        s
    }
}

/// Safe shims over the [`avx2`] kernels so the dispatch arms above stay
/// target-independent.
///
/// Invariant: these are only reached through a `Dispatch::Avx2` arm,
/// and every `_with` entry point asserts `Dispatch::available()` first
/// — so on x86_64 AVX2 is known present, and on other architectures the
/// arm is unreachable.
#[cfg(target_arch = "x86_64")]
mod avx2_call {
    use super::avx2;

    pub(super) fn axpy_into(alpha: f64, x: &[f64], y: &mut [f64]) {
        // SAFETY: AVX2 availability asserted by the `_with` caller.
        unsafe { avx2::axpy_into(alpha, x, y) }
    }
    pub(super) fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
        // SAFETY: as above.
        unsafe { avx2::sub_into(a, b, out) }
    }
    pub(super) fn scale_add(y: &mut [f64], a: f64, b: f64, x: &[f64]) {
        // SAFETY: as above.
        unsafe { avx2::scale_add(y, a, b, x) }
    }
    pub(super) fn fma_noise(x: &mut [f64], sigma: f64, xi: &[f64]) {
        // SAFETY: as above.
        unsafe { avx2::fma_noise(x, sigma, xi) }
    }
    #[allow(clippy::too_many_arguments)]
    pub(super) fn lincomb_into(
        c0: f64,
        x: &[f64],
        noise: Option<(f64, &[f64])>,
        b: &[f64],
        hist: &[f64],
        offsets: &[usize],
        out: &mut [f64],
    ) {
        // SAFETY: as above.
        unsafe { avx2::lincomb_into(c0, x, noise, b, hist, offsets, out) }
    }
    pub(super) fn lincomb_inplace(
        c0: f64,
        x: &mut [f64],
        b: &[f64],
        hist: &[f64],
        offsets: &[usize],
    ) {
        // SAFETY: as above.
        unsafe { avx2::lincomb_inplace(c0, x, b, hist, offsets) }
    }
    pub(super) fn dot_relaxed(a: &[f64], b: &[f64]) -> f64 {
        // SAFETY: as above.
        unsafe { avx2::dot_relaxed(a, b) }
    }
}

/// Unreachable stand-ins for non-x86_64 targets: `Dispatch::Avx2` is
/// never [`Dispatch::available`] there, and every entry point asserts
/// availability before matching.
#[cfg(not(target_arch = "x86_64"))]
mod avx2_call {
    pub(super) fn axpy_into(_: f64, _: &[f64], _: &mut [f64]) {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
    pub(super) fn sub_into(_: &[f64], _: &[f64], _: &mut [f64]) {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
    pub(super) fn scale_add(_: &mut [f64], _: f64, _: f64, _: &[f64]) {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
    pub(super) fn fma_noise(_: &mut [f64], _: f64, _: &[f64]) {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
    #[allow(clippy::too_many_arguments)]
    pub(super) fn lincomb_into(
        _: f64,
        _: &[f64],
        _: Option<(f64, &[f64])>,
        _: &[f64],
        _: &[f64],
        _: &[usize],
        _: &mut [f64],
    ) {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
    pub(super) fn lincomb_inplace(_: f64, _: &mut [f64], _: &[f64], _: &[f64], _: &[usize]) {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
    pub(super) fn dot_relaxed(_: &[f64], _: &[f64]) -> f64 {
        unreachable!("AVX2 tier on a non-x86_64 target");
    }
}

/// AVX 256-bit (f64×4) kernels. Every kernel uses separate
/// multiply/add/subtract intrinsics — never an FMA — so each SIMD lane
/// performs exactly the scalar per-element operation sequence and the
/// results are bit-identical to the reference tier; tails shorter than
/// one vector run the scalar loop. Gated on AVX2 by [`dispatch`].
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::BLOCK;
    use std::arch::x86_64::*;

    const LANES: usize = 4;

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_into(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = y.len();
        let va = _mm256_set1_pd(alpha);
        let mut k = 0usize;
        while k + LANES <= n {
            let vx = _mm256_loadu_pd(x.as_ptr().add(k));
            let vy = _mm256_loadu_pd(y.as_ptr().add(k));
            let r = _mm256_add_pd(vy, _mm256_mul_pd(va, vx));
            _mm256_storeu_pd(y.as_mut_ptr().add(k), r);
            k += LANES;
        }
        while k < n {
            y[k] += alpha * x[k];
            k += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
        let n = out.len();
        let mut k = 0usize;
        while k + LANES <= n {
            let va = _mm256_loadu_pd(a.as_ptr().add(k));
            let vb = _mm256_loadu_pd(b.as_ptr().add(k));
            _mm256_storeu_pd(out.as_mut_ptr().add(k), _mm256_sub_pd(va, vb));
            k += LANES;
        }
        while k < n {
            out[k] = a[k] - b[k];
            k += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_add(y: &mut [f64], a: f64, b: f64, x: &[f64]) {
        let n = y.len();
        let va = _mm256_set1_pd(a);
        let vb = _mm256_set1_pd(b);
        let mut k = 0usize;
        while k + LANES <= n {
            let vy = _mm256_loadu_pd(y.as_ptr().add(k));
            let vx = _mm256_loadu_pd(x.as_ptr().add(k));
            let r = _mm256_add_pd(_mm256_mul_pd(va, vy), _mm256_mul_pd(vb, vx));
            _mm256_storeu_pd(y.as_mut_ptr().add(k), r);
            k += LANES;
        }
        while k < n {
            y[k] = a * y[k] + b * x[k];
            k += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn fma_noise(x: &mut [f64], sigma: f64, xi: &[f64]) {
        let n = x.len();
        let vs = _mm256_set1_pd(sigma);
        let mut k = 0usize;
        while k + LANES <= n {
            let vx = _mm256_loadu_pd(x.as_ptr().add(k));
            let vz = _mm256_loadu_pd(xi.as_ptr().add(k));
            let r = _mm256_add_pd(vx, _mm256_mul_pd(vs, vz));
            _mm256_storeu_pd(x.as_mut_ptr().add(k), r);
            k += LANES;
        }
        while k < n {
            x[k] += sigma * xi[k];
            k += 1;
        }
    }

    /// Cache-blocked fused combination: pass 1 writes `c0·x (+ σ·ξ)`
    /// into the out block, then one 4-lane streaming pass per history
    /// term accumulates in ascending `j` — the scalar per-element
    /// order, with the out tile L1-resident across all `s + 1` passes.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn lincomb_into(
        c0: f64,
        x: &[f64],
        noise: Option<(f64, &[f64])>,
        b: &[f64],
        hist: &[f64],
        offsets: &[usize],
        out: &mut [f64],
    ) {
        let n = out.len();
        let vc0 = _mm256_set1_pd(c0);
        let mut base = 0usize;
        while base < n {
            let end = (base + BLOCK).min(n);
            match noise {
                Some((sigma, xi)) => {
                    let vs = _mm256_set1_pd(sigma);
                    let mut k = base;
                    while k + LANES <= end {
                        let vx = _mm256_loadu_pd(x.as_ptr().add(k));
                        let vz = _mm256_loadu_pd(xi.as_ptr().add(k));
                        let r = _mm256_add_pd(_mm256_mul_pd(vc0, vx), _mm256_mul_pd(vs, vz));
                        _mm256_storeu_pd(out.as_mut_ptr().add(k), r);
                        k += LANES;
                    }
                    while k < end {
                        out[k] = c0 * x[k] + sigma * xi[k];
                        k += 1;
                    }
                }
                None => {
                    let mut k = base;
                    while k + LANES <= end {
                        let vx = _mm256_loadu_pd(x.as_ptr().add(k));
                        _mm256_storeu_pd(out.as_mut_ptr().add(k), _mm256_mul_pd(vc0, vx));
                        k += LANES;
                    }
                    while k < end {
                        out[k] = c0 * x[k];
                        k += 1;
                    }
                }
            }
            for (bj, oj) in b.iter().zip(offsets) {
                let vb = _mm256_set1_pd(*bj);
                let h = hist.as_ptr().add(*oj);
                let mut k = base;
                while k + LANES <= end {
                    let vo = _mm256_loadu_pd(out.as_ptr().add(k));
                    let vh = _mm256_loadu_pd(h.add(k));
                    let r = _mm256_add_pd(vo, _mm256_mul_pd(vb, vh));
                    _mm256_storeu_pd(out.as_mut_ptr().add(k), r);
                    k += LANES;
                }
                while k < end {
                    out[k] += bj * hist[oj + k];
                    k += 1;
                }
            }
            base = end;
        }
    }

    /// In-place variant of [`lincomb_into`]: `x ← c0·x` over the
    /// block, then the history passes (same pinned order; `x[k]`'s
    /// original value only feeds the `c0·x` term, so overwriting it
    /// first is exact).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn lincomb_inplace(
        c0: f64,
        x: &mut [f64],
        b: &[f64],
        hist: &[f64],
        offsets: &[usize],
    ) {
        let n = x.len();
        let vc0 = _mm256_set1_pd(c0);
        let mut base = 0usize;
        while base < n {
            let end = (base + BLOCK).min(n);
            let mut k = base;
            while k + LANES <= end {
                let vx = _mm256_loadu_pd(x.as_ptr().add(k));
                _mm256_storeu_pd(x.as_mut_ptr().add(k), _mm256_mul_pd(vc0, vx));
                k += LANES;
            }
            while k < end {
                x[k] *= c0;
                k += 1;
            }
            for (bj, oj) in b.iter().zip(offsets) {
                let vb = _mm256_set1_pd(*bj);
                let h = hist.as_ptr().add(*oj);
                let mut k = base;
                while k + LANES <= end {
                    let vx = _mm256_loadu_pd(x.as_ptr().add(k));
                    let vh = _mm256_loadu_pd(h.add(k));
                    let r = _mm256_add_pd(vx, _mm256_mul_pd(vb, vh));
                    _mm256_storeu_pd(x.as_mut_ptr().add(k), r);
                    k += LANES;
                }
                while k < end {
                    x[k] += bj * hist[oj + k];
                    k += 1;
                }
            }
            base = end;
        }
    }

    /// Tolerance lane: one 4-lane accumulator vector, combined
    /// `(l0 + l1) + (l2 + l3)`, then the tail terms in index order.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_relaxed(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let mut acc = _mm256_setzero_pd();
        let mut k = 0usize;
        while k + LANES <= n {
            let va = _mm256_loadu_pd(a.as_ptr().add(k));
            let vb = _mm256_loadu_pd(b.as_ptr().add(k));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
            k += LANES;
        }
        let mut lanes = [0.0f64; LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        while k < n {
            s += a[k] * b[k];
            k += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(n: usize, mul: f64) -> Vec<f64> {
        (0..n).map(|k| (k as f64 * mul).sin() + 0.1).collect()
    }

    #[test]
    fn dispatch_is_cached_and_available() {
        let d = dispatch();
        assert!(d.available());
        assert_eq!(d, dispatch(), "dispatch must be stable across calls");
        assert!(!dispatch_source().is_empty());
        // A non-wide selection must never be silent: either the widest
        // tier is active or a fallback reason is recorded (the CI lane
        // enforces the same rule on the emitted BENCH_perf.json).
        if d != Dispatch::Avx2 && std::env::var("SADIFF_SIMD").is_err() {
            assert!(fallback_reason().is_some(), "narrow dispatch without a logged reason");
        }
    }

    #[test]
    fn every_available_tier_matches_scalar_bitwise() {
        // Unit-scope smoke (integration_simd runs the full sweep): odd
        // lengths exercise the tails, s spans the monomorphized and
        // dynamic reference arms.
        for n in [1usize, 3, 5, 17, 100] {
            let x = probe(n, 0.37);
            let xi = probe(n, 0.71);
            for s in [1usize, 2, 4, 5] {
                let hist = probe((s + 1) * n, 0.13);
                let offsets: Vec<usize> = (0..s).map(|j| j * n).collect();
                let b: Vec<f64> = (0..s).map(|j| 0.3 - 0.2 * j as f64).collect();
                let noise = Some((0.2, &xi[..]));
                let mut want = vec![0.0; n];
                scalar::lincomb_into(0.9, &x, noise, &b, &hist, &offsets, &mut want);
                for d in Dispatch::all_available() {
                    let mut got = vec![0.0; n];
                    lincomb_into_with(d, 0.9, &x, noise, &b, &hist, &offsets, &mut got);
                    assert_eq!(got, want, "lincomb_into n={n} s={s} tier={}", d.label());

                    let mut gi = x.clone();
                    lincomb_inplace_with(d, 0.9, &mut gi, &b, &hist, &offsets);
                    let mut wi = x.clone();
                    scalar::lincomb_inplace(0.9, &mut wi, &b, &hist, &offsets);
                    assert_eq!(gi, wi, "lincomb_inplace n={n} s={s} tier={}", d.label());
                }
            }
        }
    }

    #[test]
    fn dot_relaxed_is_within_the_documented_bound() {
        for n in [1usize, 4, 7, 64, 1000, 4099] {
            let a = probe(n, 0.37);
            let b = probe(n, 0.11);
            let exact = scalar::dot(&a, &b);
            let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            for d in Dispatch::all_available() {
                let relaxed = dot_relaxed_with(d, &a, &b);
                assert!(
                    (relaxed - exact).abs() <= 1e-12 * scale.max(1.0),
                    "dot_relaxed n={n} tier={}: {relaxed} vs {exact}",
                    d.label()
                );
            }
        }
    }
}
