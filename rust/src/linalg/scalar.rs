//! The **scalar reference tier** of the fused-kernel layer: the pinned
//! floating-point-evaluation-order implementations every other tier is
//! measured against.
//!
//! These are the normative kernels (docs/KERNELS.md): for each element
//! `k`, the exact sequence of IEEE-754 operations — multiplies, adds, and
//! their association — is part of the public contract, because the
//! system's bit-identity suites (stepper ≡ reference, snapshot goldens,
//! threads ≡ sequential) pin the exact `f64` results. A wide tier
//! ([`crate::linalg::simd`]) may only replace a scalar kernel if it
//! performs the *same per-element operation sequence* — lane-parallel
//! across elements, never reassociated within one — or if the call site
//! explicitly opts into the documented tolerance lane
//! ([`crate::linalg::simd::dot_relaxed`]).
//!
//! Call these directly to force the reference tier regardless of what the
//! runtime dispatch selected (tests and the roofline microbench do); the
//! public entry points in [`crate::linalg`] dispatch automatically.

/// Scalar reference `y[k] += alpha · x[k]`.
///
/// Per-element order: one multiply, one add, in index order.
pub fn axpy_into(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Scalar reference `out[k] = a[k] − b[k]`.
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Scalar reference fused scale-and-accumulate:
/// `y[k] = a · y[k] + b · x[k]`.
///
/// Per-element order: `a·y`, then `b·x`, then their sum (left to right, no
/// fused multiply-add).
pub fn scale_add(y: &mut [f64], a: f64, b: f64, x: &[f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * *yi + b * xi;
    }
}

/// Scalar reference stochastic-term update `x[k] += sigma · xi[k]`.
pub fn fma_noise(x: &mut [f64], sigma: f64, xi: &[f64]) {
    debug_assert_eq!(x.len(), xi.len());
    for (v, z) in x.iter_mut().zip(xi) {
        *v += sigma * z;
    }
}

/// Scalar reference left-to-right dot product `Σ_k a[k] · b[k]`.
///
/// The accumulation order is a single accumulator in index order — the
/// sequential sum every tolerance bound in the wide tier is stated
/// against.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Scalar reference fused stochastic-Adams combination:
///
/// `out[k] = c0 · x[k]  [+ sigma · xi[k]]  + Σ_j b[j] · hist[offsets[j] + k]`
///
/// Pinned per-element order: `c0·x[k]`, then the noise term when present,
/// then the history terms in ascending `j` — each as a separate multiply
/// and add (no reassociation, no fused multiply-add). Preconditions as on
/// [`crate::linalg::lincomb_into`].
#[allow(clippy::too_many_arguments)]
pub fn lincomb_into(
    c0: f64,
    x: &[f64],
    noise: Option<(f64, &[f64])>,
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    debug_assert_eq!(b.len(), offsets.len());
    debug_assert_eq!(x.len(), out.len());
    match noise {
        Some((sigma, xi)) => {
            debug_assert_eq!(xi.len(), out.len());
            match b.len() {
                1 => noise_pass::<1>(c0, x, sigma, xi, b, hist, offsets, out),
                2 => noise_pass::<2>(c0, x, sigma, xi, b, hist, offsets, out),
                3 => noise_pass::<3>(c0, x, sigma, xi, b, hist, offsets, out),
                4 => noise_pass::<4>(c0, x, sigma, xi, b, hist, offsets, out),
                _ => noise_pass_dyn(c0, x, sigma, xi, b, hist, offsets, out),
            }
        }
        None => match b.len() {
            1 => ode_pass::<1>(c0, x, b, hist, offsets, out),
            2 => ode_pass::<2>(c0, x, b, hist, offsets, out),
            3 => ode_pass::<3>(c0, x, b, hist, offsets, out),
            4 => ode_pass::<4>(c0, x, b, hist, offsets, out),
            _ => ode_pass_dyn(c0, x, b, hist, offsets, out),
        },
    }
}

/// Scalar reference in-place combination
/// `x[k] = c0 · x[k] + Σ_j b[j] · hist[offsets[j] + k]` (same pinned order
/// as [`lincomb_into`]; `x[k]` is read exactly once before it is written).
pub fn lincomb_inplace(c0: f64, x: &mut [f64], b: &[f64], hist: &[f64], offsets: &[usize]) {
    debug_assert_eq!(b.len(), offsets.len());
    match b.len() {
        1 => inplace_pass::<1>(c0, x, b, hist, offsets),
        2 => inplace_pass::<2>(c0, x, b, hist, offsets),
        3 => inplace_pass::<3>(c0, x, b, hist, offsets),
        4 => inplace_pass::<4>(c0, x, b, hist, offsets),
        _ => inplace_pass_dyn(c0, x, b, hist, offsets),
    }
}

/// Monomorphized fused pass with the noise term, for the common small
/// orders (lets the compiler unroll the history loop).
#[allow(clippy::too_many_arguments)]
fn noise_pass<const S: usize>(
    c0: f64,
    x: &[f64],
    sigma: f64,
    xi: &[f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    let mut bb = [0.0f64; S];
    bb.copy_from_slice(&b[..S]);
    let mut off = [0usize; S];
    off.copy_from_slice(&offsets[..S]);
    for k in 0..out.len() {
        let mut acc = c0 * x[k] + sigma * xi[k];
        for j in 0..S {
            acc += bb[j] * hist[off[j] + k];
        }
        out[k] = acc;
    }
}

#[allow(clippy::too_many_arguments)]
fn noise_pass_dyn(
    c0: f64,
    x: &[f64],
    sigma: f64,
    xi: &[f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    for k in 0..out.len() {
        let mut acc = c0 * x[k] + sigma * xi[k];
        for (bj, oj) in b.iter().zip(offsets) {
            acc += bj * hist[oj + k];
        }
        out[k] = acc;
    }
}

/// Monomorphized fused pass without a noise term.
fn ode_pass<const S: usize>(
    c0: f64,
    x: &[f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    let mut bb = [0.0f64; S];
    bb.copy_from_slice(&b[..S]);
    let mut off = [0usize; S];
    off.copy_from_slice(&offsets[..S]);
    for k in 0..out.len() {
        let mut acc = c0 * x[k];
        for j in 0..S {
            acc += bb[j] * hist[off[j] + k];
        }
        out[k] = acc;
    }
}

fn ode_pass_dyn(c0: f64, x: &[f64], b: &[f64], hist: &[f64], offsets: &[usize], out: &mut [f64]) {
    for k in 0..out.len() {
        let mut acc = c0 * x[k];
        for (bj, oj) in b.iter().zip(offsets) {
            acc += bj * hist[oj + k];
        }
        out[k] = acc;
    }
}

fn inplace_pass<const S: usize>(
    c0: f64,
    x: &mut [f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
) {
    let mut bb = [0.0f64; S];
    bb.copy_from_slice(&b[..S]);
    let mut off = [0usize; S];
    off.copy_from_slice(&offsets[..S]);
    for k in 0..x.len() {
        let mut acc = c0 * x[k];
        for j in 0..S {
            acc += bb[j] * hist[off[j] + k];
        }
        x[k] = acc;
    }
}

fn inplace_pass_dyn(c0: f64, x: &mut [f64], b: &[f64], hist: &[f64], offsets: &[usize]) {
    for k in 0..x.len() {
        let mut acc = c0 * x[k];
        for (bj, oj) in b.iter().zip(offsets) {
            acc += bj * hist[oj + k];
        }
        x[k] = acc;
    }
}
