//! Dense linear algebra substrate: small symmetric problems only (metric
//! computation needs Fréchet distances over d ≤ ~128 covariance matrices).
//!
//! Row-major `Mat` with Cholesky, a cyclic Jacobi symmetric eigensolver and
//! the PSD matrix square root built from it. No external BLAS — sizes are
//! tiny and exactness of tests matters more than throughput here.

pub mod mat;

pub use mat::Mat;

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared Euclidean norm.
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Elementwise `out = a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn vector_ops() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert!(close(dot(&a, &b), 32.0, 1e-15, 0.0));
        assert!(close(norm2(&a), 14f64.sqrt(), 1e-15, 0.0));
        let mut y = b.to_vec();
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
        assert_eq!(sub(&b, &a), vec![3.0, 3.0, 3.0]);
    }
}
