//! Dense linear algebra substrate, in two tiers:
//!
//! * **Small symmetric problems** — row-major [`Mat`] with Cholesky, a
//!   cyclic Jacobi symmetric eigensolver and the PSD matrix square root
//!   (metric computation needs Fréchet distances over d ≤ ~128 covariance
//!   matrices). No external BLAS — sizes are tiny and exactness of tests
//!   matters more than throughput here.
//! * **In-place fused kernels for the solver hot path** — [`axpy_into`],
//!   [`sub_into`], [`scale_add`], [`fma_noise`], and the history-buffer
//!   combination kernels [`lincomb_into`] / [`lincomb_inplace`] that the
//!   stochastic Adams steppers are built on, plus the [`Scratch`] arena
//!   that lets a stepper run with **zero heap allocations per step** after
//!   its `init` (asserted by a counting-allocator test).
//!
//! All hot-path kernels operate on caller-provided slices and never
//! allocate. Aliasing preconditions are the ones Rust's borrow rules
//! enforce: output slices are exclusive borrows, so they cannot overlap
//! any input. The only extra precondition is on the history kernels:
//! every `offsets[j] + out.len()` must be in bounds for `hist` (the
//! kernels index `hist[offsets[j] + k]` for `k < out.len()`).

pub mod mat;
pub mod scratch;

pub use mat::Mat;
pub use scratch::Scratch;

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared Euclidean norm.
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// `y[k] += alpha · x[k]`, in place on a caller-provided output slice.
pub fn axpy_into(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y += alpha · x` — alias retained for existing callers; the canonical
/// name is [`axpy_into`].
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_into(alpha, x, y);
}

/// Elementwise `out[k] = a[k] − b[k]`, in place on a caller-provided
/// output slice.
///
/// ```
/// let mut out = [0.0; 3];
/// sadiff::linalg::sub_into(&[4.0, 5.0, 6.0], &[1.0, 2.0, 3.0], &mut out);
/// assert_eq!(out, [3.0, 3.0, 3.0]);
/// ```
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Elementwise `a − b` into a fresh `Vec`.
///
/// Thin wrapper over [`sub_into`] kept for tests and one-off call sites;
/// anything on a per-step path must use [`sub_into`] with a reused buffer
/// instead (this function allocates on every call).
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.len()];
    sub_into(a, b, &mut out);
    out
}

/// Fused scale-and-accumulate: `y[k] = a · y[k] + b · x[k]` in a single
/// pass (one read and one write of `y`, one read of `x`).
pub fn scale_add(y: &mut [f64], a: f64, b: f64, x: &[f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * *yi + b * xi;
    }
}

/// Stochastic-term update: `x[k] += sigma · xi[k]` — the `σ̃ ξ` injection
/// of an SDE step applied to an already-computed deterministic part.
///
/// The in-tree steppers fuse their noise term into a single-pass update
/// ([`lincomb_into`]'s `noise` parameter, or a bespoke fused loop) rather
/// than paying a second sweep; this kernel is for compositions that
/// already have the deterministic part in place.
pub fn fma_noise(x: &mut [f64], sigma: f64, xi: &[f64]) {
    debug_assert_eq!(x.len(), xi.len());
    for (v, z) in x.iter_mut().zip(xi) {
        *v += sigma * z;
    }
}

/// The fused stochastic-Adams combination kernel:
///
/// `out[k] = c0 · x[k]  [+ sigma · xi[k]]  + Σ_j b[j] · hist[offsets[j] + k]`
///
/// in a **single pass** over the state — one read of each operand, one
/// write of `out`. This is the per-step update of SA-Solver's predictor
/// and corrector (Eqs. (14)/(17)) with the history buffers living in one
/// contiguous arena (`hist`) addressed by element offsets, so applying an
/// s-step combination costs no allocation and no gather indirection
/// beyond `s` base offsets. The multi-pass alternative costs `2 + s`
/// extra state-sized memory sweeps (bench_perf, §Perf).
///
/// The per-element evaluation order is fixed — `c0·x`, then the noise
/// term, then the history terms in `offsets` order — because downstream
/// bit-identity contracts (stepper ≡ reference, snapshot golden fixtures)
/// pin the exact floating-point result.
///
/// Preconditions: `b.len() == offsets.len()`, `x.len() == out.len()`
/// (likewise `xi` when present), and `offsets[j] + out.len() ≤
/// hist.len()` for every `j`.
///
/// ```
/// // out = 0.5·x + 2·h0 + 3·h1 over a 2-slot history arena.
/// let hist = [1.0, 1.0, 10.0, 10.0]; // two slots of length 2
/// let x = [4.0, 8.0];
/// let mut out = [0.0; 2];
/// sadiff::linalg::lincomb_into(0.5, &x, None, &[2.0, 3.0], &hist, &[0, 2], &mut out);
/// assert_eq!(out, [34.0, 36.0]);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn lincomb_into(
    c0: f64,
    x: &[f64],
    noise: Option<(f64, &[f64])>,
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    debug_assert_eq!(b.len(), offsets.len());
    debug_assert_eq!(x.len(), out.len());
    match noise {
        Some((sigma, xi)) => {
            debug_assert_eq!(xi.len(), out.len());
            match b.len() {
                1 => noise_pass::<1>(c0, x, sigma, xi, b, hist, offsets, out),
                2 => noise_pass::<2>(c0, x, sigma, xi, b, hist, offsets, out),
                3 => noise_pass::<3>(c0, x, sigma, xi, b, hist, offsets, out),
                4 => noise_pass::<4>(c0, x, sigma, xi, b, hist, offsets, out),
                _ => noise_pass_dyn(c0, x, sigma, xi, b, hist, offsets, out),
            }
        }
        None => match b.len() {
            1 => ode_pass::<1>(c0, x, b, hist, offsets, out),
            2 => ode_pass::<2>(c0, x, b, hist, offsets, out),
            3 => ode_pass::<3>(c0, x, b, hist, offsets, out),
            4 => ode_pass::<4>(c0, x, b, hist, offsets, out),
            _ => ode_pass_dyn(c0, x, b, hist, offsets, out),
        },
    }
}

/// In-place variant of [`lincomb_into`] without a noise term:
/// `x[k] = c0 · x[k] + Σ_j b[j] · hist[offsets[j] + k]`. Used by corrector
/// updates that overwrite the carried state directly (`x` is read exactly
/// once per element before it is written).
pub fn lincomb_inplace(c0: f64, x: &mut [f64], b: &[f64], hist: &[f64], offsets: &[usize]) {
    debug_assert_eq!(b.len(), offsets.len());
    match b.len() {
        1 => inplace_pass::<1>(c0, x, b, hist, offsets),
        2 => inplace_pass::<2>(c0, x, b, hist, offsets),
        3 => inplace_pass::<3>(c0, x, b, hist, offsets),
        4 => inplace_pass::<4>(c0, x, b, hist, offsets),
        _ => inplace_pass_dyn(c0, x, b, hist, offsets),
    }
}

/// Monomorphized fused pass with the noise term, for the common small
/// orders (lets the compiler unroll the history loop).
#[allow(clippy::too_many_arguments)]
fn noise_pass<const S: usize>(
    c0: f64,
    x: &[f64],
    sigma: f64,
    xi: &[f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    let mut bb = [0.0f64; S];
    bb.copy_from_slice(&b[..S]);
    let mut off = [0usize; S];
    off.copy_from_slice(&offsets[..S]);
    for k in 0..out.len() {
        let mut acc = c0 * x[k] + sigma * xi[k];
        for j in 0..S {
            acc += bb[j] * hist[off[j] + k];
        }
        out[k] = acc;
    }
}

#[allow(clippy::too_many_arguments)]
fn noise_pass_dyn(
    c0: f64,
    x: &[f64],
    sigma: f64,
    xi: &[f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    for k in 0..out.len() {
        let mut acc = c0 * x[k] + sigma * xi[k];
        for (bj, oj) in b.iter().zip(offsets) {
            acc += bj * hist[oj + k];
        }
        out[k] = acc;
    }
}

/// Monomorphized fused pass without a noise term.
fn ode_pass<const S: usize>(
    c0: f64,
    x: &[f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    let mut bb = [0.0f64; S];
    bb.copy_from_slice(&b[..S]);
    let mut off = [0usize; S];
    off.copy_from_slice(&offsets[..S]);
    for k in 0..out.len() {
        let mut acc = c0 * x[k];
        for j in 0..S {
            acc += bb[j] * hist[off[j] + k];
        }
        out[k] = acc;
    }
}

fn ode_pass_dyn(c0: f64, x: &[f64], b: &[f64], hist: &[f64], offsets: &[usize], out: &mut [f64]) {
    for k in 0..out.len() {
        let mut acc = c0 * x[k];
        for (bj, oj) in b.iter().zip(offsets) {
            acc += bj * hist[oj + k];
        }
        out[k] = acc;
    }
}

fn inplace_pass<const S: usize>(
    c0: f64,
    x: &mut [f64],
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
) {
    let mut bb = [0.0f64; S];
    bb.copy_from_slice(&b[..S]);
    let mut off = [0usize; S];
    off.copy_from_slice(&offsets[..S]);
    for k in 0..x.len() {
        let mut acc = c0 * x[k];
        for j in 0..S {
            acc += bb[j] * hist[off[j] + k];
        }
        x[k] = acc;
    }
}

fn inplace_pass_dyn(c0: f64, x: &mut [f64], b: &[f64], hist: &[f64], offsets: &[usize]) {
    for k in 0..x.len() {
        let mut acc = c0 * x[k];
        for (bj, oj) in b.iter().zip(offsets) {
            acc += bj * hist[oj + k];
        }
        x[k] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn vector_ops() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert!(close(dot(&a, &b), 32.0, 1e-15, 0.0));
        assert!(close(norm2(&a), 14f64.sqrt(), 1e-15, 0.0));
        let mut y = b.to_vec();
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
        assert_eq!(sub(&b, &a), vec![3.0, 3.0, 3.0]);
        let mut out = [0.0; 3];
        sub_into(&b, &a, &mut out);
        assert_eq!(out, [3.0, 3.0, 3.0]);
    }

    #[test]
    fn scale_add_and_fma_noise() {
        let mut y = [1.0, 2.0];
        scale_add(&mut y, 2.0, 3.0, &[10.0, 20.0]);
        assert_eq!(y, [32.0, 64.0]);
        let mut x = [1.0, 1.0];
        fma_noise(&mut x, 0.5, &[2.0, 4.0]);
        assert_eq!(x, [2.0, 3.0]);
    }

    #[test]
    fn lincomb_matches_reference_loops() {
        // A 3-entry history arena with an awkward slot order; compare the
        // fused kernels against a straightforward multi-pass evaluation,
        // bitwise, with and without the noise term, across the
        // monomorphized and dynamic dispatch arms.
        let n = 7usize;
        let hist: Vec<f64> = (0..5 * n).map(|k| (k as f64 * 0.37).sin()).collect();
        let x: Vec<f64> = (0..n).map(|k| (k as f64 * 0.11).cos()).collect();
        let xi: Vec<f64> = (0..n).map(|k| (k as f64 * 0.71).sin()).collect();
        for s in [1usize, 2, 3, 4, 5] {
            let offsets: Vec<usize> = (0..s).map(|j| ((j * 2 + 1) % 5) * n).collect();
            let b: Vec<f64> = (0..s).map(|j| 0.3 + j as f64).collect();
            let mut want = vec![0.0; n];
            for k in 0..n {
                let mut acc = 0.9 * x[k] + 0.2 * xi[k];
                for j in 0..s {
                    acc += b[j] * hist[offsets[j] + k];
                }
                want[k] = acc;
            }
            let mut got = vec![0.0; n];
            lincomb_into(0.9, &x, Some((0.2, &xi)), &b, &hist, &offsets, &mut got);
            assert_eq!(got, want, "s={s} with noise");

            let mut want_ode = vec![0.0; n];
            for k in 0..n {
                let mut acc = 0.9 * x[k];
                for j in 0..s {
                    acc += b[j] * hist[offsets[j] + k];
                }
                want_ode[k] = acc;
            }
            let mut got_ode = vec![0.0; n];
            lincomb_into(0.9, &x, None, &b, &hist, &offsets, &mut got_ode);
            assert_eq!(got_ode, want_ode, "s={s} ode");

            let mut got_inplace = x.clone();
            lincomb_inplace(0.9, &mut got_inplace, &b, &hist, &offsets);
            assert_eq!(got_inplace, want_ode, "s={s} inplace");
        }
    }

    #[test]
    fn lincomb_empty_history_is_scale_only() {
        let x = [2.0, -4.0];
        let mut out = [0.0; 2];
        lincomb_into(0.5, &x, None, &[], &[], &[], &mut out);
        assert_eq!(out, [1.0, -2.0]);
    }
}
