//! Dense linear algebra substrate, in two tiers:
//!
//! * **Small symmetric problems** — row-major [`Mat`] with Cholesky, a
//!   cyclic Jacobi symmetric eigensolver and the PSD matrix square root
//!   (metric computation needs Fréchet distances over d ≤ ~128 covariance
//!   matrices). No external BLAS — sizes are tiny and exactness of tests
//!   matters more than throughput here.
//! * **In-place fused kernels for the solver hot path** — [`axpy_into`],
//!   [`sub_into`], [`scale_add`], [`fma_noise`], and the history-buffer
//!   combination kernels [`lincomb_into`] / [`lincomb_inplace`] that the
//!   stochastic Adams steppers are built on, plus the [`Scratch`] arena
//!   that lets a stepper run with **zero heap allocations per step** after
//!   its `init` (asserted by a counting-allocator test).
//!
//! The hot-path kernels are themselves tiered (normative reference:
//! docs/KERNELS.md). The functions in this module are **transparent
//! dispatch entry points**: they route to the widest kernel tier the
//! host supports — explicit `std::arch` f64×4 SIMD on x86_64 with AVX2,
//! a cache-blocked portable wide tier elsewhere — as resolved once per
//! process by [`simd::dispatch`]. Every tier is **bit-identical** to
//! the pinned-FP-order reference implementations in [`scalar`] (the
//! wide tiers run the same per-element operation sequence, just
//! lane-parallel), so the system's bit-identity contracts are
//! unaffected by dispatch. The one deliberately non-identical kernel,
//! the reduction [`simd::dot_relaxed`], is opt-in by name at the call
//! site and never routed through these entry points.
//!
//! All hot-path kernels operate on caller-provided slices and never
//! allocate. Aliasing preconditions are the ones Rust's borrow rules
//! enforce: output slices are exclusive borrows, so they cannot overlap
//! any input. The only extra precondition is on the history kernels:
//! every `offsets[j] + out.len()` must be in bounds for `hist` (the
//! kernels index `hist[offsets[j] + k]` for `k < out.len()`).

pub mod mat;
pub mod scalar;
pub mod scratch;
pub mod simd;

pub use mat::Mat;
pub use scratch::Scratch;

/// Dot product, sequential left-to-right accumulation (the pinned
/// reference order; see [`simd::dot_relaxed`] for the opt-in tolerance
/// lane).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    scalar::dot(a, b)
}

/// Squared Euclidean norm.
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// `y[k] += alpha · x[k]`, in place on a caller-provided output slice.
/// Dispatches to the active kernel tier ([`simd::dispatch`]);
/// bit-identical to [`scalar::axpy_into`] on every tier.
pub fn axpy_into(alpha: f64, x: &[f64], y: &mut [f64]) {
    simd::axpy_into_with(simd::dispatch(), alpha, x, y);
}

/// `y += alpha · x` — alias retained for existing callers; the canonical
/// name is [`axpy_into`].
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_into(alpha, x, y);
}

/// Elementwise `out[k] = a[k] − b[k]`, in place on a caller-provided
/// output slice. Dispatches to the active kernel tier; bit-identical to
/// [`scalar::sub_into`] on every tier.
///
/// ```
/// let mut out = [0.0; 3];
/// sadiff::linalg::sub_into(&[4.0, 5.0, 6.0], &[1.0, 2.0, 3.0], &mut out);
/// assert_eq!(out, [3.0, 3.0, 3.0]);
/// ```
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    simd::sub_into_with(simd::dispatch(), a, b, out);
}

/// Elementwise `a − b` into a fresh `Vec`.
///
/// Thin wrapper over [`sub_into`] kept for tests and one-off call sites;
/// anything on a per-step path must use [`sub_into`] with a reused buffer
/// instead (this function allocates on every call).
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.len()];
    sub_into(a, b, &mut out);
    out
}

/// Fused scale-and-accumulate: `y[k] = a · y[k] + b · x[k]` in a single
/// pass (one read and one write of `y`, one read of `x`). Dispatches to
/// the active kernel tier; bit-identical to [`scalar::scale_add`] on
/// every tier.
pub fn scale_add(y: &mut [f64], a: f64, b: f64, x: &[f64]) {
    simd::scale_add_with(simd::dispatch(), y, a, b, x);
}

/// Stochastic-term update: `x[k] += sigma · xi[k]` — the `σ̃ ξ` injection
/// of an SDE step applied to an already-computed deterministic part.
/// Dispatches to the active kernel tier; bit-identical to
/// [`scalar::fma_noise`] on every tier.
///
/// The in-tree steppers fuse their noise term into a single-pass update
/// ([`lincomb_into`]'s `noise` parameter, or a bespoke fused loop) rather
/// than paying a second sweep; this kernel is for compositions that
/// already have the deterministic part in place.
pub fn fma_noise(x: &mut [f64], sigma: f64, xi: &[f64]) {
    simd::fma_noise_with(simd::dispatch(), x, sigma, xi);
}

/// The fused stochastic-Adams combination kernel:
///
/// `out[k] = c0 · x[k]  [+ sigma · xi[k]]  + Σ_j b[j] · hist[offsets[j] + k]`
///
/// with one read of each operand and one write of `out`. This is the
/// per-step update of SA-Solver's predictor and corrector (Eqs.
/// (14)/(17)) with the history buffers living in one contiguous arena
/// (`hist`) addressed by element offsets, so applying an s-step
/// combination costs no allocation and no gather indirection beyond `s`
/// base offsets. The multi-pass alternative costs `2 + s` extra
/// state-sized memory sweeps (bench_perf, §Perf; the wide tiers hide
/// exactly that cost behind L1-resident cache blocks — see
/// docs/KERNELS.md).
///
/// The per-element evaluation order is fixed — `c0·x`, then the noise
/// term, then the history terms in `offsets` order — because downstream
/// bit-identity contracts (stepper ≡ reference, snapshot golden fixtures)
/// pin the exact floating-point result. Dispatches to the active kernel
/// tier; bit-identical to [`scalar::lincomb_into`] on every tier.
///
/// Preconditions: `b.len() == offsets.len()`, `x.len() == out.len()`
/// (likewise `xi` when present), and `offsets[j] + out.len() ≤
/// hist.len()` for every `j`.
///
/// ```
/// // out = 0.5·x + 2·h0 + 3·h1 over a 2-slot history arena.
/// let hist = [1.0, 1.0, 10.0, 10.0]; // two slots of length 2
/// let x = [4.0, 8.0];
/// let mut out = [0.0; 2];
/// sadiff::linalg::lincomb_into(0.5, &x, None, &[2.0, 3.0], &hist, &[0, 2], &mut out);
/// assert_eq!(out, [34.0, 36.0]);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn lincomb_into(
    c0: f64,
    x: &[f64],
    noise: Option<(f64, &[f64])>,
    b: &[f64],
    hist: &[f64],
    offsets: &[usize],
    out: &mut [f64],
) {
    simd::lincomb_into_with(simd::dispatch(), c0, x, noise, b, hist, offsets, out);
}

/// In-place variant of [`lincomb_into`] without a noise term:
/// `x[k] = c0 · x[k] + Σ_j b[j] · hist[offsets[j] + k]`. Used by corrector
/// updates that overwrite the carried state directly (`x` is read exactly
/// once per element before it is written). Dispatches to the active
/// kernel tier; bit-identical to [`scalar::lincomb_inplace`] on every
/// tier.
pub fn lincomb_inplace(c0: f64, x: &mut [f64], b: &[f64], hist: &[f64], offsets: &[usize]) {
    simd::lincomb_inplace_with(simd::dispatch(), c0, x, b, hist, offsets);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn vector_ops() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert!(close(dot(&a, &b), 32.0, 1e-15, 0.0));
        assert!(close(norm2(&a), 14f64.sqrt(), 1e-15, 0.0));
        let mut y = b.to_vec();
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
        assert_eq!(sub(&b, &a), vec![3.0, 3.0, 3.0]);
        let mut out = [0.0; 3];
        sub_into(&b, &a, &mut out);
        assert_eq!(out, [3.0, 3.0, 3.0]);
    }

    #[test]
    fn scale_add_and_fma_noise() {
        let mut y = [1.0, 2.0];
        scale_add(&mut y, 2.0, 3.0, &[10.0, 20.0]);
        assert_eq!(y, [32.0, 64.0]);
        let mut x = [1.0, 1.0];
        fma_noise(&mut x, 0.5, &[2.0, 4.0]);
        assert_eq!(x, [2.0, 3.0]);
    }

    #[test]
    fn lincomb_matches_reference_loops() {
        // A 3-entry history arena with an awkward slot order; compare the
        // fused kernels (through whatever tier the dispatch selected)
        // against a straightforward multi-pass evaluation, bitwise, with
        // and without the noise term, across the monomorphized and
        // dynamic reference arms.
        let n = 7usize;
        let hist: Vec<f64> = (0..5 * n).map(|k| (k as f64 * 0.37).sin()).collect();
        let x: Vec<f64> = (0..n).map(|k| (k as f64 * 0.11).cos()).collect();
        let xi: Vec<f64> = (0..n).map(|k| (k as f64 * 0.71).sin()).collect();
        for s in [1usize, 2, 3, 4, 5] {
            let offsets: Vec<usize> = (0..s).map(|j| ((j * 2 + 1) % 5) * n).collect();
            let b: Vec<f64> = (0..s).map(|j| 0.3 + j as f64).collect();
            let mut want = vec![0.0; n];
            for k in 0..n {
                let mut acc = 0.9 * x[k] + 0.2 * xi[k];
                for j in 0..s {
                    acc += b[j] * hist[offsets[j] + k];
                }
                want[k] = acc;
            }
            let mut got = vec![0.0; n];
            lincomb_into(0.9, &x, Some((0.2, &xi)), &b, &hist, &offsets, &mut got);
            assert_eq!(got, want, "s={s} with noise");

            let mut want_ode = vec![0.0; n];
            for k in 0..n {
                let mut acc = 0.9 * x[k];
                for j in 0..s {
                    acc += b[j] * hist[offsets[j] + k];
                }
                want_ode[k] = acc;
            }
            let mut got_ode = vec![0.0; n];
            lincomb_into(0.9, &x, None, &b, &hist, &offsets, &mut got_ode);
            assert_eq!(got_ode, want_ode, "s={s} ode");

            let mut got_inplace = x.clone();
            lincomb_inplace(0.9, &mut got_inplace, &b, &hist, &offsets);
            assert_eq!(got_inplace, want_ode, "s={s} inplace");
        }
    }

    #[test]
    fn lincomb_empty_history_is_scale_only() {
        let x = [2.0, -4.0];
        let mut out = [0.0; 2];
        lincomb_into(0.5, &x, None, &[], &[], &[], &mut out);
        assert_eq!(out, [1.0, -2.0]);
    }
}
