//! The per-stepper scratch arena: one contiguous allocation, handed out
//! as disjoint equally-sized slots, so a solver step can use any number
//! of temporary state-sized buffers without a single heap allocation.
//!
//! Every stepper in the zoo sizes its arena once at `Stepper::init` (the
//! allocation-free-after-init contract is asserted by a counting-allocator
//! test); the arena is *not* serialized by snapshot/restore — scratch
//! contents are fully rewritten every step, so a restored stepper simply
//! re-sizes a fresh arena on its first step.

/// A slot-based scratch arena over one contiguous `Vec<f64>`.
///
/// Slots all have the same capacity (`chunk` elements); [`Scratch::split`]
/// borrows `K` disjoint slots at the caller's current active length,
/// which may shrink over the arena's lifetime (lane cancellation drops
/// rows, and scratch contents carry no cross-step state, so no compaction
/// is needed — callers just ask for shorter slices).
///
/// ```
/// use sadiff::linalg::Scratch;
/// let mut scr = Scratch::new(2, 4);
/// let [a, b] = scr.split(3);
/// a.fill(1.0);
/// b.fill(2.0);
/// assert_eq!(a.len(), 3);
/// assert_eq!(b.len(), 3);
/// ```
#[derive(Debug, Default)]
pub struct Scratch {
    buf: Vec<f64>,
    chunk: usize,
    /// Largest slot count this arena has been asked for; the arena never
    /// shrinks below `slots × chunk`, so a `split` with a smaller `K`
    /// cannot truncate slots another call site still uses.
    slots: usize,
}

impl Scratch {
    /// An arena of `slots` buffers of `chunk` elements each, zeroed.
    pub fn new(slots: usize, chunk: usize) -> Scratch {
        Scratch { buf: vec![0.0; slots * chunk], chunk, slots }
    }

    /// Capacity of each slot, in elements.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Borrow `K` disjoint slots of `len` active elements each.
    ///
    /// Grows the arena if it is too small for `K` slots of `len` — the
    /// steady state never grows (steppers size the arena at `init` and
    /// lane counts only shrink afterwards); the growth path exists so a
    /// stepper rebuilt by `restore`, which skips `init`, self-sizes on
    /// its first step. Growth never truncates the arena, but growing the
    /// slot capacity relocates slot bases, so contents are only
    /// meaningful between same-shape splits — which is all scratch
    /// semantics promise.
    pub fn split<const K: usize>(&mut self, len: usize) -> [&mut [f64]; K] {
        self.slots = self.slots.max(K);
        if self.chunk < len {
            self.chunk = len;
        }
        let need = self.slots * self.chunk;
        if self.buf.len() < need {
            self.buf.resize(need, 0.0);
        }
        let chunk = self.chunk;
        let mut out: [&mut [f64]; K] = std::array::from_fn(|_| Default::default());
        let mut rest: &mut [f64] = &mut self.buf;
        for slot in out.iter_mut() {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(chunk);
            let (active, _) = head.split_at_mut(len);
            *slot = active;
            rest = tail;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_are_disjoint_and_persistent() {
        let mut scr = Scratch::new(3, 4);
        {
            let [a, b, c] = scr.split(4);
            a.fill(1.0);
            b.fill(2.0);
            c.fill(3.0);
        }
        // Contents persist between splits (same backing arena).
        let [a, b, c] = scr.split(4);
        assert_eq!(a, &[1.0; 4]);
        assert_eq!(b, &[2.0; 4]);
        assert_eq!(c, &[3.0; 4]);
    }

    #[test]
    fn shorter_active_length_reuses_the_same_slots() {
        let mut scr = Scratch::new(2, 6);
        {
            let [a, _] = scr.split(6);
            a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        }
        let [a, b] = scr.split(2);
        assert_eq!(a, &[1.0, 2.0], "slot base must not move when len shrinks");
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn grows_when_undersized() {
        let mut scr = Scratch::default();
        let [a, b] = scr.split(5);
        assert_eq!(a.len(), 5);
        assert_eq!(b.len(), 5);
        assert_eq!(scr.chunk(), 5);
    }

    #[test]
    fn smaller_split_never_truncates_other_slots() {
        // A K smaller than the constructed slot count, even with a larger
        // len, must not shrink the arena under the wider call site.
        let mut scr = Scratch::new(3, 4);
        {
            let [_, _, c] = scr.split(4);
            c.copy_from_slice(&[7.0, 8.0, 9.0, 10.0]);
        }
        {
            let [a, _] = scr.split(5); // grows chunk, keeps all 3 slots
            assert_eq!(a.len(), 5);
        }
        let [_, _, c] = scr.split(5);
        assert_eq!(c.len(), 5, "third slot must survive the narrower split");
    }
}
