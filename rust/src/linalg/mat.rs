//! Row-major dense matrix with the decompositions the metric layer needs.

use crate::util::error::{Error, Result};

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major entries, `rows × cols`.
    pub data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Diagonal matrix from entries.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, v) in d.iter().enumerate() {
            m[(i, i)] = *v;
        }
        m
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Elementwise sum of two matrices.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat::from_rows(self.rows, self.cols, data)
    }

    /// Scale by a constant.
    pub fn scale(&self, s: f64) -> Mat {
        Mat::from_rows(self.rows, self.cols, self.data.iter().map(|v| v * s).collect())
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| super::dot(&self.data[i * self.cols..(i + 1) * self.cols], x))
            .collect()
    }

    /// Cholesky factor L with `self = L L^T` (lower-triangular). Errors if
    /// the matrix is not (numerically) positive definite.
    pub fn cholesky(&self) -> Result<Mat> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(Error::numerics(format!(
                            "cholesky: non-PD pivot {s:.3e} at {i}"
                        )));
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(l)
    }

    /// Symmetric eigendecomposition by cyclic Jacobi rotations.
    /// Returns `(eigenvalues, V)` with `self = V diag(w) V^T`, eigenvectors
    /// in the *columns* of V. Input must be symmetric.
    pub fn sym_eig(&self) -> (Vec<f64>, Mat) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut v = Mat::eye(n);
        // Up to 64 sweeps; tiny matrices converge in < 10.
        for _sweep in 0..64 {
            let mut off = 0.0;
            for i in 0..n {
                for j in (i + 1)..n {
                    off += a[(i, j)] * a[(i, j)];
                }
            }
            if off.sqrt() < 1e-14 * (1.0 + a.trace().abs()) {
                break;
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = a[(p, q)];
                    if apq.abs() < 1e-300 {
                        continue;
                    }
                    let theta = (a[(q, q)] - a[(p, p)]) / (2.0 * apq);
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    // A <- J^T A J applied to rows/cols p, q.
                    for k in 0..n {
                        let akp = a[(k, p)];
                        let akq = a[(k, q)];
                        a[(k, p)] = c * akp - s * akq;
                        a[(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a[(p, k)];
                        let aqk = a[(q, k)];
                        a[(p, k)] = c * apk - s * aqk;
                        a[(q, k)] = s * apk + c * aqk;
                    }
                    for k in 0..n {
                        let vkp = v[(k, p)];
                        let vkq = v[(k, q)];
                        v[(k, p)] = c * vkp - s * vkq;
                        v[(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
        let w = (0..n).map(|i| a[(i, i)]).collect();
        (w, v)
    }

    /// PSD square root via eigendecomposition; negative eigenvalues (from
    /// floating-point noise on a PSD input) are clamped to zero.
    pub fn psd_sqrt(&self) -> Mat {
        let (w, v) = self.sym_eig();
        let sq = Mat::diag(&w.iter().map(|x| x.max(0.0).sqrt()).collect::<Vec<_>>());
        v.matmul(&sq).matmul(&v.transpose())
    }

    /// Frobenius norm of `self - other`.
    pub fn frob_dist(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn matmul_and_transpose() {
        let a = Mat::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
        assert_eq!(a.transpose().data, vec![1.0, 3.0, 2.0, 4.0]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn cholesky_roundtrip() {
        let a = Mat::from_rows(3, 3, vec![4.0, 2.0, 0.6, 2.0, 5.0, 1.0, 0.6, 1.0, 3.0]);
        let l = a.cholesky().unwrap();
        let re = l.matmul(&l.transpose());
        assert!(a.frob_dist(&re) < 1e-12);
        // Non-PD must error.
        let bad = Mat::from_rows(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(bad.cholesky().is_err());
    }

    #[test]
    fn jacobi_eigen_diag() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let (mut w, _v) = a.sym_eig();
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!(close(w[0], 1.0, 1e-12, 0.0));
        assert!(close(w[1], 2.0, 1e-12, 0.0));
        assert!(close(w[2], 3.0, 1e-12, 0.0));
    }

    #[test]
    fn jacobi_eigen_reconstructs() {
        let a = Mat::from_rows(3, 3, vec![2.0, 1.0, 0.0, 1.0, 3.0, 0.5, 0.0, 0.5, 1.5]);
        let (w, v) = a.sym_eig();
        let re = v.matmul(&Mat::diag(&w)).matmul(&v.transpose());
        assert!(a.frob_dist(&re) < 1e-10, "dist={}", a.frob_dist(&re));
        // Orthogonality of V.
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.frob_dist(&Mat::eye(3)) < 1e-10);
    }

    #[test]
    fn psd_sqrt_squares_back() {
        let b = Mat::from_rows(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let s = b.psd_sqrt();
        assert!(s.matmul(&s).frob_dist(&b) < 1e-10);
    }

    #[test]
    fn psd_sqrt_of_identity_times() {
        let a = Mat::eye(4).scale(9.0);
        let s = a.psd_sqrt();
        assert!(s.frob_dist(&Mat::eye(4).scale(3.0)) < 1e-10);
    }
}
