//! Declarative CLI argument parsing (clap is not in the offline vendor
//! set). Supports `--key value`, `--switch`, positionals and generated
//! `--help` text; typed getters with defaults.

use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// One flag description, used for help text and validation.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Whether the flag takes a value (`--nfe 20`) or is a switch (`--quick`).
    pub takes_value: bool,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse raw tokens against a spec. Unknown `--flags` are rejected so
    /// typos surface instead of silently using defaults.
    pub fn parse(tokens: &[String], spec: &[FlagSpec]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let fs = spec
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| Error::config(format!("unknown flag --{name}")))?;
                if fs.takes_value {
                    let val = it
                        .next()
                        .ok_or_else(|| Error::config(format!("--{name} needs a value")))?;
                    args.flags.insert(name.to_string(), val.clone());
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positionals.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` minus the binary name.
    pub fn from_env(spec: &[FlagSpec]) -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&tokens, spec)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::config(format!("--{name}: '{s}' is not a number"))),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::config(format!("--{name}: '{s}' is not an integer"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::config(format!("--{name}: '{s}' is not an integer"))),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse a comma-separated list of numbers, e.g. `--taus 0,0.4,1.0`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| Error::config(format!("--{name}: bad number '{p}'")))
                })
                .collect(),
        }
    }

    /// Parse a comma-separated list of integers.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| Error::config(format!("--{name}: bad integer '{p}'")))
                })
                .collect(),
        }
    }
}

/// Render help text for a command.
pub fn render_help(cmd: &str, about: &str, spec: &[FlagSpec]) -> String {
    let mut out = format!("{cmd} — {about}\n\nFlags:\n");
    for f in spec {
        let val = if f.takes_value { " <value>" } else { "" };
        out.push_str(&format!("  --{}{:<14} {}\n", f.name, val, f.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "nfe", help: "evaluations", takes_value: true },
            FlagSpec { name: "quick", help: "small run", takes_value: false },
            FlagSpec { name: "taus", help: "list", takes_value: true },
        ]
    }

    #[test]
    fn parse_mixed() {
        let toks: Vec<String> = ["run", "--nfe", "20", "--quick", "extra"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&toks, &spec()).unwrap();
        assert_eq!(a.positionals, vec!["run", "extra"]);
        assert_eq!(a.get_usize("nfe", 0).unwrap(), 20);
        assert!(a.has("quick"));
        assert!(!a.has("slow"));
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn unknown_flag_rejected() {
        let toks = vec!["--bogus".to_string()];
        assert!(Args::parse(&toks, &spec()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        let toks = vec!["--nfe".to_string()];
        assert!(Args::parse(&toks, &spec()).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let toks = vec!["--nfe".to_string(), "abc".to_string()];
        let a = Args::parse(&toks, &spec()).unwrap();
        assert!(a.get_usize("nfe", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let toks = vec!["--taus".to_string(), "0,0.4, 1.0".to_string()];
        let a = Args::parse(&toks, &spec()).unwrap();
        assert_eq!(a.get_f64_list("taus", &[]).unwrap(), vec![0.0, 0.4, 1.0]);
    }

    fn args_with(value: &str) -> Args {
        let toks = vec!["--taus".to_string(), value.to_string()];
        Args::parse(&toks, &spec()).unwrap()
    }

    #[test]
    fn list_parsing_rejects_empty_string() {
        // `--taus ""` is an error (no silent empty list), for both types.
        assert!(args_with("").get_f64_list("taus", &[]).is_err());
        assert!(args_with("").get_usize_list("taus", &[]).is_err());
    }

    #[test]
    fn list_parsing_rejects_trailing_comma() {
        assert!(args_with("1,2,").get_f64_list("taus", &[]).is_err());
        assert!(args_with("5,10,").get_usize_list("taus", &[]).is_err());
        assert!(args_with(",5").get_usize_list("taus", &[]).is_err());
    }

    #[test]
    fn list_parsing_rejects_malformed_entries() {
        for bad in ["a,b", "1,x,3", "1.5,2", "--3", "1;2"] {
            let err = args_with(bad).get_usize_list("taus", &[]);
            assert!(err.is_err(), "usize list accepted {bad:?}");
        }
        for bad in ["a,b", "0.5,,1", "1,2,three"] {
            let err = args_with(bad).get_f64_list("taus", &[]);
            assert!(err.is_err(), "f64 list accepted {bad:?}");
        }
        // Errors name the flag and the offending entry.
        let msg = args_with("1,x").get_usize_list("taus", &[]).unwrap_err().to_string();
        assert!(msg.contains("taus") && msg.contains('x'), "{msg}");
    }

    #[test]
    fn list_parsing_whitespace_tolerant() {
        assert_eq!(args_with(" 5 , 10 ").get_usize_list("taus", &[]).unwrap(), vec![5, 10]);
    }

    #[test]
    fn help_renders() {
        let h = render_help("sadiff", "sampler", &spec());
        assert!(h.contains("--nfe"));
        assert!(h.contains("--quick"));
    }
}
