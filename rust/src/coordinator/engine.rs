//! The sampling engine: executes solver loops for single requests and
//! merged batches, with per-request Philox noise streams so batching never
//! changes a request's samples.

use crate::config::SamplerConfig;
use crate::coordinator::request::{SampleRequest, SampleResponse};
use crate::exec::{chunks, Executor};
use crate::jsonlite::Value;
use crate::models::{EvalCtx, ModelEval};
use crate::rng::normal::{NormalSource, SplitNoise};
use crate::rng::Philox4x32;
use crate::schedule::timesteps;
use crate::solvers::snapshot::{
    check_schema_version, f64s_to_hex, hex_to_f64s, hex_u64_array, u64_to_hex, StepperState,
    SNAPSHOT_SCHEMA_VERSION,
};
use crate::solvers::stepper::{self, Stepper};
use crate::solvers::{prior_sample, run_chunked, Grid, SolveOutput};
use crate::util::error::{Error, Result};
use crate::util::timing::Stopwatch;
use crate::workloads::Workload;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

/// Per-request noise streams inside a merged batch: global lane `l` maps to
/// (request r, local lane) and draws from request r's own Philox key, so
/// lane noise is identical to an unbatched run of that request. The tables
/// live behind `Arc` so [`SplitNoise::split_lanes`] is O(1) per worker
/// chunk (no per-batch copies on the serving hot path).
pub struct CompositeNormal {
    gens: Arc<Vec<Philox4x32>>,
    /// (generator index, local lane) per global lane.
    lane_map: Arc<Vec<(usize, u64)>>,
    /// Global lane this instance's local stream 0 refers to (worker shards
    /// of a chunked solve; 0 for the parent).
    lane0: usize,
}

impl CompositeNormal {
    /// Build from the (seed, n) list of the batch members, in lane order.
    pub fn new(members: &[(u64, usize)]) -> CompositeNormal {
        let mut gens = Vec::with_capacity(members.len());
        let mut lane_map = Vec::new();
        for (gi, (seed, n)) in members.iter().enumerate() {
            gens.push(Philox4x32::new(*seed));
            for local in 0..*n {
                lane_map.push((gi, local as u64));
            }
        }
        CompositeNormal { gens: Arc::new(gens), lane_map: Arc::new(lane_map), lane0: 0 }
    }

    /// A view whose local stream `l` draws global lane `lanes[l]`'s stream.
    /// This generalizes [`SplitNoise::split_lanes`] to non-contiguous lane
    /// sets — what a step-level shard becomes once cancellation has punched
    /// holes into its original lane range.
    pub fn select(&self, lanes: &[usize]) -> CompositeNormal {
        let map: Vec<(usize, u64)> =
            lanes.iter().map(|&l| self.lane_map[self.lane0 + l]).collect();
        CompositeNormal { gens: self.gens.clone(), lane_map: Arc::new(map), lane0: 0 }
    }

    /// Number of lanes this source addresses.
    pub fn lanes(&self) -> usize {
        self.lane_map.len() - self.lane0
    }

    /// The `(Philox key, local stream)` pair driving this view's lane
    /// `lane`. Philox is counter-keyed, so this pair IS the lane's whole
    /// noise-stream state — there is no mutable cursor; the step index of
    /// the next draw lives in the solve's grid position. This is what a
    /// checkpoint records per lane.
    pub fn stream_of(&self, lane: usize) -> (u64, u64) {
        let (gi, local) = self.lane_map[self.lane0 + lane];
        (self.gens[gi].key_u64(), local)
    }

    /// Rebuild a source from explicit per-lane streams (checkpoint
    /// restore): the new lane `l` draws stream `streams[l]` = (key, local).
    /// Generators are deduplicated by key, so a restored batch keeps one
    /// generator per original request like [`CompositeNormal::new`] builds.
    pub fn from_streams(streams: &[(u64, u64)]) -> CompositeNormal {
        let mut gens: Vec<Philox4x32> = Vec::new();
        let mut index: HashMap<u64, usize> = HashMap::new();
        let mut lane_map = Vec::with_capacity(streams.len());
        for (key, local) in streams {
            let gi = *index.entry(*key).or_insert_with(|| {
                gens.push(Philox4x32::new(*key));
                gens.len() - 1
            });
            lane_map.push((gi, *local));
        }
        CompositeNormal { gens: Arc::new(gens), lane_map: Arc::new(lane_map), lane0: 0 }
    }
}

impl NormalSource for CompositeNormal {
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]) {
        // An out-of-range lane must panic, not wrap: a silent `% len` here
        // would alias two requests' noise streams and quietly correlate
        // their samples — the worst possible failure mode for a serving
        // system whose core invariant is batch-composition-independence.
        let lane = self.lane0 + stream as usize;
        assert!(
            lane < self.lane_map.len(),
            "noise stream {stream} (global lane {lane}) out of range for a {}-lane batch",
            self.lane_map.len()
        );
        let (gi, local) = self.lane_map[lane];
        self.gens[gi].normals_into(local, step, out);
    }
}

impl SplitNoise for CompositeNormal {
    fn split_lanes(&self, lane0: usize) -> Box<dyn NormalSource + Send> {
        // Shared tables + an offset: each worker draws exactly the streams
        // the sequential run draws for its lanes (Philox is counter-keyed).
        Box::new(CompositeNormal {
            gens: self.gens.clone(),
            lane_map: self.lane_map.clone(),
            lane0: self.lane0 + lane0,
        })
    }
}

/// Run one solve for a single request-equivalent (workload model or any
/// other `ModelEval`).
pub fn sample(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> SolveOutput {
    sample_with(model, wl, cfg, n, seed, &Executor::sequential())
}

/// [`sample`] with an explicit lane-parallel executor (bit-identical output
/// for any thread count).
pub fn sample_with(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
    exec: &Executor,
) -> SolveOutput {
    let noise = CompositeNormal::new(&[(seed, n)]);
    run_chunked(model, &wl.schedule, cfg, n, &noise, exec)
}

/// One row of an experiment table: solver quality at a configuration.
#[derive(Debug, Clone)]
pub struct EvalRow {
    /// Distribution metric vs the workload reference (lower is better).
    pub sim_fid: f64,
    /// Sliced-Wasserstein-2 vs the workload reference.
    pub sliced_w2: f64,
    /// Model evaluations spent.
    pub nfe: usize,
    /// Wall-clock seconds of the solve.
    pub wall_s: f64,
}

/// Sample and score against the workload's reference distribution.
pub fn evaluate(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> EvalRow {
    evaluate_with(model, wl, cfg, n, seed, &Executor::sequential())
}

/// [`evaluate`] with an explicit lane-parallel executor.
pub fn evaluate_with(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
    exec: &Executor,
) -> EvalRow {
    let sw = Stopwatch::start();
    let out = sample_with(model, wl, cfg, n, seed, exec);
    let wall_s = sw.secs();
    let reference = wl.reference(n, seed ^ 0x5a5a);
    let sim_fid = crate::metrics::sim_fid(&out.samples, &reference, wl.dim())
        .unwrap_or(f64::NAN);
    let sliced_w2 = crate::metrics::sliced_w2(&out.samples, &reference, wl.dim(), 32, seed);
    EvalRow { sim_fid, sliced_w2, nfe: out.nfe, wall_s }
}

/// Execute a merged batch of compatible requests in one solver loop.
/// All requests must share (workload, cfg) — the batcher guarantees this.
pub fn run_batch(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    requests: &[SampleRequest],
) -> Vec<SampleResponse> {
    run_batch_with(model, wl, cfg, requests, &Executor::sequential())
}

/// [`run_batch`] with an explicit lane-parallel executor: the merged batch's
/// lanes are chunked across worker threads, and per-request Philox streams
/// keep every request's samples identical to an unbatched sequential run.
/// Runs start-to-finish on the stepper driver; the serving scheduler uses
/// the step-level [`BatchRun`] instead (bit-identical, asserted in tests).
pub fn run_batch_with(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    requests: &[SampleRequest],
    exec: &Executor,
) -> Vec<SampleResponse> {
    debug_assert!(!requests.is_empty());
    let sw = Stopwatch::start();
    let members: Vec<(u64, usize)> = requests.iter().map(|r| (r.seed, r.n)).collect();
    let total_n: usize = members.iter().map(|(_, n)| n).sum();
    let noise = CompositeNormal::new(&members);
    let out = run_chunked(model, &wl.schedule, cfg, total_n, &noise, exec);
    let wall_ms = sw.millis();
    let dim = out.dim;

    let mut responses = Vec::with_capacity(requests.len());
    let mut lane = 0usize;
    for req in requests {
        let lo = lane * dim;
        let hi = (lane + req.n) * dim;
        lane += req.n;
        let slice = &out.samples[lo..hi];
        let (sim_fid, sliced_w2) = if req.want_metrics && req.n >= 2 {
            let reference = wl.reference(req.n, req.seed ^ 0x5a5a);
            (
                crate::metrics::sim_fid(slice, &reference, dim).ok(),
                Some(crate::metrics::sliced_w2(slice, &reference, dim, 32, req.seed)),
            )
        } else {
            (None, None)
        };
        responses.push(SampleResponse {
            id: req.id,
            ok: true,
            error: None,
            kind: None,
            retry_after_ms: None,
            n: req.n,
            dim,
            nfe: out.nfe,
            wall_ms,
            sim_fid,
            sliced_w2,
            samples: if req.return_samples { Some(slice.to_vec()) } else { None },
        });
    }
    responses
}

/// NFE-counting model wrapper that also accumulates evaluation wall time
/// and records each batched call as a `model_eval` trace span on the
/// calling (exec pool) thread. Stack-allocated per shard per step, so it
/// adds nothing to the zero-allocs-per-step contract; the timing is two
/// monotonic clock reads per batched eval.
struct TimedModel<'a> {
    inner: &'a dyn ModelEval,
    count: std::sync::atomic::AtomicUsize,
    wall_us: std::sync::atomic::AtomicU64,
}

impl<'a> TimedModel<'a> {
    fn new(inner: &'a dyn ModelEval) -> Self {
        TimedModel {
            inner,
            count: std::sync::atomic::AtomicUsize::new(0),
            wall_us: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn count(&self) -> usize {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn wall_us(&self) -> u64 {
        self.wall_us.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl ModelEval for TimedModel<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_batch(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]) {
        let _span = crate::obs::trace::span("model_eval", "engine");
        let t0 = std::time::Instant::now();
        self.inner.eval_batch(xs, ctx, out);
        self.wall_us
            .fetch_add(t0.elapsed().as_micros() as u64, std::sync::atomic::Ordering::Relaxed);
        self.count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

/// One lane shard of an in-flight batch: a contiguous-at-admission slice
/// of the merged batch's lanes, with its own stepper state and noise view.
/// Cancellation can punch holes into `lanes`; the `select`ed noise view
/// keeps every surviving lane on its original global stream.
struct Shard {
    /// Original global lane ids this shard still runs, ascending.
    lanes: Vec<usize>,
    /// Row-major `lanes.len() × dim` state.
    x: Vec<f64>,
    stepper: Box<dyn Stepper>,
    noise: CompositeNormal,
    /// Model evaluations this shard has spent (identical across shards —
    /// calls are per step, not per lane; see `solvers::run_chunked`).
    evals: usize,
    /// Model-eval wall time of this shard's most recent step, µs.
    step_eval_us: u64,
}

/// A merged batch as a *step-level* primitive: the scheduler advances it
/// one grid step at a time (`step`), can drop a cancelled request's lanes
/// at any step boundary (`cancel`), and collects responses at the end
/// (`finish`). Built on the solver [`Stepper`] core; a `BatchRun` stepped
/// to completion is bit-identical to [`run_batch_with`] on the same
/// executor width (asserted in tests), which is itself bit-identical to a
/// sequential unbatched run per request.
pub struct BatchRun {
    model: Arc<dyn ModelEval>,
    wl: Workload,
    /// The group's shared solver config (kept for snapshot/restore — the
    /// grid and steppers are derived from it).
    cfg: SamplerConfig,
    grid: Grid,
    dim: usize,
    /// Surviving requests in arrival order, each with its global lane range
    /// in the merged batch (original ranges at admission; renumbered to a
    /// compact 0-based layout after a checkpoint restore).
    requests: Vec<(SampleRequest, Range<usize>)>,
    shards: Vec<Shard>,
    parent_noise: CompositeNormal,
    next_step: usize,
    sw: Stopwatch,
}

impl BatchRun {
    /// Admit a compatible group: draw priors, build per-shard steppers and
    /// run their warm-up (`init`) evaluations. All requests must share
    /// (workload, cfg) — the batcher guarantees this.
    pub fn new(
        model: Arc<dyn ModelEval>,
        wl: &Workload,
        cfg: &SamplerConfig,
        requests: Vec<SampleRequest>,
        exec: &Executor,
    ) -> BatchRun {
        debug_assert!(!requests.is_empty());
        let sw = Stopwatch::start();
        let dim = model.dim();
        let m = cfg.steps_for_nfe();
        let grid = Grid::new(&wl.schedule, timesteps(&wl.schedule, cfg.selector, m));
        let members: Vec<(u64, usize)> = requests.iter().map(|r| (r.seed, r.n)).collect();
        let total_n: usize = members.iter().map(|(_, n)| n).sum();
        let parent_noise = CompositeNormal::new(&members);

        let mut lane = 0usize;
        let requests: Vec<(SampleRequest, Range<usize>)> = requests
            .into_iter()
            .map(|r| {
                let range = lane..lane + r.n;
                lane += r.n;
                (r, range)
            })
            .collect();

        // Same lane chunking as `run_chunked`, so a full BatchRun equals a
        // `run_batch_with` of the same group bitwise at any thread count.
        // The prior draws and stepper warm-up evaluations (the expensive
        // part of admission for a real model) run shard-parallel on the
        // executor, like every subsequent step.
        let mut shards: Vec<Shard> = chunks(total_n, exec.threads())
            .into_iter()
            .map(|range| {
                let lanes: Vec<usize> = range.collect();
                let noise = parent_noise.select(&lanes);
                let stepper = stepper::make_stepper(cfg, &wl.schedule);
                Shard { lanes, x: Vec::new(), stepper, noise, evals: 0, step_eval_us: 0 }
            })
            .collect();
        let model_ref = &*model;
        let grid_ref = &grid;
        exec.for_each_mut(&mut shards, |_, shard| {
            let timed = TimedModel::new(model_ref);
            let n = shard.lanes.len();
            shard.x = prior_sample(grid_ref, dim, n, &mut shard.noise);
            shard.stepper.init(&timed, grid_ref, &mut shard.x, n, &mut shard.noise);
            shard.evals = timed.count();
        });
        BatchRun {
            model,
            wl: wl.clone(),
            cfg: cfg.clone(),
            grid,
            dim,
            requests,
            shards,
            parent_noise,
            next_step: 0,
            sw,
        }
    }

    /// Serialize the whole in-flight run at the current step boundary: the
    /// surviving requests, the evolved per-lane state, every stepper's
    /// history (shard states merged into one lane-ordered state), the grid
    /// position, and each lane's noise stream. The snapshot is independent
    /// of the shard layout it was taken under — [`BatchRun::restore`] is
    /// free to re-shard for a different executor width, and the resumed
    /// steps are bit-identical either way (asserted in
    /// `integration_snapshot` for every `SolverKind`).
    pub fn snapshot(&self) -> Value {
        let _span = crate::obs::trace::span("snapshot", "engine");
        debug_assert!(!self.requests.is_empty(), "snapshot of a drained group");
        let mut x = Vec::with_capacity(self.lanes() * self.dim);
        let mut keys = Vec::with_capacity(self.lanes());
        let mut locals = Vec::with_capacity(self.lanes());
        for shard in &self.shards {
            x.extend_from_slice(&shard.x);
            for &l in &shard.lanes {
                let (k, local) = self.parent_noise.stream_of(l);
                keys.push(Value::Str(u64_to_hex(k)));
                locals.push(Value::Str(u64_to_hex(local)));
            }
        }
        let states: Vec<StepperState> = self
            .shards
            .iter()
            .map(|s| s.stepper.snapshot(s.lanes.len(), self.dim))
            .collect();
        let merged = StepperState::merge(&states).expect("lockstep shards have mergeable states");
        Value::obj(vec![
            ("schema_version", Value::Num(SNAPSHOT_SCHEMA_VERSION as f64)),
            ("workload", Value::Str(self.wl.name.to_string())),
            ("solver_cfg", self.cfg.to_json()),
            ("dim", Value::Num(self.dim as f64)),
            ("next_step", Value::Num(self.next_step as f64)),
            ("evals", Value::Num(self.shards.first().map_or(0, |s| s.evals) as f64)),
            (
                "requests",
                Value::Array(self.requests.iter().map(|(r, _)| r.to_json()).collect()),
            ),
            ("stream_keys", Value::Array(keys)),
            ("stream_locals", Value::Array(locals)),
            ("x", Value::Str(f64s_to_hex(&x))),
            ("stepper", merged.to_json()),
        ])
    }

    /// Rebuild an in-flight run from a [`BatchRun::snapshot`] value. The
    /// lane shards are laid out for `exec`'s width — same or different from
    /// the snapshotting process — and surviving lanes are renumbered to a
    /// compact 0-based layout while each keeps its original noise stream,
    /// so the remaining steps reproduce the uninterrupted run bitwise.
    /// `model` is the resolved model for the group's requests (the caller
    /// resolves it the same way admission does).
    pub fn restore(v: &Value, model: Arc<dyn ModelEval>, exec: &Executor) -> Result<BatchRun> {
        let _span = crate::obs::trace::span("restore", "engine");
        check_schema_version(v, "batch checkpoint")?;
        let wl_name = v.req_str("workload")?;
        let wl = crate::workloads::by_name(wl_name)
            .ok_or_else(|| Error::config(format!("checkpoint names unknown workload '{wl_name}'")))?;
        let cfg = SamplerConfig::from_json(
            v.get("solver_cfg")
                .ok_or_else(|| Error::config("checkpoint missing 'solver_cfg'"))?,
        )?;
        let dim = v.req_usize("dim")?;
        if dim != model.dim() {
            return Err(Error::config(format!(
                "checkpoint dim {dim} does not match model dim {}",
                model.dim()
            )));
        }
        let next_step = v.req_usize("next_step")?;
        let evals = v.req_usize("evals")?;

        // Surviving requests, renumbered onto compact lane ranges.
        let req_values = v
            .get("requests")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("checkpoint missing 'requests' array"))?;
        let mut lane = 0usize;
        let mut requests: Vec<(SampleRequest, Range<usize>)> = Vec::with_capacity(req_values.len());
        for rv in req_values {
            let r = SampleRequest::from_json(rv)?;
            let range = lane..lane + r.n;
            lane += r.n;
            requests.push((r, range));
        }
        let total_n = lane;
        if total_n == 0 {
            return Err(Error::config("checkpoint group has no surviving lanes"));
        }

        let keys = hex_u64_array(v, "stream_keys")?;
        let locals = hex_u64_array(v, "stream_locals")?;
        if keys.len() != total_n || locals.len() != total_n {
            return Err(Error::config(format!(
                "checkpoint has {} noise streams for {} lanes",
                keys.len().min(locals.len()),
                total_n
            )));
        }
        let streams: Vec<(u64, u64)> = keys.into_iter().zip(locals).collect();
        let parent_noise = CompositeNormal::from_streams(&streams);

        let x = hex_to_f64s(v.req_str("x")?)?;
        if x.len() != total_n * dim {
            return Err(Error::config(format!(
                "checkpoint state has {} values for {} lanes × dim {}",
                x.len(),
                total_n,
                dim
            )));
        }

        let m = cfg.steps_for_nfe();
        if next_step > m {
            return Err(Error::config(format!(
                "checkpoint next_step {next_step} exceeds the {m}-step grid"
            )));
        }
        let grid = Grid::new(&wl.schedule, timesteps(&wl.schedule, cfg.selector, m));

        let merged = StepperState::from_json(
            v.get("stepper").ok_or_else(|| Error::config("checkpoint missing 'stepper'"))?,
        )?;
        if merged.lanes != total_n || merged.dim != dim {
            return Err(Error::config(format!(
                "checkpoint stepper state is {}×{}, expected {}×{}",
                merged.lanes, merged.dim, total_n, dim
            )));
        }

        // Lay the surviving lanes out as shards for THIS executor's width.
        let ranges = chunks(total_n, exec.threads());
        let counts: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        let parts = merged.split(&counts)?;
        let mut shards = Vec::with_capacity(ranges.len());
        for (range, part) in ranges.into_iter().zip(&parts) {
            let lanes: Vec<usize> = range.clone().collect();
            let noise = parent_noise.select(&lanes);
            let mut st = stepper::make_stepper(&cfg, &wl.schedule);
            st.restore(part, &grid, dim)?;
            shards.push(Shard {
                lanes,
                x: x[range.start * dim..range.end * dim].to_vec(),
                stepper: st,
                noise,
                evals,
                step_eval_us: 0,
            });
        }
        Ok(BatchRun {
            model,
            wl,
            cfg,
            grid,
            dim,
            requests,
            shards,
            parent_noise,
            next_step,
            sw: Stopwatch::start(),
        })
    }

    /// Advance every lane by one grid step. Shards dispatch onto `exec`'s
    /// persistent parked pool workers (`exec_shard` spans on stable
    /// `sadiff-exec-N` trace lanes); a step costs one pool round-trip,
    /// never a thread spawn/join. Returns `true` once the run is finished.
    pub fn step(&mut self, exec: &Executor) -> bool {
        if self.is_done() {
            return true;
        }
        let _span = crate::obs::trace::span("batch_step", "engine");
        let i = self.next_step;
        let model = &*self.model;
        let grid = &self.grid;
        exec.for_each_mut(&mut self.shards, |_, shard| {
            let _shard_span = crate::obs::trace::span("shard_step", "engine");
            let timed = TimedModel::new(model);
            let n = shard.lanes.len();
            shard.stepper.step(&timed, grid, i, &mut shard.x, n, &mut shard.noise);
            shard.evals += timed.count();
            shard.step_eval_us = timed.wall_us();
        });
        self.next_step += 1;
        self.is_done()
    }

    /// Model-evaluation wall time of the most recent [`BatchRun::step`],
    /// in milliseconds: the maximum across shards (the critical path —
    /// shards run in parallel). 0 before the first step.
    pub fn last_eval_ms(&self) -> f64 {
        self.shards.iter().map(|s| s.step_eval_us).max().unwrap_or(0) as f64 / 1000.0
    }

    /// Steps completed / total steps (per-step progress reporting).
    pub fn progress(&self) -> (usize, usize) {
        (self.next_step, self.grid.m())
    }

    /// True once every step ran (or every request was cancelled).
    pub fn is_done(&self) -> bool {
        self.next_step >= self.grid.m() || self.requests.is_empty()
    }

    /// Ids of the requests still in flight (the server's reply tickets).
    pub fn tickets(&self) -> Vec<u64> {
        self.requests.iter().map(|(r, _)| r.id).collect()
    }

    /// Surviving lane count.
    pub fn lanes(&self) -> usize {
        self.requests.iter().map(|(r, _)| r.n).sum()
    }

    /// Drop request `ticket`'s lanes at the current step boundary. Every
    /// other request's lanes keep their global noise streams and history
    /// rows, so survivors are bit-identical to an undisturbed run. Returns
    /// the `"cancelled"` error response for the dropped request, or `None`
    /// if the ticket is not part of this run.
    pub fn cancel(&mut self, ticket: u64) -> Option<SampleResponse> {
        let pos = self.requests.iter().position(|(r, _)| r.id == ticket)?;
        let (req, range) = self.requests.remove(pos);
        let dim = self.dim;
        for shard in &mut self.shards {
            if !shard.lanes.iter().any(|l| range.contains(l)) {
                continue;
            }
            let keep: Vec<bool> = shard.lanes.iter().map(|l| !range.contains(l)).collect();
            shard.stepper.retain_lanes(&keep, dim);
            stepper::retain_rows(&mut shard.x, &keep, dim);
            // Compact the lane list in place (matching the row compaction
            // the steppers do) instead of rebuilding it.
            shard.lanes.retain(|l| !range.contains(l));
            shard.noise = self.parent_noise.select(&shard.lanes);
        }
        // A shard whose lanes were all cancelled has nothing left to
        // advance — drop it so remaining steps don't pay its per-step
        // lane-independent costs (coefficients, empty model calls). The
        // surviving shards all hold the full eval history, so NFE
        // accounting still reads any remaining shard.
        self.shards.retain(|s| !s.lanes.is_empty());
        Some(SampleResponse::typed_err(req.id, "cancelled", "cancelled"))
    }

    /// Collect responses for the surviving requests. Call after `step`
    /// returned `true`.
    pub fn finish(mut self) -> Vec<SampleResponse> {
        debug_assert!(self.is_done());
        for shard in &mut self.shards {
            shard.stepper.finish(&mut shard.x);
        }
        let wall_ms = self.sw.millis();
        let nfe = self.shards.first().map_or(0, |s| s.evals);
        let dim = self.dim;
        // Shards hold ascending disjoint lane sets, so their concatenation
        // is the surviving lanes in global order — request blocks in
        // arrival order, exactly as `run_batch_with` lays them out.
        let mut samples = Vec::with_capacity(self.lanes() * dim);
        for shard in &self.shards {
            samples.extend_from_slice(&shard.x);
        }
        let mut responses = Vec::with_capacity(self.requests.len());
        let mut lane = 0usize;
        for (req, _) in &self.requests {
            let lo = lane * dim;
            let hi = (lane + req.n) * dim;
            lane += req.n;
            let slice = &samples[lo..hi];
            let (sim_fid, sliced_w2) = if req.want_metrics && req.n >= 2 {
                let reference = self.wl.reference(req.n, req.seed ^ 0x5a5a);
                (
                    crate::metrics::sim_fid(slice, &reference, dim).ok(),
                    Some(crate::metrics::sliced_w2(slice, &reference, dim, 32, req.seed)),
                )
            } else {
                (None, None)
            };
            responses.push(SampleResponse {
                id: req.id,
                ok: true,
                error: None,
                kind: None,
                retry_after_ms: None,
                n: req.n,
                dim,
                nfe,
                wall_ms,
                sim_fid,
                sliced_w2,
                samples: if req.return_samples { Some(slice.to_vec()) } else { None },
            });
        }
        responses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn req(id: u64, n: usize, seed: u64) -> SampleRequest {
        SampleRequest {
            id,
            workload: "latent_analog".into(),
            model: "gmm".into(),
            cfg: SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() },
            n,
            seed,
            return_samples: true,
            want_metrics: false,
            preset: None,
            deadline_ms: None,
            priority: 0,
        }
    }

    #[test]
    fn batching_invariance() {
        // A request's samples must be identical whether it runs alone or
        // merged with others — the core serving reproducibility invariant.
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let alone = run_batch(&*model, &wl, &cfg, &[req(1, 3, 111)]);
        let merged = run_batch(
            &*model,
            &wl,
            &cfg,
            &[req(0, 5, 999), req(1, 3, 111), req(2, 2, 222)],
        );
        let alone_s = alone[0].samples.as_ref().unwrap();
        let merged_s = merged[1].samples.as_ref().unwrap();
        assert_eq!(alone_s, merged_s);
    }

    #[test]
    fn parallel_batch_matches_sequential_batch() {
        // Lane-chunked batch execution must not change any request's
        // samples or NFE accounting, for uneven request sizes.
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let reqs = [req(0, 5, 999), req(1, 3, 111), req(2, 2, 222)];
        let seq = run_batch(&*model, &wl, &cfg, &reqs);
        for threads in [2usize, 3, 16] {
            let par = run_batch_with(&*model, &wl, &cfg, &reqs, &Executor::new(threads));
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a.samples, b.samples, "threads={threads}");
                assert_eq!(a.nfe, b.nfe);
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn composite_fill_out_of_range_lane_panics() {
        // Regression: this used to wrap with `% lane_map.len()`, silently
        // aliasing two requests' noise streams. It must panic instead.
        let mut noise = CompositeNormal::new(&[(1, 2), (2, 3)]);
        let mut out = [0.0; 4];
        noise.fill(5, 0, &mut out); // 5 lanes exist: streams 0..=4
    }

    #[test]
    fn composite_fill_in_range_lane_still_works() {
        let mut noise = CompositeNormal::new(&[(1, 2), (2, 3)]);
        let mut a = [0.0; 4];
        let mut b = [0.0; 4];
        noise.fill(4, 0, &mut a); // last valid lane: request 2, local lane 2
        let mut direct = crate::rng::normal::PhiloxNormal::new(2);
        direct.fill(2, 0, &mut b);
        assert_eq!(a, b);
        assert_eq!(noise.lanes(), 5);
    }

    #[test]
    fn select_view_matches_global_streams() {
        // A selected (non-contiguous) view must draw exactly the global
        // lanes it names — the cancellation-survivor noise contract.
        let parent = CompositeNormal::new(&[(7, 2), (9, 3)]);
        let mut view = parent.select(&[0, 3, 4]);
        let mut direct = CompositeNormal::new(&[(7, 2), (9, 3)]);
        let mut a = [0.0; 4];
        let mut b = [0.0; 4];
        for (local, global) in [(0u64, 0u64), (1, 3), (2, 4)] {
            view.fill(local, 5, &mut a);
            direct.fill(global, 5, &mut b);
            assert_eq!(a, b, "local={local} global={global}");
        }
    }

    #[test]
    fn batch_run_stepping_matches_run_batch() {
        // BatchRun stepped to completion == run_batch_with, bitwise, for
        // every executor width (the step-level scheduler's correctness
        // contract).
        let wl = workloads::latent_analog();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let reqs = [req(0, 5, 999), req(1, 3, 111), req(2, 2, 222)];
        let model = wl.model();
        let want = run_batch(&*model, &wl, &cfg, &reqs);
        for threads in [1usize, 2, 4] {
            let exec = Executor::new(threads);
            let model: Arc<dyn ModelEval> = Arc::from(wl.model());
            let mut run = BatchRun::new(model, &wl, &cfg, reqs.to_vec(), &exec);
            let mut steps = 0usize;
            while !run.step(&exec) {
                steps += 1;
            }
            assert_eq!(run.progress().0, run.progress().1);
            assert!(steps + 1 == run.progress().1, "one step() call per grid step");
            let got = run.finish();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.samples, b.samples, "threads={threads}, id={}", a.id);
                assert_eq!(a.nfe, b.nfe, "threads={threads}");
                assert_eq!((a.n, a.dim, a.id), (b.n, b.dim, b.id));
            }
        }
    }

    #[test]
    fn batch_run_cancel_leaves_survivors_bit_identical() {
        // Cancel the middle request halfway through: the survivors must
        // equal their solo runs bitwise, at several thread counts.
        let wl = workloads::latent_analog();
        let cfg = SamplerConfig { nfe: 10, ..SamplerConfig::sa_default() };
        let reqs = [req(0, 3, 999), req(1, 4, 111), req(2, 2, 222)];
        let model = wl.model();
        let solo_a = run_batch(&*model, &wl, &cfg, &reqs[0..1]);
        let solo_c = run_batch(&*model, &wl, &cfg, &reqs[2..3]);
        for threads in [1usize, 3] {
            let exec = Executor::new(threads);
            let model: Arc<dyn ModelEval> = Arc::from(wl.model());
            let mut run = BatchRun::new(model, &wl, &cfg, reqs.to_vec(), &exec);
            for _ in 0..4 {
                assert!(!run.step(&exec));
            }
            let resp = run.cancel(1).expect("ticket 1 is in flight");
            assert!(!resp.ok);
            assert_eq!(resp.error.as_deref(), Some("cancelled"));
            assert!(run.cancel(1).is_none(), "double-cancel finds nothing");
            assert_eq!(run.lanes(), 5);
            assert_eq!(run.tickets(), vec![0, 2]);
            while !run.step(&exec) {}
            let got = run.finish();
            assert_eq!(got.len(), 2);
            assert_eq!(got[0].samples, solo_a[0].samples, "threads={threads}");
            assert_eq!(got[1].samples, solo_c[0].samples, "threads={threads}");
        }
    }

    #[test]
    fn batch_run_cancel_everything_finishes_early() {
        let wl = workloads::latent_analog();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let exec = Executor::sequential();
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let mut run = BatchRun::new(model, &wl, &cfg, vec![req(7, 2, 1)], &exec);
        run.step(&exec);
        assert!(run.cancel(7).is_some());
        assert!(run.is_done(), "no surviving requests → done");
        assert!(run.finish().is_empty());
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        // Snapshot halfway, round-trip through the wire form (a simulated
        // process boundary), restore at a different executor width, and the
        // finished responses must equal the uninterrupted run bitwise.
        let wl = workloads::latent_analog();
        let cfg = SamplerConfig { nfe: 9, ..SamplerConfig::sa_default() };
        let reqs = [req(0, 3, 999), req(1, 2, 111)];
        let model = wl.model();
        let want = run_batch(&*model, &wl, &cfg, &reqs);
        for (threads_before, threads_after) in [(1usize, 4usize), (4, 1), (2, 2)] {
            let exec = Executor::new(threads_before);
            let model: Arc<dyn ModelEval> = Arc::from(wl.model());
            let mut run = BatchRun::new(model, &wl, &cfg, reqs.to_vec(), &exec);
            for _ in 0..4 {
                run.step(&exec);
            }
            let line = crate::jsonlite::to_string(&run.snapshot());
            drop(run); // the "killed" process

            let v = crate::jsonlite::parse(&line).unwrap();
            let model: Arc<dyn ModelEval> = Arc::from(wl.model());
            let exec2 = Executor::new(threads_after);
            let mut resumed = BatchRun::restore(&v, model, &exec2).unwrap();
            assert_eq!(resumed.progress().0, 4);
            while !resumed.step(&exec2) {}
            let got = resumed.finish();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(
                    a.samples, b.samples,
                    "restore {threads_before}→{threads_after} diverged (id={})",
                    a.id
                );
                assert_eq!(a.nfe, b.nfe, "NFE accounting diverged across restore");
                assert_eq!((a.id, a.n, a.dim), (b.id, b.n, b.dim));
            }
        }
    }

    #[test]
    fn snapshot_restore_after_cancel_keeps_survivor_streams() {
        // Cancel punches holes into the lane set; a snapshot taken after
        // must carry each survivor's original noise stream so the resumed
        // run still matches the survivors' solo runs.
        let wl = workloads::latent_analog();
        let cfg = SamplerConfig { nfe: 10, ..SamplerConfig::sa_default() };
        let reqs = [req(0, 3, 999), req(1, 4, 111), req(2, 2, 222)];
        let model = wl.model();
        let solo_a = run_batch(&*model, &wl, &cfg, &reqs[0..1]);
        let solo_c = run_batch(&*model, &wl, &cfg, &reqs[2..3]);
        let exec = Executor::new(3);
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let mut run = BatchRun::new(model, &wl, &cfg, reqs.to_vec(), &exec);
        for _ in 0..5 {
            run.step(&exec);
        }
        run.cancel(1).expect("ticket 1 in flight");
        let v = run.snapshot();
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let exec2 = Executor::new(2);
        let mut resumed = BatchRun::restore(&v, model, &exec2).unwrap();
        assert_eq!(resumed.tickets(), vec![0, 2]);
        assert_eq!(resumed.lanes(), 5);
        while !resumed.step(&exec2) {}
        let got = resumed.finish();
        assert_eq!(got[0].samples, solo_a[0].samples, "survivor A corrupted");
        assert_eq!(got[1].samples, solo_c[0].samples, "survivor C corrupted");
    }

    #[test]
    fn restore_rejects_newer_schema_and_garbage() {
        let wl = workloads::latent_analog();
        let cfg = SamplerConfig { nfe: 6, ..SamplerConfig::sa_default() };
        let exec = Executor::sequential();
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let run = BatchRun::new(model, &wl, &cfg, vec![req(5, 2, 4)], &exec);
        let mut v = run.snapshot();
        if let Value::Object(fields) = &mut v {
            for (k, val) in fields.iter_mut() {
                if k == "schema_version" {
                    *val = Value::Num(99.0);
                }
            }
        }
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let err = BatchRun::restore(&v, model, &exec).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        assert!(BatchRun::restore(&Value::obj(vec![]), model, &exec).is_err());
    }

    #[test]
    fn composite_from_streams_matches_original() {
        let parent = CompositeNormal::new(&[(7, 2), (9, 3)]);
        let streams: Vec<(u64, u64)> = (0..5).map(|l| parent.stream_of(l)).collect();
        assert_eq!(streams[0], (7, 0));
        assert_eq!(streams[4], (9, 2));
        let mut rebuilt = CompositeNormal::from_streams(&streams);
        let mut direct = CompositeNormal::new(&[(7, 2), (9, 3)]);
        let mut a = [0.0; 6];
        let mut b = [0.0; 6];
        for lane in 0..5u64 {
            rebuilt.fill(lane, 3, &mut a);
            direct.fill(lane, 3, &mut b);
            assert_eq!(a, b, "lane {lane}");
        }
        // Non-contiguous survivor subset, as after a cancel.
        let subset: Vec<(u64, u64)> = [0usize, 3, 4].iter().map(|&l| parent.stream_of(l)).collect();
        let mut view = CompositeNormal::from_streams(&subset);
        for (new_lane, old_lane) in [(0u64, 0u64), (1, 3), (2, 4)] {
            view.fill(new_lane, 8, &mut a);
            direct.fill(old_lane, 8, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn evaluate_produces_sane_metrics() {
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 24, ..SamplerConfig::sa_default() };
        let row = evaluate(&*model, &wl, &cfg, 256, 5);
        assert!(row.sim_fid.is_finite() && row.sim_fid >= 0.0);
        assert!(row.sliced_w2.is_finite() && row.sliced_w2 >= 0.0);
        assert_eq!(row.nfe, 24);
        // More NFE should not be dramatically worse.
        let row_fine = evaluate(&*model, &wl, &cfg, 256, 5);
        assert!(row_fine.sim_fid.is_finite());
    }

    #[test]
    fn responses_align_with_requests() {
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 6, ..SamplerConfig::sa_default() };
        let rs = run_batch(&*model, &wl, &cfg, &[req(7, 2, 1), req(8, 4, 2)]);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 7);
        assert_eq!(rs[0].n, 2);
        assert_eq!(rs[1].id, 8);
        assert_eq!(rs[1].samples.as_ref().unwrap().len(), 4 * wl.dim());
    }
}
