//! The sampling engine: executes solver loops for single requests and
//! merged batches, with per-request Philox noise streams so batching never
//! changes a request's samples.

use crate::config::SamplerConfig;
use crate::coordinator::request::{SampleRequest, SampleResponse};
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::rng::Philox4x32;
use crate::solvers::{run_with_noise, SolveOutput};
use crate::util::timing::Stopwatch;
use crate::workloads::Workload;

/// Per-request noise streams inside a merged batch: global lane `l` maps to
/// (request r, local lane) and draws from request r's own Philox key, so
/// lane noise is identical to an unbatched run of that request.
pub struct CompositeNormal {
    gens: Vec<Philox4x32>,
    /// (generator index, local lane) per global lane.
    lane_map: Vec<(usize, u64)>,
}

impl CompositeNormal {
    /// Build from the (seed, n) list of the batch members, in lane order.
    pub fn new(members: &[(u64, usize)]) -> CompositeNormal {
        let mut gens = Vec::with_capacity(members.len());
        let mut lane_map = Vec::new();
        for (gi, (seed, n)) in members.iter().enumerate() {
            gens.push(Philox4x32::new(*seed));
            for local in 0..*n {
                lane_map.push((gi, local as u64));
            }
        }
        CompositeNormal { gens, lane_map }
    }
}

impl NormalSource for CompositeNormal {
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]) {
        let (gi, local) = self.lane_map[stream as usize % self.lane_map.len()];
        self.gens[gi].normals_into(local, step, out);
    }
}

/// Run one solve for a single request-equivalent (workload model or any
/// other `ModelEval`).
pub fn sample(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> SolveOutput {
    let mut noise = CompositeNormal::new(&[(seed, n)]);
    run_with_noise(model, &wl.schedule, cfg, n, &mut noise)
}

/// One row of an experiment table: solver quality at a configuration.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub sim_fid: f64,
    pub sliced_w2: f64,
    pub nfe: usize,
    pub wall_s: f64,
}

/// Sample and score against the workload's reference distribution.
pub fn evaluate(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> EvalRow {
    let sw = Stopwatch::start();
    let out = sample(model, wl, cfg, n, seed);
    let wall_s = sw.secs();
    let reference = wl.reference(n, seed ^ 0x5a5a);
    let sim_fid = crate::metrics::sim_fid(&out.samples, &reference, wl.dim())
        .unwrap_or(f64::NAN);
    let sliced_w2 = crate::metrics::sliced_w2(&out.samples, &reference, wl.dim(), 32, seed);
    EvalRow { sim_fid, sliced_w2, nfe: out.nfe, wall_s }
}

/// Execute a merged batch of compatible requests in one solver loop.
/// All requests must share (workload, cfg) — the batcher guarantees this.
pub fn run_batch(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    requests: &[SampleRequest],
) -> Vec<SampleResponse> {
    debug_assert!(!requests.is_empty());
    let sw = Stopwatch::start();
    let members: Vec<(u64, usize)> = requests.iter().map(|r| (r.seed, r.n)).collect();
    let total_n: usize = members.iter().map(|(_, n)| n).sum();
    let mut noise = CompositeNormal::new(&members);
    let out = run_with_noise(model, &wl.schedule, cfg, total_n, &mut noise);
    let wall_ms = sw.millis();
    let dim = out.dim;

    let mut responses = Vec::with_capacity(requests.len());
    let mut lane = 0usize;
    for req in requests {
        let lo = lane * dim;
        let hi = (lane + req.n) * dim;
        lane += req.n;
        let slice = &out.samples[lo..hi];
        let (sim_fid, sliced_w2) = if req.want_metrics && req.n >= 2 {
            let reference = wl.reference(req.n, req.seed ^ 0x5a5a);
            (
                crate::metrics::sim_fid(slice, &reference, dim).ok(),
                Some(crate::metrics::sliced_w2(slice, &reference, dim, 32, req.seed)),
            )
        } else {
            (None, None)
        };
        responses.push(SampleResponse {
            id: req.id,
            ok: true,
            error: None,
            n: req.n,
            dim,
            nfe: out.nfe,
            wall_ms,
            sim_fid,
            sliced_w2,
            samples: if req.return_samples { Some(slice.to_vec()) } else { None },
        });
    }
    responses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn req(id: u64, n: usize, seed: u64) -> SampleRequest {
        SampleRequest {
            id,
            workload: "latent_analog".into(),
            model: "gmm".into(),
            cfg: SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() },
            n,
            seed,
            return_samples: true,
            want_metrics: false,
        }
    }

    #[test]
    fn batching_invariance() {
        // A request's samples must be identical whether it runs alone or
        // merged with others — the core serving reproducibility invariant.
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let alone = run_batch(&*model, &wl, &cfg, &[req(1, 3, 111)]);
        let merged = run_batch(
            &*model,
            &wl,
            &cfg,
            &[req(0, 5, 999), req(1, 3, 111), req(2, 2, 222)],
        );
        let alone_s = alone[0].samples.as_ref().unwrap();
        let merged_s = merged[1].samples.as_ref().unwrap();
        assert_eq!(alone_s, merged_s);
    }

    #[test]
    fn evaluate_produces_sane_metrics() {
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 24, ..SamplerConfig::sa_default() };
        let row = evaluate(&*model, &wl, &cfg, 256, 5);
        assert!(row.sim_fid.is_finite() && row.sim_fid >= 0.0);
        assert!(row.sliced_w2.is_finite() && row.sliced_w2 >= 0.0);
        assert_eq!(row.nfe, 24);
        // More NFE should not be dramatically worse.
        let row_fine = evaluate(&*model, &wl, &cfg, 256, 5);
        assert!(row_fine.sim_fid.is_finite());
    }

    #[test]
    fn responses_align_with_requests() {
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 6, ..SamplerConfig::sa_default() };
        let rs = run_batch(&*model, &wl, &cfg, &[req(7, 2, 1), req(8, 4, 2)]);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 7);
        assert_eq!(rs[0].n, 2);
        assert_eq!(rs[1].id, 8);
        assert_eq!(rs[1].samples.as_ref().unwrap().len(), 4 * wl.dim());
    }
}
