//! The sampling engine: executes solver loops for single requests and
//! merged batches, with per-request Philox noise streams so batching never
//! changes a request's samples.

use crate::config::SamplerConfig;
use crate::coordinator::request::{SampleRequest, SampleResponse};
use crate::exec::Executor;
use crate::models::ModelEval;
use crate::rng::normal::{NormalSource, SplitNoise};
use crate::rng::Philox4x32;
use crate::solvers::{run_chunked, SolveOutput};
use crate::util::timing::Stopwatch;
use crate::workloads::Workload;
use std::sync::Arc;

/// Per-request noise streams inside a merged batch: global lane `l` maps to
/// (request r, local lane) and draws from request r's own Philox key, so
/// lane noise is identical to an unbatched run of that request. The tables
/// live behind `Arc` so [`SplitNoise::split_lanes`] is O(1) per worker
/// chunk (no per-batch copies on the serving hot path).
pub struct CompositeNormal {
    gens: Arc<Vec<Philox4x32>>,
    /// (generator index, local lane) per global lane.
    lane_map: Arc<Vec<(usize, u64)>>,
    /// Global lane this instance's local stream 0 refers to (worker shards
    /// of a chunked solve; 0 for the parent).
    lane0: usize,
}

impl CompositeNormal {
    /// Build from the (seed, n) list of the batch members, in lane order.
    pub fn new(members: &[(u64, usize)]) -> CompositeNormal {
        let mut gens = Vec::with_capacity(members.len());
        let mut lane_map = Vec::new();
        for (gi, (seed, n)) in members.iter().enumerate() {
            gens.push(Philox4x32::new(*seed));
            for local in 0..*n {
                lane_map.push((gi, local as u64));
            }
        }
        CompositeNormal { gens: Arc::new(gens), lane_map: Arc::new(lane_map), lane0: 0 }
    }
}

impl NormalSource for CompositeNormal {
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]) {
        let lane = (self.lane0 + stream as usize) % self.lane_map.len();
        let (gi, local) = self.lane_map[lane];
        self.gens[gi].normals_into(local, step, out);
    }
}

impl SplitNoise for CompositeNormal {
    fn split_lanes(&self, lane0: usize) -> Box<dyn NormalSource + Send> {
        // Shared tables + an offset: each worker draws exactly the streams
        // the sequential run draws for its lanes (Philox is counter-keyed).
        Box::new(CompositeNormal {
            gens: self.gens.clone(),
            lane_map: self.lane_map.clone(),
            lane0: self.lane0 + lane0,
        })
    }
}

/// Run one solve for a single request-equivalent (workload model or any
/// other `ModelEval`).
pub fn sample(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> SolveOutput {
    sample_with(model, wl, cfg, n, seed, &Executor::sequential())
}

/// [`sample`] with an explicit lane-parallel executor (bit-identical output
/// for any thread count).
pub fn sample_with(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
    exec: &Executor,
) -> SolveOutput {
    let noise = CompositeNormal::new(&[(seed, n)]);
    run_chunked(model, &wl.schedule, cfg, n, &noise, exec)
}

/// One row of an experiment table: solver quality at a configuration.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub sim_fid: f64,
    pub sliced_w2: f64,
    pub nfe: usize,
    pub wall_s: f64,
}

/// Sample and score against the workload's reference distribution.
pub fn evaluate(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> EvalRow {
    evaluate_with(model, wl, cfg, n, seed, &Executor::sequential())
}

/// [`evaluate`] with an explicit lane-parallel executor.
pub fn evaluate_with(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
    exec: &Executor,
) -> EvalRow {
    let sw = Stopwatch::start();
    let out = sample_with(model, wl, cfg, n, seed, exec);
    let wall_s = sw.secs();
    let reference = wl.reference(n, seed ^ 0x5a5a);
    let sim_fid = crate::metrics::sim_fid(&out.samples, &reference, wl.dim())
        .unwrap_or(f64::NAN);
    let sliced_w2 = crate::metrics::sliced_w2(&out.samples, &reference, wl.dim(), 32, seed);
    EvalRow { sim_fid, sliced_w2, nfe: out.nfe, wall_s }
}

/// Execute a merged batch of compatible requests in one solver loop.
/// All requests must share (workload, cfg) — the batcher guarantees this.
pub fn run_batch(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    requests: &[SampleRequest],
) -> Vec<SampleResponse> {
    run_batch_with(model, wl, cfg, requests, &Executor::sequential())
}

/// [`run_batch`] with an explicit lane-parallel executor: the merged batch's
/// lanes are chunked across worker threads, and per-request Philox streams
/// keep every request's samples identical to an unbatched sequential run.
pub fn run_batch_with(
    model: &dyn ModelEval,
    wl: &Workload,
    cfg: &SamplerConfig,
    requests: &[SampleRequest],
    exec: &Executor,
) -> Vec<SampleResponse> {
    debug_assert!(!requests.is_empty());
    let sw = Stopwatch::start();
    let members: Vec<(u64, usize)> = requests.iter().map(|r| (r.seed, r.n)).collect();
    let total_n: usize = members.iter().map(|(_, n)| n).sum();
    let noise = CompositeNormal::new(&members);
    let out = run_chunked(model, &wl.schedule, cfg, total_n, &noise, exec);
    let wall_ms = sw.millis();
    let dim = out.dim;

    let mut responses = Vec::with_capacity(requests.len());
    let mut lane = 0usize;
    for req in requests {
        let lo = lane * dim;
        let hi = (lane + req.n) * dim;
        lane += req.n;
        let slice = &out.samples[lo..hi];
        let (sim_fid, sliced_w2) = if req.want_metrics && req.n >= 2 {
            let reference = wl.reference(req.n, req.seed ^ 0x5a5a);
            (
                crate::metrics::sim_fid(slice, &reference, dim).ok(),
                Some(crate::metrics::sliced_w2(slice, &reference, dim, 32, req.seed)),
            )
        } else {
            (None, None)
        };
        responses.push(SampleResponse {
            id: req.id,
            ok: true,
            error: None,
            n: req.n,
            dim,
            nfe: out.nfe,
            wall_ms,
            sim_fid,
            sliced_w2,
            samples: if req.return_samples { Some(slice.to_vec()) } else { None },
        });
    }
    responses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn req(id: u64, n: usize, seed: u64) -> SampleRequest {
        SampleRequest {
            id,
            workload: "latent_analog".into(),
            model: "gmm".into(),
            cfg: SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() },
            n,
            seed,
            return_samples: true,
            want_metrics: false,
            preset: None,
        }
    }

    #[test]
    fn batching_invariance() {
        // A request's samples must be identical whether it runs alone or
        // merged with others — the core serving reproducibility invariant.
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let alone = run_batch(&*model, &wl, &cfg, &[req(1, 3, 111)]);
        let merged = run_batch(
            &*model,
            &wl,
            &cfg,
            &[req(0, 5, 999), req(1, 3, 111), req(2, 2, 222)],
        );
        let alone_s = alone[0].samples.as_ref().unwrap();
        let merged_s = merged[1].samples.as_ref().unwrap();
        assert_eq!(alone_s, merged_s);
    }

    #[test]
    fn parallel_batch_matches_sequential_batch() {
        // Lane-chunked batch execution must not change any request's
        // samples or NFE accounting, for uneven request sizes.
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let reqs = [req(0, 5, 999), req(1, 3, 111), req(2, 2, 222)];
        let seq = run_batch(&*model, &wl, &cfg, &reqs);
        for threads in [2usize, 3, 16] {
            let par = run_batch_with(&*model, &wl, &cfg, &reqs, &Executor::new(threads));
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a.samples, b.samples, "threads={threads}");
                assert_eq!(a.nfe, b.nfe);
            }
        }
    }

    #[test]
    fn evaluate_produces_sane_metrics() {
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 24, ..SamplerConfig::sa_default() };
        let row = evaluate(&*model, &wl, &cfg, 256, 5);
        assert!(row.sim_fid.is_finite() && row.sim_fid >= 0.0);
        assert!(row.sliced_w2.is_finite() && row.sliced_w2 >= 0.0);
        assert_eq!(row.nfe, 24);
        // More NFE should not be dramatically worse.
        let row_fine = evaluate(&*model, &wl, &cfg, 256, 5);
        assert!(row_fine.sim_fid.is_finite());
    }

    #[test]
    fn responses_align_with_requests() {
        let wl = workloads::latent_analog();
        let model = wl.model();
        let cfg = SamplerConfig { nfe: 6, ..SamplerConfig::sa_default() };
        let rs = run_batch(&*model, &wl, &cfg, &[req(7, 2, 1), req(8, 4, 2)]);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].id, 7);
        assert_eq!(rs[0].n, 2);
        assert_eq!(rs[1].id, 8);
        assert_eq!(rs[1].samples.as_ref().unwrap().len(), 4 * wl.dim());
    }
}
