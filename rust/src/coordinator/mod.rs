//! The serving coordinator (Layer 3): request types and wire protocol,
//! dynamic batcher, sampling engine, TCP server and serving metrics.
//!
//! Design (vLLM-router mold, DESIGN.md §6): clients submit sampling
//! requests over newline-delimited JSON; the batcher groups *compatible*
//! requests (same workload + solver config) into one solver loop whose
//! model evaluations are batched; per-request Philox noise streams make a
//! request's samples independent of how it was batched.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::{BatchKey, Batcher};
pub use engine::{sample, EvalRow};
pub use request::{SampleRequest, SampleResponse};
pub use server::{Server, ServerHandle};
