//! The serving coordinator (Layer 3): request types and wire protocol,
//! dynamic batcher, sampling engine, TCP server and serving metrics.
//!
//! Design (vLLM-router mold, DESIGN.md §6): clients submit sampling
//! requests over newline-delimited JSON; the batcher groups *compatible*
//! requests (same workload + solver config) into one merged lane batch
//! whose model evaluations are shared; per-request Philox noise streams
//! make a request's samples independent of how it was batched. The hot
//! path is *step-synchronous*: a merged batch is an [`engine::BatchRun`]
//! over the solver `Stepper` core, advanced one grid step at a time, so
//! workers can interleave several in-flight batches, admit newly queued
//! requests at step boundaries (continuous batching), cancel in-flight
//! requests, and report per-step progress.

pub mod batcher;
pub mod checkpoint;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{BatchKey, Batcher};
pub use checkpoint::{GroupCheckpoint, ServerCheckpoint};
pub use engine::{sample, BatchRun, EvalRow};
pub use request::{cancel_line, SampleRequest, SampleResponse};
pub use router::{ChaosHooks, Placement, Router, RouterConfig, RouterHandle, WorkerView};
pub use server::{Server, ServerHandle};
