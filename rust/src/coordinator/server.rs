//! The sampling server: newline-delimited JSON over TCP, a shared pending
//! queue with deadline-based dynamic batching, and a worker pool executing
//! solver loops. tokio is not in the offline vendor set; the design is a
//! classic blocking-I/O thread-per-connection front with channel-backed
//! response routing, which is appropriate at the connection counts a
//! sampling service sees.
//!
//! Protocol (one JSON object per line):
//! * sampling request — see [`SampleRequest::from_json`]; an optional
//!   `"preset"` field (`"auto"` or a preset name) resolves against the
//!   loaded tuner registry *at ingress*, so preset and manual requests
//!   with the same concrete config share a batch;
//! * `{"cmd": "stats"}` → serving-metrics snapshot (includes the current
//!   `queued_samples` gauge);
//! * `{"cmd": "presets"}` → summary of the loaded preset registry;
//! * `{"cmd": "ping"}` → `{"ok": true}`;
//! * `{"cmd": "shutdown"}` → stops accepting and drains workers.
//!
//! Every malformed line — bad JSON, invalid UTF-8, unknown command — gets
//! a reply with an `"error"` field; the connection is never silently
//! dropped on bad input.

use crate::config::ServerConfig;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::run_batch_with;
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::request::{SampleRequest, SampleResponse};
use crate::exec::Executor;
use crate::jsonlite::{parse, to_string, Value};
use crate::models::ModelEval;
use crate::runtime::{HloModel, RuntimeHost};
use crate::tuner::PresetRegistry;
use crate::util::error::{Error, Result};
use crate::workloads;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Shared server state.
struct Shared {
    queue: Mutex<QueueState>,
    cond: Condvar,
    metrics: ServingMetrics,
    cfg: ServerConfig,
    shutdown: AtomicBool,
    /// Bound address, for self-pokes that unblock the accept loop.
    addr: SocketAddr,
    /// Lane-parallel executor used inside each batch's solver loop
    /// (`cfg.threads`; bit-identical output for any thread count).
    exec: Executor,
    /// Tuner preset registry serving the request `"preset"` field.
    presets: Option<PresetRegistry>,
    /// Lazily started PJRT runtime host (only if a request needs it).
    runtime: Mutex<Option<Arc<RuntimeHost>>>,
}

struct QueueState {
    batcher: Batcher,
    replies: HashMap<u64, Sender<SampleResponse>>,
    /// Monotone internal ticket for reply routing (client ids may collide).
    next_ticket: u64,
}

/// A running server.
pub struct Server {
    shared: Arc<Shared>,
    listener: TcpListener,
}

/// Handle returned by `spawn`: address + shutdown control.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and join the accept loop. Safe when the accept
    /// thread already exited (e.g. after a protocol `shutdown` command):
    /// the poke-connect may fail, but the join happens regardless, and a
    /// handle that was already shut down is a no-op (`Drop` relies on
    /// this).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        let Some(t) = self.accept_thread.take() else {
            return; // already shut down
        };
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cond.notify_all();
        // Poke the accept loop so it notices the flag. The connect can
        // fail (listener already closed) — that must not skip the join
        // below, which is what actually reclaims the thread.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(250));
        let _ = t.join();
    }

    pub fn metrics_snapshot(&self) -> Value {
        self.shared.metrics.snapshot()
    }
}

impl Drop for ServerHandle {
    /// A dropped handle still stops the server — tests that panic (or
    /// forget to call `shutdown`) must not leak the accept thread.
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

impl Server {
    /// Bind to `cfg.addr` (use port 0 for an ephemeral port), loading the
    /// preset registry from `cfg.presets_path` when set.
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        let presets = cfg.presets_path.as_deref().map(PresetRegistry::load).transpose()?;
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::runtime(format!("bind {}: {e}", cfg.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::runtime(format!("local_addr: {e}")))?;
        if let Some(reg) = &presets {
            crate::log_info!("server", "loaded {} presets", reg.presets.len());
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                batcher: Batcher::new(),
                replies: HashMap::new(),
                next_ticket: 1,
            }),
            cond: Condvar::new(),
            metrics: ServingMetrics::new(),
            exec: Executor::new(cfg.threads),
            cfg,
            shutdown: AtomicBool::new(false),
            addr,
            presets,
            runtime: Mutex::new(None),
        });
        Ok(Server { shared, listener })
    }

    /// Start workers and the accept loop on background threads; returns a
    /// handle with the bound address.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.shared.addr;
        for w in 0..self.shared.cfg.workers {
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("sadiff-worker-{w}"))
                .spawn(move || worker_loop(shared))
                .map_err(|e| Error::runtime(format!("spawn worker: {e}")))?;
        }
        let shared = self.shared.clone();
        let listener = self.listener;
        let accept_thread = std::thread::Builder::new()
            .name("sadiff-accept".into())
            .spawn(move || accept_loop(listener, shared))
            .map_err(|e| Error::runtime(format!("spawn accept: {e}")))?;
        crate::log_info!("server", "listening on {addr}");
        Ok(ServerHandle { addr, shared: self.shared, accept_thread: Some(accept_thread) })
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let shared = shared.clone();
                let _ = std::thread::Builder::new()
                    .name("sadiff-conn".into())
                    .spawn(move || connection_loop(s, shared));
            }
            Err(e) => {
                crate::log_warn!("server", "accept error: {e}");
            }
        }
    }
}

fn connection_loop(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // Read raw lines (not `BufRead::lines`): a line that is not valid
    // UTF-8 must produce an `"error"` reply, not a silently dropped
    // connection. Only hard I/O errors (where no reply can be written
    // anyway) end the loop early.
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(_) => break,
        }
        let reply_line = match std::str::from_utf8(&buf) {
            Ok(line) if line.trim().is_empty() => continue,
            Ok(line) => handle_line(line.trim_end_matches(&['\r', '\n'][..]), &shared),
            Err(_) => SampleResponse::err(0, "request line is not valid utf-8").to_line(),
        };
        if writer
            .write_all(format!("{reply_line}\n").as_bytes())
            .is_err()
        {
            break;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    crate::log_debug!("server", "connection {peer} closed");
}

/// Handle one protocol line, returning the response line.
fn handle_line(line: &str, shared: &Arc<Shared>) -> String {
    let v = match parse(line) {
        Ok(v) => v,
        Err(e) => return SampleResponse::err(0, format!("bad json: {e}")).to_line(),
    };
    if let Some(cmd) = v.get("cmd").and_then(Value::as_str) {
        return match cmd {
            "stats" => to_string(&shared.metrics.snapshot()),
            "presets" => match &shared.presets {
                Some(reg) => to_string(&reg.summary()),
                None => r#"{"ok":false,"error":"no preset registry loaded"}"#.to_string(),
            },
            "ping" => r#"{"ok":true}"#.to_string(),
            "shutdown" => {
                shared.shutdown.store(true, Ordering::SeqCst);
                shared.cond.notify_all();
                // Unblock the accept loop so the thread actually exits
                // (nothing else may ever connect again).
                let _ = TcpStream::connect_timeout(&shared.addr, Duration::from_millis(250));
                r#"{"ok":true,"shutting_down":true}"#.to_string()
            }
            other => SampleResponse::err(0, format!("unknown cmd '{other}'")).to_line(),
        };
    }
    let mut request = match SampleRequest::from_json(&v) {
        Ok(r) => r,
        Err(e) => return SampleResponse::err(0, e.to_string()).to_line(),
    };
    // Resolve a preset to its concrete config *before* enqueueing: the
    // batcher then keys on the resolved config, so preset and manual
    // requests merge into the same group.
    if let Some(spec) = &request.preset {
        match &shared.presets {
            None => {
                return SampleResponse::err(
                    request.id,
                    format!("preset '{spec}' requested but no registry loaded (serve --presets)"),
                )
                .to_line()
            }
            Some(reg) => match reg.resolve(spec, &request.workload, request.cfg.nfe) {
                Ok(p) => request.cfg = p.cfg.clone(),
                Err(e) => return SampleResponse::err(request.id, e.to_string()).to_line(),
            },
        }
    }
    shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
    // Shed load if the queue is over capacity.
    let (tx, rx) = std::sync::mpsc::channel();
    {
        let mut q = shared.queue.lock().expect("queue lock");
        if q.batcher.len() >= shared.cfg.queue_cap {
            shared.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return SampleResponse::err(request.id, "overloaded: queue full").to_line();
        }
        let ticket = q.next_ticket;
        q.next_ticket += 1;
        // The ticket rides in the request id slot internally; the original
        // id is restored when the response is routed back.
        let mut internal = request.clone();
        internal.id = ticket;
        q.replies.insert(ticket, tx);
        q.batcher.push(internal);
        shared.metrics.set_queued_samples(q.batcher.queued_samples());
    }
    shared.cond.notify_one();
    let timeout = Duration::from_secs(120);
    match rx.recv_timeout(timeout) {
        Ok(mut resp) => {
            resp.id = request.id;
            if resp.ok {
                shared.metrics.responses_ok.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.metrics.responses_err.fetch_add(1, Ordering::Relaxed);
            }
            shared.metrics.observe_latency_ms(resp.wall_ms);
            resp.to_line()
        }
        Err(_) => SampleResponse::err(request.id, "timeout").to_line(),
    }
}

/// Worker: wait for work, give the batcher a short deadline to fill a
/// group, execute, route responses.
fn worker_loop(shared: Arc<Shared>) {
    loop {
        let group = {
            let mut q = shared.queue.lock().expect("queue lock");
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.batcher.is_empty() {
                    return;
                }
                if !q.batcher.is_empty() {
                    // Deadline-based flush: wait until the oldest request
                    // has aged past the batching window, or a full batch
                    // is available.
                    let deadline = Duration::from_millis(shared.cfg.batch_deadline_ms);
                    let age = q.batcher.oldest_age().unwrap_or_default();
                    if q.batcher.len() >= shared.cfg.max_batch || age >= deadline {
                        break;
                    }
                    let wait = deadline - age;
                    let (qq, _timeout) = shared
                        .cond
                        .wait_timeout(q, wait)
                        .expect("queue lock poisoned");
                    q = qq;
                } else {
                    let (qq, _res) = shared
                        .cond
                        .wait_timeout(q, Duration::from_millis(50))
                        .expect("queue lock poisoned");
                    q = qq;
                }
            }
            let group = q.batcher.pop_group(shared.cfg.max_batch);
            shared.metrics.set_queued_samples(q.batcher.queued_samples());
            group
        };
        if group.is_empty() {
            continue;
        }
        let responses = execute_group(&shared, &group);
        let mut q = shared.queue.lock().expect("queue lock");
        for resp in responses {
            if let Some(tx) = q.replies.remove(&resp.id) {
                let _ = tx.send(resp);
            }
        }
    }
}

/// Execute one compatible group end to end.
fn execute_group(shared: &Arc<Shared>, group: &[SampleRequest]) -> Vec<SampleResponse> {
    let first = &group[0];
    let Some(wl) = workloads::by_name(&first.workload) else {
        return group
            .iter()
            .map(|r| SampleResponse::err(r.id, format!("unknown workload '{}'", first.workload)))
            .collect();
    };
    let model: Box<dyn ModelEval> = if let Some(name) = first.model.strip_prefix("artifact:") {
        match artifact_model(shared, name) {
            Ok(m) => m,
            Err(e) => {
                return group
                    .iter()
                    .map(|r| SampleResponse::err(r.id, e.to_string()))
                    .collect()
            }
        }
    } else {
        wl.model()
    };
    let total: usize = group.iter().map(|r| r.n).sum();
    let responses = run_batch_with(&*model, &wl, &first.cfg, group, &shared.exec);
    let nfe = responses.first().map(|r| r.nfe).unwrap_or(0);
    shared.metrics.observe_batch(group.len(), total, nfe);
    responses
}

/// Resolve an artifact-backed model through the lazily started runtime host.
fn artifact_model(shared: &Arc<Shared>, name: &str) -> Result<Box<dyn ModelEval>> {
    let mut guard = shared.runtime.lock().expect("runtime lock");
    if guard.is_none() {
        *guard = Some(RuntimeHost::open_default()?);
    }
    let host = guard.as_ref().unwrap().clone();
    drop(guard);
    Ok(Box::new(HloModel::from_manifest(host, name)?))
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::runtime(format!("connect {addr}: {e}")))?;
        let writer = stream
            .try_clone()
            .map_err(|e| Error::runtime(format!("clone stream: {e}")))?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one line, read one line.
    pub fn round_trip(&mut self, line: &str) -> Result<String> {
        self.writer
            .write_all(format!("{line}\n").as_bytes())
            .map_err(Error::Io)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf).map_err(Error::Io)?;
        Ok(buf.trim_end().to_string())
    }

    pub fn request(&mut self, req: &SampleRequest) -> Result<SampleResponse> {
        let line = self.round_trip(&req.to_line())?;
        SampleResponse::from_json(&parse(&line)?)
    }

    pub fn stats(&mut self) -> Result<Value> {
        let line = self.round_trip(r#"{"cmd":"stats"}"#)?;
        parse(&line)
    }
}
