//! The sampling server: newline-delimited JSON over TCP, a shared pending
//! queue, and a worker pool running a *step-synchronous scheduler*. tokio
//! is not in the offline vendor set; the design is a classic blocking-I/O
//! thread-per-connection front with channel-backed response routing, which
//! is appropriate at the connection counts a sampling service sees.
//!
//! Each worker owns a set of in-flight lane groups ([`BatchRun`]s built on
//! the solver `Stepper` core) and interleaves them one solver step at a
//! time. At every step boundary it admits newly queued compatible groups
//! (up to `max_inflight`) instead of waiting for the current solve to
//! drain, and applies pending cancellations — per-lane Philox streams make
//! every request's samples independent of when and with whom it ran.
//!
//! Protocol (one JSON object per line):
//! * sampling request — see [`SampleRequest::from_json`]; an optional
//!   `"preset"` field (`"auto"` or a preset name) resolves against the
//!   loaded tuner registry *at ingress*, so preset and manual requests
//!   with the same concrete config share a batch. Requests may carry
//!   `deadline_ms` (still-queued past the budget → typed `deadline` error
//!   at the admission boundary) and `priority` (group extraction is
//!   priority-then-EDF; reorder-safe by per-lane Philox keys). Admission
//!   sheds — typed `shed` error with a `retry_after_ms` hint — when the
//!   queue is full by request count (`queue_cap`) or by queued lanes
//!   (`queue_lane_cap`; an empty queue always admits), and a connection
//!   waiting longer than `reply_timeout_ms` gets a typed `timeout` error
//!   with its ticket cancelled so the lanes free;
//! * `{"cmd": "stats"}` → serving-metrics snapshot (includes the
//!   `queued_samples` gauge plus the per-step scheduler fields `steps`,
//!   `step_lanes`, `cancelled`, `inflight_groups`, `inflight_lanes`, and
//!   the SLO counters `timeouts` / `deadline_miss`);
//! * `{"cmd": "cancel", "id": N}` → cancels every queued or in-flight
//!   request whose client-visible id is `N`: queued requests are removed
//!   immediately, in-flight ones are dropped at the owning worker's next
//!   step boundary (their lanes are freed; co-batched requests are
//!   unaffected). Each cancelled request's waiting connection receives a
//!   typed `cancelled` error reply;
//! * `{"cmd": "presets"}` → summary of the loaded preset registry;
//! * `{"cmd": "recover"}` → ids of checkpoint-recovered results ready to
//!   fetch (plus the count still resuming); `{"cmd": "recover", "id": N}`
//!   returns the recovered response for client id `N`, and with
//!   `"take": true` also removes it from the store (the router's
//!   exactly-once fetch). Recovered results exist because a restarted
//!   server resumes checkpointed groups whose original connections died
//!   with the previous process — or because a group was `migrate_in`-ed
//!   from another worker;
//! * `{"cmd": "ping"}` → `{"ok": true}`;
//! * `{"cmd": "snapshot"}` → load gauges plus (when snapshotting is on —
//!   `checkpoint_path` or `publish_snapshots`) the current in-flight
//!   group checkpoints. This is the router's heartbeat: the groups it
//!   returns are exactly what a failover would re-assign;
//! * `{"cmd": "migrate_out"}` (optional `"client": N`, `"timeout_ms"`) →
//!   hand one in-flight group over: the owning worker detaches it at its
//!   next step boundary and the reply carries its [`GroupCheckpoint`].
//!   Remaining waiting connections for the migrated requests get typed
//!   `migrated` errors (the router follows the group to its new home);
//! * `{"cmd": "migrate_in", "group": {…}}` → accept a migrated group:
//!   its requests are re-ticketed into this server's ticket space and it
//!   resumes through the checkpoint-recovery path, results landing in
//!   the `recover` store keyed by the ids the checkpoint carried;
//! * `{"cmd": "trace", "action": "start"|"stop"|"dump"}` → controls the
//!   process-wide span recorder ([`crate::obs`]). `dump` writes a Chrome
//!   Trace Event file to the command's `"path"` (falling back to
//!   `ServerConfig.trace_path`), or returns the trace inline when neither
//!   is set;
//! * `{"cmd": "shutdown"}` → stops accepting and drains workers.
//!
//! With `ServerConfig.checkpoint_path` set (`serve --checkpoint-path`),
//! every worker rewrites the in-flight set — as [`BatchRun`] snapshots —
//! at step boundaries: every `checkpoint_every` scheduler steps and on any
//! change to the in-flight set. On startup the file (if present) is loaded
//! and its groups are requeued to resume exactly where they stopped; the
//! resumed steps are bit-identical to an uninterrupted run (per-lane
//! Philox streams + serialized stepper history). Recovery is at-least-once:
//! a crash after a result was delivered but before the next checkpoint
//! rewrite re-runs that group on restart, landing a duplicate (identical)
//! result in the recover store.
//!
//! Every malformed line — bad JSON, invalid UTF-8, unknown command — gets
//! a reply with an `"error"` field; the connection is never silently
//! dropped on bad input.

use crate::config::ServerConfig;
use crate::coordinator::batcher::{Batcher, Pending};
use crate::coordinator::checkpoint::{GroupCheckpoint, ServerCheckpoint};
use crate::coordinator::engine::BatchRun;
use crate::coordinator::metrics::{ServingMetrics, Stage};
use crate::coordinator::request::{cancel_line, SampleRequest, SampleResponse};
use crate::exec::Executor;
use crate::jsonlite::{parse, to_string, Value};
use crate::models::ModelEval;
use crate::obs::trace;
use crate::runtime::{HloModel, RuntimeHost};
use crate::tuner::PresetRegistry;
use crate::util::error::{Error, Result};
use crate::workloads;
use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Shared server state.
struct Shared {
    queue: Mutex<QueueState>,
    cond: Condvar,
    metrics: ServingMetrics,
    cfg: ServerConfig,
    shutdown: AtomicBool,
    /// Hard-kill flag ([`ServerHandle::kill`]): workers exit at their next
    /// boundary check WITHOUT draining — the crash-simulation path the
    /// checkpoint recovery tests restart from.
    abort: AtomicBool,
    /// Bound address, for self-pokes that unblock the accept loop.
    addr: SocketAddr,
    /// Lane-parallel executor used inside each batch's solver loop
    /// (`cfg.threads`; bit-identical output for any thread count). Built
    /// once at bind time, this owns the server's one persistent parked
    /// worker pool — every engine worker dispatches through it for the
    /// process lifetime (the pool serializes dispatches, so `workers`
    /// concurrent solver loops never stack their thread counts), and its
    /// `sadiff-exec-N` threads give traces stable per-worker lanes.
    exec: Executor,
    /// Tuner preset registry serving the request `"preset"` field.
    presets: Option<PresetRegistry>,
    /// Lazily started PJRT runtime host (only if a request needs it).
    runtime: Mutex<Option<Arc<RuntimeHost>>>,
    /// Per-worker in-flight snapshots, merged into the checkpoint file on
    /// every write (workers only ever replace their own slice).
    checkpoint_sink: Mutex<HashMap<usize, Vec<GroupCheckpoint>>>,
}

struct QueueState {
    batcher: Batcher,
    replies: HashMap<u64, Sender<SampleResponse>>,
    /// Ticket → client-visible id, for `cancel` routing; entries live from
    /// enqueue until the reply is routed.
    client_of: HashMap<u64, u64>,
    /// Tickets flagged for cancellation while in flight; the owning worker
    /// applies them at its next step boundary.
    cancel_flags: HashSet<u64>,
    /// Checkpointed groups loaded at startup, awaiting a worker slot.
    restored: Vec<GroupCheckpoint>,
    /// Restored groups claimed by a worker but not yet reflected in that
    /// worker's in-flight checkpoint slice, keyed by worker id. Checkpoint
    /// rewrites include these (and `restored`) so a backlog of resumed
    /// groups survives a second crash — groups leave the file only once a
    /// worker's own slice carries them (or they complete).
    restoring: HashMap<usize, GroupCheckpoint>,
    /// Ticket → client id for requests resumed from a checkpoint (their
    /// connections died with the previous process).
    recovered_clients: HashMap<u64, u64>,
    /// Finished recovered responses, keyed by client-visible id and served
    /// by the `recover` protocol command.
    recovered_results: HashMap<u64, SampleResponse>,
    /// Monotone internal ticket for reply routing (client ids may collide).
    next_ticket: u64,
    /// Pending `migrate_out` requests parked by connection threads; a
    /// worker claims one at a step boundary when it owns a matching group.
    migrate_outs: Vec<MigrateOut>,
}

/// A parked `migrate_out`: the connection thread waits on `tx`'s receiver
/// until a worker detaches a matching group (or the wait times out and the
/// entry is withdrawn under the queue lock).
struct MigrateOut {
    /// Identity for withdrawal on timeout (drawn from the ticket counter).
    id: u64,
    /// Restrict the pick to the group owning this client-visible id;
    /// `None` migrates the widest in-flight group.
    client: Option<u64>,
    /// Reply channel back to the connection thread. The claiming worker
    /// sends while still holding the queue lock, so a withdrawn entry
    /// (timeout) and a sent checkpoint cannot race: whoever takes the
    /// lock first wins, and the loser observes it.
    tx: Sender<std::result::Result<GroupCheckpoint, String>>,
}

/// Route one response to its waiting connection and drop its bookkeeping.
/// A response whose connection is gone because it was resumed from a
/// checkpoint lands in the recover store instead.
fn route_reply(q: &mut QueueState, resp: SampleResponse) {
    q.client_of.remove(&resp.id);
    q.cancel_flags.remove(&resp.id);
    if let Some(tx) = q.replies.remove(&resp.id) {
        let _ = tx.send(resp);
    } else if let Some(client) = q.recovered_clients.remove(&resp.id) {
        let mut resp = resp;
        resp.id = client;
        q.recovered_results.insert(client, resp);
    }
}

/// A running server.
pub struct Server {
    shared: Arc<Shared>,
    listener: TcpListener,
}

/// Handle returned by `spawn`: address + shutdown control.
pub struct ServerHandle {
    /// The bound listen address (useful with port 0 binds).
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and join the accept loop. Safe when the accept
    /// thread already exited (e.g. after a protocol `shutdown` command):
    /// the poke-connect may fail, but the join happens regardless, and a
    /// handle that was already shut down is a no-op (`Drop` relies on
    /// this).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    /// Simulate a crash: every worker exits at its next boundary check
    /// WITHOUT draining the queue or finishing in-flight groups, exactly as
    /// `kill -9` would abandon them. The checkpoint file (when enabled)
    /// keeps its last written state — the state a restarted server resumes
    /// from. Waiting connections never get replies; recovery tests restart
    /// a server on the same `checkpoint_path` and fetch results through
    /// the `recover` protocol command.
    pub fn kill(mut self) {
        self.shared.abort.store(true, Ordering::SeqCst);
        self.shared.cond.notify_all();
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        let Some(t) = self.accept_thread.take() else {
            return; // already shut down
        };
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cond.notify_all();
        // Poke the accept loop so it notices the flag. The connect can
        // fail (listener already closed) — that must not skip the join
        // below, which is what actually reclaims the thread.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(250));
        let _ = t.join();
    }

    /// The current serving-metrics snapshot (what `stats` returns).
    pub fn metrics_snapshot(&self) -> Value {
        self.shared.metrics.snapshot()
    }
}

impl Drop for ServerHandle {
    /// A dropped handle still stops the server — tests that panic (or
    /// forget to call `shutdown`) must not leak the accept thread.
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

impl Server {
    /// Bind to `cfg.addr` (use port 0 for an ephemeral port), loading the
    /// preset registry from `cfg.presets_path` when set.
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        let presets = cfg.presets_path.as_deref().map(PresetRegistry::load).transpose()?;
        // Crash-safe resume: load the previous process's in-flight set (if
        // a checkpoint exists) before any worker starts. Tickets of the
        // dead process stay reserved so fresh requests cannot collide with
        // them in the reply-routing maps.
        let mut restored: Vec<GroupCheckpoint> = Vec::new();
        let mut recovered_clients: HashMap<u64, u64> = HashMap::new();
        let mut next_ticket = 1u64;
        if let Some(path) = cfg.checkpoint_path.as_deref() {
            if std::path::Path::new(path).exists() {
                let ck = ServerCheckpoint::load(path)?;
                for g in ck.groups {
                    for (t, c) in &g.clients {
                        recovered_clients.insert(*t, *c);
                        next_ticket = next_ticket.max(t + 1);
                    }
                    restored.push(g);
                }
                if !restored.is_empty() {
                    crate::log_info!(
                        "server",
                        "checkpoint {path}: resuming {} in-flight group(s)",
                        restored.len()
                    );
                }
            }
        }
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::runtime(format!("bind {}: {e}", cfg.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::runtime(format!("local_addr: {e}")))?;
        if let Some(reg) = &presets {
            crate::log_info!("server", "loaded {} presets", reg.presets.len());
        }
        // Tracing: the ring capacity applies to threads registering from
        // here on (workers have not spawned yet); a configured dump path
        // means "capture from startup", so the recorder starts now.
        trace::set_capacity(cfg.trace_capacity);
        if let Some(path) = cfg.trace_path.as_deref() {
            trace::start();
            crate::log_info!("server", "tracing enabled (default dump path {path})");
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                batcher: Batcher::new(),
                replies: HashMap::new(),
                client_of: HashMap::new(),
                cancel_flags: HashSet::new(),
                restored,
                restoring: HashMap::new(),
                recovered_clients,
                recovered_results: HashMap::new(),
                next_ticket,
                migrate_outs: Vec::new(),
            }),
            cond: Condvar::new(),
            metrics: ServingMetrics::new(),
            exec: Executor::new(cfg.threads),
            cfg,
            shutdown: AtomicBool::new(false),
            abort: AtomicBool::new(false),
            addr,
            presets,
            runtime: Mutex::new(None),
            checkpoint_sink: Mutex::new(HashMap::new()),
        });
        Ok(Server { shared, listener })
    }

    /// Start workers and the accept loop on background threads; returns a
    /// handle with the bound address.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.shared.addr;
        for w in 0..self.shared.cfg.workers {
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("sadiff-worker-{w}"))
                .spawn(move || worker_loop(shared, w))
                .map_err(|e| Error::runtime(format!("spawn worker: {e}")))?;
        }
        let shared = self.shared.clone();
        let listener = self.listener;
        let accept_thread = std::thread::Builder::new()
            .name("sadiff-accept".into())
            .spawn(move || accept_loop(listener, shared))
            .map_err(|e| Error::runtime(format!("spawn accept: {e}")))?;
        crate::log_info!("server", "listening on {addr}");
        Ok(ServerHandle { addr, shared: self.shared, accept_thread: Some(accept_thread) })
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let _span = trace::span("accept", "server");
                let shared = shared.clone();
                let _ = std::thread::Builder::new()
                    .name("sadiff-conn".into())
                    .spawn(move || connection_loop(s, shared));
            }
            Err(e) => {
                crate::log_warn!("server", "accept error: {e}");
            }
        }
    }
}

fn connection_loop(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // Read raw lines (not `BufRead::lines`): a line that is not valid
    // UTF-8 must produce an `"error"` reply, not a silently dropped
    // connection. Only hard I/O errors (where no reply can be written
    // anyway) end the loop early.
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(_) => break,
        }
        let reply_line = match std::str::from_utf8(&buf) {
            Ok(line) if line.trim().is_empty() => continue,
            Ok(line) => handle_line(line.trim_end_matches(&['\r', '\n'][..]), &shared),
            Err(_) => SampleResponse::err(0, "request line is not valid utf-8").to_line(),
        };
        let wrote = {
            let _span = trace::span("response_write", "server");
            let t0 = Instant::now();
            let r = writer.write_all(format!("{reply_line}\n").as_bytes());
            shared
                .metrics
                .observe_stage(Stage::ResponseWrite, t0.elapsed().as_secs_f64() * 1e3);
            r
        };
        if wrote.is_err() {
            break;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    crate::log_debug!("server", "connection {peer} closed");
}

/// Handle one protocol line, returning the response line.
fn handle_line(line: &str, shared: &Arc<Shared>) -> String {
    let v = match parse(line) {
        Ok(v) => v,
        Err(e) => return SampleResponse::err(0, format!("bad json: {e}")).to_line(),
    };
    if let Some(cmd) = v.get("cmd").and_then(Value::as_str) {
        return match cmd {
            "stats" => to_string(&shared.metrics.snapshot()),
            "cancel" => match v.get("id").and_then(Value::as_u64) {
                None => SampleResponse::err(0, "cancel needs a numeric \"id\"").to_line(),
                Some(target) => handle_cancel(shared, target),
            },
            "presets" => match &shared.presets {
                Some(reg) => to_string(&reg.summary()),
                None => r#"{"ok":false,"error":"no preset registry loaded"}"#.to_string(),
            },
            "recover" => {
                let take = v.opt_bool("take", false);
                let mut q = shared.queue.lock().expect("queue lock");
                match v.get("id").and_then(Value::as_u64) {
                    Some(id) => {
                        let hit = if take {
                            q.recovered_results.remove(&id)
                        } else {
                            q.recovered_results.get(&id).cloned()
                        };
                        match hit {
                            Some(resp) => resp.to_line(),
                            None if q.recovered_clients.values().any(|c| *c == id) => {
                                format!(r#"{{"ok":false,"id":{id},"error":"recovery pending"}}"#)
                            }
                            None => SampleResponse::err(id, "no recovered result for this id")
                                .to_line(),
                        }
                    }
                    None => {
                        let mut ready: Vec<u64> = q.recovered_results.keys().copied().collect();
                        ready.sort_unstable();
                        to_string(&Value::obj(vec![
                            ("ok", Value::Bool(true)),
                            (
                                "ready",
                                Value::Array(
                                    ready.iter().map(|id| Value::Num(*id as f64)).collect(),
                                ),
                            ),
                            ("pending", Value::Num(q.recovered_clients.len() as f64)),
                        ]))
                    }
                }
            }
            "ping" => r#"{"ok":true}"#.to_string(),
            "snapshot" => handle_snapshot(shared),
            "migrate_out" => handle_migrate_out(shared, &v),
            "migrate_in" => handle_migrate_in(shared, &v),
            "trace" => handle_trace(shared, &v),
            "shutdown" => {
                shared.shutdown.store(true, Ordering::SeqCst);
                shared.cond.notify_all();
                // Unblock the accept loop so the thread actually exits
                // (nothing else may ever connect again).
                let _ = TcpStream::connect_timeout(&shared.addr, Duration::from_millis(250));
                r#"{"ok":true,"shutting_down":true}"#.to_string()
            }
            other => SampleResponse::err(0, format!("unknown cmd '{other}'")).to_line(),
        };
    }
    let mut request = match SampleRequest::from_json(&v) {
        Ok(r) => r,
        Err(e) => return SampleResponse::err(0, e.to_string()).to_line(),
    };
    // Resolve a preset to its concrete config *before* enqueueing: the
    // batcher then keys on the resolved config, so preset and manual
    // requests merge into the same group.
    if let Some(spec) = &request.preset {
        match &shared.presets {
            None => {
                return SampleResponse::err(
                    request.id,
                    format!("preset '{spec}' requested but no registry loaded (serve --presets)"),
                )
                .to_line()
            }
            Some(reg) => match reg.resolve(spec, &request.workload, request.cfg.nfe) {
                Ok(p) => request.cfg = p.cfg.clone(),
                Err(e) => return SampleResponse::err(request.id, e.to_string()).to_line(),
            },
        }
    }
    shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
    // Shed load if the queue is over capacity — by request count OR by
    // queued lanes. The lane check is what makes shedding width-aware: a
    // single n=100000 request occupies one queue slot but would otherwise
    // swamp every step budget behind it.
    let (tx, rx) = std::sync::mpsc::channel();
    let ticket;
    {
        let mut q = shared.queue.lock().expect("queue lock");
        let lane_cap = shared.cfg.effective_queue_lane_cap();
        let queued_lanes = q.batcher.queued_samples();
        // An empty queue always admits — like the worker's idle-admission
        // rule, an oversized single request must still run rather than be
        // unservable at any load.
        if q.batcher.len() >= shared.cfg.queue_cap
            || (queued_lanes > 0 && queued_lanes.saturating_add(request.n) > lane_cap)
        {
            shared.metrics.shed.fetch_add(1, Ordering::Relaxed);
            // Backoff hint: roughly how long the present backlog needs to
            // drain, in batching-deadline units per max_batch-sized group.
            let groups = (q.batcher.len() / shared.cfg.max_batch.max(1)) as u64;
            let retry = shared.cfg.batch_deadline_ms.max(1).saturating_mul(1 + groups);
            return SampleResponse::shed(request.id, retry).to_line();
        }
        ticket = q.next_ticket;
        q.next_ticket += 1;
        // The ticket rides in the request id slot internally; the original
        // id is restored when the response is routed back.
        let mut internal = request.clone();
        internal.id = ticket;
        q.replies.insert(ticket, tx);
        q.client_of.insert(ticket, request.id);
        q.batcher.push(internal);
        shared.metrics.set_queued_samples(q.batcher.queued_samples());
    }
    shared.cond.notify_one();
    let timeout = Duration::from_millis(shared.cfg.reply_timeout_ms.max(1));
    match rx.recv_timeout(timeout) {
        Ok(mut resp) => {
            resp.id = request.id;
            if resp.ok {
                shared.metrics.responses_ok.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.metrics.responses_err.fetch_add(1, Ordering::Relaxed);
            }
            shared.metrics.observe_latency_ms(resp.wall_ms);
            resp.to_line()
        }
        Err(_) => {
            // This connection is giving up: reclaim the ticket so its
            // lanes stop burning NFEs for a receiver that is gone. Queued →
            // remove outright; in flight → flag for the owning worker's
            // next step boundary (the existing cancel path).
            let mut q = shared.queue.lock().expect("queue lock");
            // The reply may have raced in between the timeout firing and
            // taking the lock — deliver it instead of cancelling.
            if let Ok(mut resp) = rx.try_recv() {
                drop(q);
                resp.id = request.id;
                if resp.ok {
                    shared.metrics.responses_ok.fetch_add(1, Ordering::Relaxed);
                } else {
                    shared.metrics.responses_err.fetch_add(1, Ordering::Relaxed);
                }
                shared.metrics.observe_latency_ms(resp.wall_ms);
                return resp.to_line();
            }
            q.replies.remove(&ticket);
            q.client_of.remove(&ticket);
            let removed = q.batcher.remove_where(|r| r.id == ticket);
            shared.metrics.set_queued_samples(q.batcher.queued_samples());
            if removed.is_empty() {
                // Not queued → in flight somewhere; the owning worker frees
                // the lanes at its next boundary (route_reply then finds no
                // receiver and drops the response).
                q.cancel_flags.insert(ticket);
            }
            drop(q);
            shared.cond.notify_all();
            shared.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
            shared.metrics.responses_err.fetch_add(1, Ordering::Relaxed);
            shared.metrics.observe_latency_ms(timeout.as_secs_f64() * 1e3);
            SampleResponse::typed_err(
                request.id,
                "timeout",
                format!("timeout after {} ms waiting for reply", timeout.as_millis()),
            )
            .to_line()
        }
    }
}

/// The `trace` protocol command: control the process-wide span recorder.
/// `start` clears previous captures and begins recording; `stop` freezes
/// the capture; `dump` exports it as Chrome Trace Event JSON — to the
/// command's `"path"`, else to `ServerConfig.trace_path`, else inline in
/// the reply under `"trace"`.
fn handle_trace(shared: &Arc<Shared>, v: &Value) -> String {
    let Some(action) = v.get("action").and_then(Value::as_str) else {
        return SampleResponse::err(0, "trace needs an \"action\" (start|stop|dump)").to_line();
    };
    match action {
        "start" => {
            trace::start();
            r#"{"ok":true,"tracing":true}"#.to_string()
        }
        "stop" => {
            trace::stop();
            r#"{"ok":true,"tracing":false}"#.to_string()
        }
        "dump" => {
            let path = v
                .get("path")
                .and_then(Value::as_str)
                .map(String::from)
                .or_else(|| shared.cfg.trace_path.clone());
            match path {
                Some(p) => match crate::obs::chrome::write_file(&p) {
                    Ok(events) => to_string(&Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("path", Value::Str(p)),
                        ("events", Value::Num(events as f64)),
                    ])),
                    Err(e) => SampleResponse::err(0, format!("trace dump: {e}")).to_line(),
                },
                None => {
                    let dump = crate::obs::chrome::export_current();
                    let spans = dump
                        .get("traceEvents")
                        .and_then(Value::as_array)
                        .map_or(0, |a| a.len());
                    to_string(&Value::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("events", Value::Num(spans as f64)),
                        ("trace", dump),
                    ]))
                }
            }
        }
        other => SampleResponse::err(0, format!("unknown trace action '{other}'")).to_line(),
    }
}

/// The `cancel` protocol command: cancel every queued or in-flight request
/// with client-visible id `target`. Queued requests are removed and
/// answered immediately; in-flight tickets are flagged for the owning
/// worker's next step boundary.
fn handle_cancel(shared: &Arc<Shared>, target: u64) -> String {
    let _span = trace::span("cancel", "server");
    let (queued, pending) = {
        let mut q = shared.queue.lock().expect("queue lock");
        // Both routing maps: fresh requests live in client_of, checkpoint-
        // recovered ones in recovered_clients (their connections died with
        // the previous process, but their lanes are just as cancellable).
        let tickets: Vec<u64> = q
            .client_of
            .iter()
            .chain(q.recovered_clients.iter())
            .filter(|(_, c)| **c == target)
            .map(|(t, _)| *t)
            .collect();
        let removed = q.batcher.remove_where(|r| tickets.contains(&r.id));
        shared.metrics.set_queued_samples(q.batcher.queued_samples());
        let removed_tickets: HashSet<u64> = removed.iter().map(|r| r.id).collect();
        for r in removed {
            shared.metrics.observe_cancel(0);
            route_reply(&mut q, SampleResponse::typed_err(r.id, "cancelled", "cancelled"));
        }
        let mut pending = 0usize;
        for t in &tickets {
            if !removed_tickets.contains(t) && q.cancel_flags.insert(*t) {
                pending += 1;
            }
        }
        (removed_tickets.len(), pending)
    };
    shared.cond.notify_all();
    format!(r#"{{"ok":true,"cancelled_queued":{queued},"cancel_pending":{pending}}}"#)
}

/// The `snapshot` protocol command: a heartbeat carrying load gauges plus
/// — when snapshotting is on (`checkpoint_path` or `publish_snapshots`) —
/// the current in-flight group checkpoints. The router polls this to
/// track worker load AND to hold each worker's last atomic checkpoint,
/// which is exactly what a crash failover re-assigns to a survivor.
fn handle_snapshot(shared: &Arc<Shared>) -> String {
    let publishing = shared.cfg.publish_snapshots || shared.cfg.checkpoint_path.is_some();
    // Lock order queue → sink, matching the checkpoint write path. The
    // reported set merges every worker's sink slice with the groups still
    // waiting to be (re)materialized, same as a checkpoint file write.
    let (queued_requests, queued_lanes, waiting) = {
        let q = shared.queue.lock().expect("queue lock");
        let waiting: Vec<GroupCheckpoint> =
            q.restored.iter().cloned().chain(q.restoring.values().cloned()).collect();
        (q.batcher.len(), q.batcher.queued_samples(), waiting)
    };
    let groups: Vec<Value> = if publishing {
        let sink = shared.checkpoint_sink.lock().expect("checkpoint sink lock");
        sink.values().flatten().cloned().chain(waiting).map(|g| g.to_json()).collect()
    } else {
        Vec::new()
    };
    let m = shared.metrics.snapshot();
    let gauge = |key: &str| Value::Num(m.req_f64(key).unwrap_or(0.0));
    to_string(&Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("publishing", Value::Bool(publishing)),
        ("queued_requests", Value::Num(queued_requests as f64)),
        ("queued_lanes", Value::Num(queued_lanes as f64)),
        ("inflight_groups", gauge("inflight_groups")),
        ("inflight_lanes", gauge("inflight_lanes")),
        ("steps", gauge("steps")),
        ("groups", Value::Array(groups)),
    ]))
}

/// The `migrate_out` protocol command: park a hand-over request for the
/// worker pool and wait until a worker detaches a matching in-flight
/// group at one of its step boundaries. The reply carries the detached
/// group's [`GroupCheckpoint`]; connections still waiting on the migrated
/// requests get typed `migrated` errors (the caller — normally the router
/// — owns delivering their results from the group's new home). A
/// `"client"` field restricts the pick to the group owning that
/// client-visible id; otherwise the widest group moves. Times out with a
/// typed `timeout` error when no worker claims the request.
fn handle_migrate_out(shared: &Arc<Shared>, v: &Value) -> String {
    let client = v.get("client").and_then(Value::as_u64);
    let timeout = Duration::from_millis(v.opt_usize("timeout_ms", 2000).max(1) as u64);
    let (tx, rx) = std::sync::mpsc::channel();
    let id = {
        let mut q = shared.queue.lock().expect("queue lock");
        let id = q.next_ticket;
        q.next_ticket += 1;
        q.migrate_outs.push(MigrateOut { id, client, tx });
        id
    };
    shared.cond.notify_all();
    let outcome = match rx.recv_timeout(timeout) {
        Ok(r) => Some(r),
        Err(_) => {
            // Withdraw under the queue lock. If the entry is already gone,
            // a worker claimed it — and because claimants send the result
            // while still holding this lock, it is in the channel by now.
            let mut q = shared.queue.lock().expect("queue lock");
            let before = q.migrate_outs.len();
            q.migrate_outs.retain(|m| m.id != id);
            let withdrawn = q.migrate_outs.len() < before;
            drop(q);
            if withdrawn {
                None
            } else {
                rx.try_recv().ok()
            }
        }
    };
    match outcome {
        Some(Ok(g)) => {
            let lanes: usize = g
                .group
                .get("requests")
                .and_then(Value::as_array)
                .map(|a| a.iter().map(|r| r.opt_usize("n", 1)).sum())
                .unwrap_or(0);
            to_string(&Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("requests", Value::Num(g.clients.len() as f64)),
                ("lanes", Value::Num(lanes as f64)),
                ("group", g.to_json()),
            ]))
        }
        Some(Err(msg)) => SampleResponse::err(0, msg).to_line(),
        None => SampleResponse::typed_err(
            0,
            "timeout",
            format!("no in-flight group matched within {} ms", timeout.as_millis()),
        )
        .to_line(),
    }
}

/// The `migrate_in` protocol command: accept a group another worker
/// detached. Its requests are re-ticketed into this server's ticket space
/// (the tickets inside the checkpoint belong to the source process and
/// could collide here), the original client-visible ids ride along in
/// `recovered_clients`, and the group resumes through the normal
/// checkpoint-recovery path — results land in the `recover` store under
/// those client ids, bit-identical to an uninterrupted run.
fn handle_migrate_in(shared: &Arc<Shared>, v: &Value) -> String {
    let Some(gv) = v.get("group") else {
        return SampleResponse::err(0, "migrate_in needs a \"group\" checkpoint").to_line();
    };
    let gck = match GroupCheckpoint::from_json(gv) {
        Ok(g) => g,
        Err(e) => return SampleResponse::err(0, format!("bad group checkpoint: {e}")).to_line(),
    };
    // Parse the checkpointed requests up front, outside the lock, so a
    // malformed group is rejected before any ticket state changes. The
    // snapshot's `requests` entries are plain request JSON, so a
    // parse → re-id → serialize round trip is lossless.
    let req_vals = match gck.group.get("requests").and_then(Value::as_array) {
        Some(a) if a.len() == gck.clients.len() && !a.is_empty() => a.clone(),
        Some(_) => return SampleResponse::err(0, "group requests/clients mismatch").to_line(),
        None => return SampleResponse::err(0, "group checkpoint has no requests").to_line(),
    };
    let mut requests = Vec::with_capacity(req_vals.len());
    for rv in &req_vals {
        match SampleRequest::from_json(rv) {
            Ok(r) => requests.push(r),
            Err(e) => {
                return SampleResponse::err(0, format!("bad request in group: {e}")).to_line()
            }
        }
    }
    let lanes: usize = requests.iter().map(|r| r.n).sum();
    let accepted = gck.clients.len();
    {
        let mut q = shared.queue.lock().expect("queue lock");
        let mut new_clients = Vec::with_capacity(requests.len());
        let mut new_req_json = Vec::with_capacity(requests.len());
        for (i, mut r) in requests.into_iter().enumerate() {
            let t = q.next_ticket;
            q.next_ticket += 1;
            r.id = t;
            let client = gck.clients[i].1;
            q.recovered_clients.insert(t, client);
            new_clients.push((t, client));
            new_req_json.push(r.to_json());
        }
        let mut group = gck.group.clone();
        set_field(&mut group, "requests", Value::Array(new_req_json));
        q.restored.push(GroupCheckpoint { group, clients: new_clients });
    }
    shared.cond.notify_all();
    shared.metrics.observe_migrated_in();
    format!(r#"{{"ok":true,"requests":{accepted},"lanes":{lanes}}}"#)
}

/// Replace (or insert) one field of a JSON object in place.
fn set_field(v: &mut Value, key: &str, val: Value) {
    if let Value::Object(fields) = v {
        for (k, slot) in fields.iter_mut() {
            if k == key {
                *slot = val;
                return;
            }
        }
        fields.push((key.to_string(), val));
    }
}

/// Worker: a step-synchronous scheduler over up to `max_inflight` lane
/// groups. Each loop iteration is one step boundary: admit newly queued
/// groups whose batching deadline has passed (or whose batch is full),
/// apply pending cancellations, then advance ONE group by ONE solver step
/// (round-robin). A request that arrives while a long solve is in flight
/// therefore starts making progress at the next boundary instead of
/// waiting for the drain — and its samples are identical either way,
/// because every lane draws from its own request-seeded Philox stream.
fn worker_loop(shared: Arc<Shared>, worker: usize) {
    let mut active: Vec<BatchRun> = Vec::new();
    let mut rr = 0usize;
    // Tolerate a programmatically-built config with max_inflight 0 (the
    // JSON/CLI ingress clamps, direct struct literals may not): 0 would
    // admit nothing and hang shutdown on a non-empty queue.
    let max_inflight = shared.cfg.max_inflight.max(1);
    let checkpointing = shared.cfg.checkpoint_path.is_some();
    // Snapshotting keeps the in-memory checkpoint sink fresh (what the
    // `snapshot` heartbeat reports); checkpointing additionally persists
    // it to the file.
    let snapshotting = checkpointing || shared.cfg.publish_snapshots;
    // Scheduler steps since this worker last wrote a checkpoint.
    let mut ckpt_steps = 0u64;
    loop {
        // Hard kill (simulated crash): abandon everything immediately —
        // no drain, no final checkpoint rewrite.
        if shared.abort.load(Ordering::SeqCst) {
            return;
        }
        // --- Step boundary bookkeeping under the queue lock.
        let mut admitted: Vec<Vec<Pending>> = Vec::new();
        let mut restored_take: Option<GroupCheckpoint> = None;
        let mut flagged: Vec<u64> = Vec::new();
        let mut drained = false;
        {
            let mut q = shared.queue.lock().expect("queue lock");
            loop {
                if shared.abort.load(Ordering::SeqCst) {
                    return;
                }
                let draining = shared.shutdown.load(Ordering::SeqCst);
                if draining
                    && q.batcher.is_empty()
                    && q.restored.is_empty()
                    && active.is_empty()
                    && admitted.is_empty()
                    && restored_take.is_none()
                {
                    drained = true;
                    break;
                }
                // Resume checkpointed groups ahead of fresh admissions —
                // they were already in flight before the restart. The
                // claimed group is parked in `restoring` so checkpoint
                // rewrites keep carrying it until this worker's own
                // in-flight slice does.
                if restored_take.is_none() && active.len() + admitted.len() < max_inflight {
                    if let Some(g) = q.restored.pop() {
                        q.restoring.insert(worker, g.clone());
                        restored_take = Some(g);
                    }
                }
                // Admit at most ONE ready group per boundary ("ready" =
                // full batch, aged past the batching deadline, or drain);
                // taking one at a time leaves further ready groups for
                // idle sibling workers (see the hand-off notify below)
                // instead of one worker hoarding the whole queue.
                let slots =
                    active.len() + admitted.len() + usize::from(restored_take.is_some());
                if slots < max_inflight && !q.batcher.is_empty() {
                    // Per-step lane budget: this worker's lanes already in
                    // flight (or admitted this boundary) plus the next
                    // group's seed must fit max_step_lanes. An idle worker
                    // always admits — an oversized request must still run.
                    let budget = if shared.cfg.max_step_lanes == 0 {
                        usize::MAX
                    } else {
                        shared.cfg.max_step_lanes
                    };
                    let active_lanes: usize = active.iter().map(|r| r.lanes()).sum::<usize>()
                        + admitted
                            .iter()
                            .flat_map(|g| g.iter())
                            .map(|p| p.request.n)
                            .sum::<usize>();
                    let lane_room = active_lanes == 0
                        || q
                            .batcher
                            .head_lanes()
                            .is_some_and(|n| active_lanes.saturating_add(n) <= budget);
                    let deadline = Duration::from_millis(shared.cfg.batch_deadline_ms);
                    let age = q.batcher.oldest_age().unwrap_or_default();
                    // Full-batch trigger on the *compatible head group*,
                    // not total queue length — a queue of mutually
                    // incompatible requests must not force-admit an
                    // undersized group before its deadline.
                    let ready = q.batcher.head_group_len() >= shared.cfg.max_batch
                        || age >= deadline
                        || draining;
                    if lane_room && ready {
                        let remaining = budget.saturating_sub(active_lanes);
                        let g = q.batcher.pop_group_pending(shared.cfg.max_batch, remaining);
                        if !g.is_empty() {
                            admitted.push(g);
                        }
                        // Hand any remaining queued work to an idle
                        // sibling worker.
                        if !q.batcher.is_empty() {
                            shared.cond.notify_one();
                        }
                    }
                }
                shared.metrics.set_queued_samples(q.batcher.queued_samples());
                if !admitted.is_empty() || restored_take.is_some() || !active.is_empty() {
                    break;
                }
                // Idle: wait for work, bounded so the deadline clock and
                // the shutdown flag are re-checked.
                let wait = match q.batcher.oldest_age() {
                    Some(age) => Duration::from_millis(shared.cfg.batch_deadline_ms)
                        .saturating_sub(age)
                        .max(Duration::from_millis(1)),
                    None => Duration::from_millis(50),
                };
                let (qq, _res) = shared.cond.wait_timeout(q, wait).expect("queue lock poisoned");
                q = qq;
            }
            // Claim the cancel flags that belong to this worker's groups.
            if !drained && !q.cancel_flags.is_empty() {
                for run in &active {
                    for t in run.tickets() {
                        if q.cancel_flags.remove(&t) {
                            flagged.push(t);
                        }
                    }
                }
            }
        }
        if drained {
            // Graceful drain with nothing in flight: leave an empty
            // checkpoint so a restart does not resurrect finished work.
            if snapshotting {
                checkpoint_boundary(&shared, worker, &active);
            }
            return;
        }
        // Whether the in-flight set changed at this boundary (admission,
        // recovery, cancellation, retirement) — those force a checkpoint
        // rewrite regardless of the periodic step counter.
        let mut set_changed = false;
        // --- Materialize a recovered group (model resolution + state
        // rebuild run outside the lock).
        if let Some(g) = restored_take {
            match restore_group(&shared, &g.group) {
                Ok(run) => {
                    shared.metrics.group_admitted(run.lanes());
                    shared.metrics.observe_recovered();
                    active.push(run);
                }
                Err(e) => {
                    // No connection to answer; park typed errors in the
                    // recover store so `recover` queries see the failure,
                    // and drop the claim — this group is not coming back.
                    let mut q = shared.queue.lock().expect("queue lock");
                    q.restoring.remove(&worker);
                    for (t, _) in &g.clients {
                        route_reply(
                            &mut q,
                            SampleResponse::err(*t, format!("recovery failed: {e}")),
                        );
                    }
                }
            }
            set_changed = true;
        }
        // --- Materialize admissions (model resolution + stepper warm-up
        // run outside the lock). Queue wait is attributed per request here
        // — enqueue-to-admission, measured from the batcher's arrival
        // stamp — then the merge + warm-up itself is the batch_merge stage.
        for g in admitted {
            let _span = trace::span("batch_merge", "server");
            let merge_t0 = Instant::now();
            let now = Instant::now();
            let mut group = Vec::with_capacity(g.len());
            let mut expired: Vec<u64> = Vec::new();
            for p in g {
                // Deadline-expired skip-and-reply: a request whose latency
                // budget already lapsed gets a typed `deadline` error
                // instead of burning NFEs on an answer nobody can use.
                if p.deadline.is_some_and(|d| now >= d) {
                    expired.push(p.request.id);
                    continue;
                }
                let wait_ms = p.arrived.elapsed().as_secs_f64() * 1e3;
                shared.metrics.observe_stage(Stage::QueueWait, wait_ms);
                if trace::is_enabled() {
                    let start = trace::now_us().saturating_sub((wait_ms * 1e3) as u64);
                    trace::record_since("queue_wait", "server", start);
                }
                group.push(p.request);
            }
            if !expired.is_empty() {
                shared.metrics.observe_deadline_miss(expired.len());
                let mut q = shared.queue.lock().expect("queue lock");
                for t in expired {
                    route_reply(
                        &mut q,
                        SampleResponse::typed_err(
                            t,
                            "deadline",
                            "deadline exceeded before admission",
                        ),
                    );
                }
            }
            if group.is_empty() {
                continue;
            }
            match admit_group(&shared, group) {
                Ok(run) => {
                    shared.metrics.group_admitted(run.lanes());
                    active.push(run);
                    set_changed = true;
                }
                Err(responses) => {
                    let mut q = shared.queue.lock().expect("queue lock");
                    for resp in responses {
                        route_reply(&mut q, resp);
                    }
                }
            }
            shared
                .metrics
                .observe_stage(Stage::BatchMerge, merge_t0.elapsed().as_secs_f64() * 1e3);
        }
        // --- Apply cancellations at this step boundary.
        for t in flagged {
            let _span = trace::span("cancel", "server");
            for run in active.iter_mut() {
                let before = run.lanes();
                if let Some(resp) = run.cancel(t) {
                    shared.metrics.observe_cancel(before - run.lanes());
                    let mut q = shared.queue.lock().expect("queue lock");
                    route_reply(&mut q, resp);
                    set_changed = true;
                    break;
                }
            }
        }
        // --- Serve a pending migrate-out at this step boundary. This runs
        // after the cancel block so a fresh cancel cannot slip between the
        // claim and the snapshot; the claim itself re-checks the flags.
        if !active.is_empty() {
            set_changed |= serve_migrate_out(&shared, &mut active);
        }
        // --- Advance one group by one solver step (round-robin).
        if active.is_empty() {
            if snapshotting && set_changed {
                checkpoint_boundary(&shared, worker, &active);
                ckpt_steps = 0;
            }
            continue;
        }
        if rr >= active.len() {
            rr = 0;
        }
        // A group whose last request was cancelled is already done —
        // retire it without counting a phantom scheduler step.
        let was_done = active[rr].is_done();
        let step_t0 = Instant::now();
        let done = {
            let _span = trace::span("step", "server");
            active[rr].step(&shared.exec)
        };
        if !was_done {
            shared
                .metrics
                .observe_stage(Stage::SolverStep, step_t0.elapsed().as_secs_f64() * 1e3);
            shared.metrics.observe_stage(Stage::ModelEval, active[rr].last_eval_ms());
            shared.metrics.observe_step(active[rr].lanes());
            ckpt_steps += 1;
        }
        if done {
            let run = active.swap_remove(rr);
            shared.metrics.group_retired(run.lanes());
            let total = run.lanes();
            let responses = run.finish();
            if !responses.is_empty() {
                let nfe = responses.first().map(|r| r.nfe).unwrap_or(0);
                shared.metrics.observe_batch(responses.len(), total, nfe);
            }
            let mut q = shared.queue.lock().expect("queue lock");
            for resp in responses {
                route_reply(&mut q, resp);
            }
            set_changed = true;
        } else {
            rr += 1;
        }
        if snapshotting && (set_changed || ckpt_steps >= shared.cfg.checkpoint_every) {
            checkpoint_boundary(&shared, worker, &active);
            ckpt_steps = 0;
        }
    }
}

/// Serve one pending `migrate_out` request from this worker's in-flight
/// set, if any matches. Everything happens under one queue-lock hold so
/// the hand-over is atomic with respect to cancels, replies, and the
/// requesting connection's timeout withdrawal:
///
/// 1. pick the first claimable request (its `client` owned by one of our
///    groups, or unrestricted — then the widest group moves);
/// 2. apply any pending cancel flags for the chosen group FIRST, so the
///    snapshot never carries a cancelled-elsewhere lane (and the lane
///    cannot be dropped a second time at the destination);
/// 3. snapshot and send the checkpoint while still holding the lock — on
///    send failure (requester gone) the group simply stays here;
/// 4. on success, detach the group: typed `migrated` errors to waiting
///    connections, and every routing-map entry for its tickets purged —
///    including `recovered_results`, so a later `recover` poll on this
///    worker cannot serve a stale entry for a group that lives elsewhere.
///
/// Returns whether the in-flight set changed (forces a sink rewrite).
fn serve_migrate_out(shared: &Arc<Shared>, active: &mut Vec<BatchRun>) -> bool {
    let mut q = shared.queue.lock().expect("queue lock");
    if q.migrate_outs.is_empty() {
        return false;
    }
    let mut claim: Option<(usize, usize)> = None;
    for (mi, m) in q.migrate_outs.iter().enumerate() {
        let run_idx = match m.client {
            None => active
                .iter()
                .enumerate()
                .filter(|(_, r)| !r.is_done())
                .max_by_key(|(_, r)| r.lanes())
                .map(|(i, _)| i),
            Some(c) => active.iter().position(|r| {
                !r.is_done()
                    && r.tickets().iter().any(|t| {
                        q.client_of.get(t).or_else(|| q.recovered_clients.get(t)) == Some(&c)
                    })
            }),
        };
        if let Some(ri) = run_idx {
            claim = Some((mi, ri));
            break;
        }
    }
    let Some((mi, ri)) = claim else {
        return false;
    };
    let m = q.migrate_outs.remove(mi);
    // Cancel-before-snapshot: flags racing this boundary are applied to
    // the chosen group now, exactly as the worker's cancel block would.
    let mut changed = false;
    for t in active[ri].tickets() {
        if q.cancel_flags.remove(&t) {
            let before = active[ri].lanes();
            if let Some(resp) = active[ri].cancel(t) {
                shared.metrics.observe_cancel(before - active[ri].lanes());
                route_reply(&mut q, resp);
                changed = true;
            }
        }
    }
    let tickets = active[ri].tickets();
    if tickets.is_empty() {
        // Every request was cancelled at this boundary; retire the group
        // instead of shipping an empty checkpoint.
        let run = active.swap_remove(ri);
        shared.metrics.group_retired(run.lanes());
        let _ = m.tx.send(Err("group emptied by cancellation at the boundary".into()));
        return true;
    }
    let clients: Vec<(u64, u64)> = tickets
        .iter()
        .map(|t| {
            let client = q
                .client_of
                .get(t)
                .or_else(|| q.recovered_clients.get(t))
                .copied()
                .unwrap_or(*t);
            (*t, client)
        })
        .collect();
    let gck = GroupCheckpoint { group: active[ri].snapshot(), clients };
    if m.tx.send(Ok(gck)).is_err() {
        // The requesting connection withdrew (timeout) or died before we
        // committed; the group keeps running here, nothing was detached.
        return changed;
    }
    let run = active.swap_remove(ri);
    shared.metrics.group_retired(run.lanes());
    shared.metrics.observe_migrated_out();
    for t in run.tickets() {
        q.client_of.remove(&t);
        q.cancel_flags.remove(&t);
        if let Some(tx) = q.replies.remove(&t) {
            let _ = tx.send(SampleResponse::typed_err(
                t,
                "migrated",
                "request migrated to another worker",
            ));
        }
        if let Some(client) = q.recovered_clients.remove(&t) {
            q.recovered_results.remove(&client);
        }
    }
    true
}

/// Rewrite this worker's slice of the in-memory checkpoint sink —
/// snapshotting every in-flight group at the current step boundary — and,
/// when `checkpoint_path` is set, merge all slices and atomically replace
/// the checkpoint file. With `publish_snapshots` but no path, the sink
/// alone stays fresh for the `snapshot` heartbeat. Lock order is queue →
/// sink; nothing takes them in the other order.
fn checkpoint_boundary(shared: &Arc<Shared>, worker: usize, active: &[BatchRun]) {
    let live: Vec<&BatchRun> = active.iter().filter(|r| !r.is_done()).collect();
    // Ticket → client-id maps under the queue lock; the (pure CPU) state
    // snapshots and the file write happen outside it. The same lock visit
    // retires this worker's `restoring` claim (its group is in `active`
    // now, so this write's slice carries it) and collects every restored
    // group no worker has materialized yet — those must keep riding in the
    // file or a second crash would silently drop the resume backlog.
    let (client_maps, waiting): (Vec<Vec<(u64, u64)>>, Vec<GroupCheckpoint>) = {
        let mut q = shared.queue.lock().expect("queue lock");
        q.restoring.remove(&worker);
        let maps = live
            .iter()
            .map(|r| {
                r.tickets()
                    .iter()
                    .map(|t| {
                        let client = q
                            .client_of
                            .get(t)
                            .or_else(|| q.recovered_clients.get(t))
                            .copied()
                            .unwrap_or(*t);
                        (*t, client)
                    })
                    .collect()
            })
            .collect();
        let waiting =
            q.restored.iter().cloned().chain(q.restoring.values().cloned()).collect();
        (maps, waiting)
    };
    let groups: Vec<GroupCheckpoint> = live
        .iter()
        .zip(client_maps)
        .map(|(r, clients)| GroupCheckpoint { group: r.snapshot(), clients })
        .collect();
    let mut sink = shared.checkpoint_sink.lock().expect("checkpoint sink lock");
    sink.insert(worker, groups);
    let Some(path) = shared.cfg.checkpoint_path.as_deref() else {
        return; // snapshot-publishing only: the sink is the product
    };
    let merged = ServerCheckpoint {
        groups: sink.values().flatten().cloned().chain(waiting).collect(),
    };
    let ckpt_t0 = Instant::now();
    match merged.save(path) {
        Ok(()) => {
            shared.metrics.observe_checkpoint();
            shared
                .metrics
                .observe_stage(Stage::CheckpointWrite, ckpt_t0.elapsed().as_secs_f64() * 1e3);
        }
        Err(e) => crate::log_warn!("server", "checkpoint write failed: {e}"),
    }
}

/// Rebuild a checkpointed group as an in-flight [`BatchRun`], resolving its
/// workload + model exactly as fresh admission does.
fn restore_group(shared: &Arc<Shared>, group: &Value) -> Result<BatchRun> {
    let model_name = group
        .get("requests")
        .and_then(Value::as_array)
        .and_then(|a| a.first())
        .map(|r| r.opt_str("model", "gmm").to_string())
        .unwrap_or_else(|| "gmm".to_string());
    let model: Arc<dyn ModelEval> = if let Some(name) = model_name.strip_prefix("artifact:") {
        Arc::from(artifact_model(shared, name)?)
    } else {
        let wl_name = group.req_str("workload")?;
        let wl = workloads::by_name(wl_name)
            .ok_or_else(|| Error::protocol(format!("unknown workload '{wl_name}'")))?;
        Arc::from(wl.model())
    };
    BatchRun::restore(group, model, &shared.exec)
}

/// Resolve a group's workload + model and admit it as an in-flight
/// [`BatchRun`] (runs the steppers' warm-up evaluations); on resolution
/// failure, an error response per member.
fn admit_group(
    shared: &Arc<Shared>,
    group: Vec<SampleRequest>,
) -> std::result::Result<BatchRun, Vec<SampleResponse>> {
    let first = &group[0];
    let Some(wl) = workloads::by_name(&first.workload) else {
        let msg = format!("unknown workload '{}'", first.workload);
        return Err(group.iter().map(|r| SampleResponse::err(r.id, msg.clone())).collect());
    };
    let model: Arc<dyn ModelEval> = if let Some(name) = first.model.strip_prefix("artifact:") {
        match artifact_model(shared, name) {
            Ok(m) => Arc::from(m),
            Err(e) => {
                return Err(group
                    .iter()
                    .map(|r| SampleResponse::err(r.id, e.to_string()))
                    .collect())
            }
        }
    } else {
        Arc::from(wl.model())
    };
    let cfg = first.cfg.clone();
    Ok(BatchRun::new(model, &wl, &cfg, group, &shared.exec))
}

/// Resolve an artifact-backed model through the lazily started runtime host.
fn artifact_model(shared: &Arc<Shared>, name: &str) -> Result<Box<dyn ModelEval>> {
    let mut guard = shared.runtime.lock().expect("runtime lock");
    if guard.is_none() {
        *guard = Some(RuntimeHost::open_default()?);
    }
    let host = guard.as_ref().unwrap().clone();
    drop(guard);
    Ok(Box::new(HloModel::from_manifest(host, name)?))
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a serving address (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::runtime(format!("connect {addr}: {e}")))?;
        let writer = stream
            .try_clone()
            .map_err(|e| Error::runtime(format!("clone stream: {e}")))?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one line, read one line.
    pub fn round_trip(&mut self, line: &str) -> Result<String> {
        self.writer
            .write_all(format!("{line}\n").as_bytes())
            .map_err(Error::Io)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf).map_err(Error::Io)?;
        Ok(buf.trim_end().to_string())
    }

    /// Submit a sampling request and wait for its response.
    pub fn request(&mut self, req: &SampleRequest) -> Result<SampleResponse> {
        let line = self.round_trip(&req.to_line())?;
        SampleResponse::from_json(&parse(&line)?)
    }

    /// Fetch the `stats` metrics snapshot.
    pub fn stats(&mut self) -> Result<Value> {
        let line = self.round_trip(r#"{"cmd":"stats"}"#)?;
        parse(&line)
    }

    /// Cancel every queued or in-flight request with client-visible `id`.
    /// The reply reports how many were removed from the queue and how many
    /// were flagged for their owning worker's next step boundary.
    pub fn cancel(&mut self, id: u64) -> Result<Value> {
        let line = self.round_trip(&cancel_line(id))?;
        parse(&line)
    }

    /// Control the server's span recorder: `action` is `"start"`, `"stop"`
    /// or `"dump"`; `path` overrides the server's default dump path for a
    /// `dump`. Returns the server's JSON reply.
    pub fn trace(&mut self, action: &str, path: Option<&str>) -> Result<Value> {
        let mut fields = vec![
            ("cmd", Value::Str("trace".into())),
            ("action", Value::Str(action.into())),
        ];
        if let Some(p) = path {
            fields.push(("path", Value::Str(p.into())));
        }
        let line = self.round_trip(&to_string(&Value::obj(fields)))?;
        parse(&line)
    }

    /// Query the recover store: results of solves that were resumed from a
    /// checkpoint after a restart (their original connections died with the
    /// previous process). `None` lists ready ids + the pending count;
    /// `Some(id)` fetches one recovered response.
    pub fn recover(&mut self, id: Option<u64>) -> Result<Value> {
        let line = match id {
            Some(id) => format!(r#"{{"cmd":"recover","id":{id}}}"#),
            None => r#"{"cmd":"recover"}"#.to_string(),
        };
        let reply = self.round_trip(&line)?;
        parse(&reply)
    }

    /// Fetch AND remove one recovered response (`recover` with
    /// `"take": true`) — the router's exactly-once result fetch.
    pub fn recover_take(&mut self, id: u64) -> Result<Value> {
        let reply = self.round_trip(&format!(r#"{{"cmd":"recover","id":{id},"take":true}}"#))?;
        parse(&reply)
    }

    /// Fetch the `snapshot` heartbeat: load gauges plus the in-flight
    /// group checkpoints (when the server publishes snapshots).
    pub fn snapshot(&mut self) -> Result<Value> {
        let line = self.round_trip(r#"{"cmd":"snapshot"}"#)?;
        parse(&line)
    }

    /// Ask the server to detach one in-flight group at a step boundary
    /// (`client` restricts the pick to the group owning that id). Returns
    /// the raw reply; on success its `"group"` field is the checkpoint to
    /// hand to [`Client::migrate_in`] on another server.
    pub fn migrate_out(&mut self, client: Option<u64>, timeout_ms: u64) -> Result<Value> {
        let mut fields = vec![
            ("cmd", Value::Str("migrate_out".into())),
            ("timeout_ms", Value::Num(timeout_ms as f64)),
        ];
        if let Some(c) = client {
            fields.push(("client", Value::Num(c as f64)));
        }
        let line = self.round_trip(&to_string(&Value::obj(fields)))?;
        parse(&line)
    }

    /// Hand a migrated group checkpoint to this server; it resumes through
    /// the recovery path and its results become `recover`-able under the
    /// client ids the checkpoint carries.
    pub fn migrate_in(&mut self, group: &GroupCheckpoint) -> Result<Value> {
        let line = to_string(&Value::obj(vec![
            ("cmd", Value::Str("migrate_in".into())),
            ("group", group.to_json()),
        ]));
        let reply = self.round_trip(&line)?;
        parse(&reply)
    }
}
