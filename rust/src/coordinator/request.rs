//! Request/response types and their JSON wire forms.

use crate::config::SamplerConfig;
use crate::jsonlite::{to_string, Value};
use crate::util::error::{Error, Result};

/// A sampling request.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleRequest {
    /// Client-visible id (rides the internal routing ticket while queued).
    pub id: u64,
    /// Workload name (`workloads::by_name`) — fixes schedule + reference
    /// distribution.
    pub workload: String,
    /// Model selector: "gmm" (exact analytic model) or `artifact:<name>`
    /// (PJRT artifact from the registry).
    pub model: String,
    /// Solver configuration (grid, orders, τ, …).
    pub cfg: SamplerConfig,
    /// Samples requested.
    pub n: usize,
    /// Philox seed keying this request's noise streams.
    pub seed: u64,
    /// Include raw samples in the response (large!).
    pub return_samples: bool,
    /// Compute distribution metrics vs. the workload reference.
    pub want_metrics: bool,
    /// Tuner preset to run instead of `cfg`: `"auto"` (resolve by workload
    /// + nearest NFE budget) or an exact preset name. Resolved at server
    /// ingress against the loaded registry — the resolved concrete config
    /// replaces `cfg`, so preset and manual requests batch together.
    pub preset: Option<String>,
    /// Latency budget in milliseconds, measured from enqueue. A request
    /// still queued when its budget expires is answered with a typed
    /// `deadline` error at the next admission boundary instead of running.
    /// `None` means no deadline.
    pub deadline_ms: Option<u64>,
    /// Scheduling priority (higher is more urgent; default 0). The batcher
    /// seeds group extraction with the best (priority, deadline, arrival)
    /// request and orders members of an oversubscribed compatibility group
    /// the same way, so priority never affects *which* samples a request
    /// gets — only when it runs.
    pub priority: i64,
}

impl SampleRequest {
    /// Parse a protocol request object; missing fields take defaults.
    pub fn from_json(v: &Value) -> Result<SampleRequest> {
        let cfg = match v.get("solver") {
            Some(sv) => SamplerConfig::from_json(sv)?,
            None => SamplerConfig::sa_default(),
        };
        let n = v.opt_usize("n", 16);
        if n == 0 || n > 100_000 {
            return Err(Error::protocol(format!("n={n} out of range")));
        }
        Ok(SampleRequest {
            id: v.opt_usize("id", 0) as u64,
            workload: v.opt_str("workload", "latent_analog").to_string(),
            model: v.opt_str("model", "gmm").to_string(),
            cfg,
            n,
            seed: v.get("seed").and_then(Value::as_u64).unwrap_or(0),
            return_samples: v.opt_bool("return_samples", false),
            want_metrics: v.opt_bool("metrics", false),
            preset: v.get("preset").and_then(Value::as_str).map(String::from),
            deadline_ms: v.get("deadline_ms").and_then(Value::as_u64),
            priority: v.get("priority").and_then(Value::as_f64).map_or(0, |p| p as i64),
        })
    }

    /// Serialize to the protocol wire object.
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("id", Value::Num(self.id as f64)),
            ("workload", Value::Str(self.workload.clone())),
            ("model", Value::Str(self.model.clone())),
            ("solver", self.cfg.to_json()),
            ("n", Value::Num(self.n as f64)),
            ("seed", Value::Num(self.seed as f64)),
            ("return_samples", Value::Bool(self.return_samples)),
            ("metrics", Value::Bool(self.want_metrics)),
        ];
        if let Some(p) = &self.preset {
            fields.push(("preset", Value::Str(p.clone())));
        }
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Value::Num(d as f64)));
        }
        if self.priority != 0 {
            fields.push(("priority", Value::Num(self.priority as f64)));
        }
        Value::obj(fields)
    }

    /// One protocol line (JSON, no trailing newline).
    pub fn to_line(&self) -> String {
        to_string(&self.to_json())
    }
}

/// Wire line for the `cancel` protocol command: cancels every queued or
/// in-flight request whose client-visible id equals `id` (the server
/// replies to each cancelled request's own connection with
/// `{"error":"cancelled"}`).
pub fn cancel_line(id: u64) -> String {
    format!(r#"{{"cmd":"cancel","id":{id}}}"#)
}

/// A sampling response.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleResponse {
    /// Echo of the request id (ticket internally, client id on the wire).
    pub id: u64,
    /// Whether the solve completed.
    pub ok: bool,
    /// Error message when `ok` is false.
    pub error: Option<String>,
    /// Typed error kind when the failure is one the protocol classifies
    /// (`shed` / `deadline` / `timeout` / `cancelled`); `None` for untyped
    /// errors. On the wire a typed error serializes as an object
    /// (`{"error":{"kind":...,"message":...}}`), an untyped one as the
    /// legacy plain string.
    pub kind: Option<String>,
    /// Backoff hint carried by `shed` replies, in milliseconds.
    pub retry_after_ms: Option<u64>,
    /// Lanes produced.
    pub n: usize,
    /// Data dimension per lane.
    pub dim: usize,
    /// Model evaluations spent on the solve.
    pub nfe: usize,
    /// Wall-clock milliseconds of the (possibly batched) solve.
    pub wall_ms: f64,
    /// Distribution metric vs the workload reference, when requested.
    pub sim_fid: Option<f64>,
    /// Sliced-Wasserstein-2 vs the workload reference, when requested.
    pub sliced_w2: Option<f64>,
    /// Raw samples (row-major `n × dim`), when requested.
    pub samples: Option<Vec<f64>>,
}

impl SampleResponse {
    /// An error response carrying only `id` and the message.
    pub fn err(id: u64, msg: impl Into<String>) -> SampleResponse {
        SampleResponse {
            id,
            ok: false,
            error: Some(msg.into()),
            kind: None,
            retry_after_ms: None,
            n: 0,
            dim: 0,
            nfe: 0,
            wall_ms: 0.0,
            sim_fid: None,
            sliced_w2: None,
            samples: None,
        }
    }

    /// A typed error response: `kind` is one of the protocol's classified
    /// failure kinds (`shed` / `deadline` / `timeout` / `cancelled`).
    pub fn typed_err(id: u64, kind: &str, msg: impl Into<String>) -> SampleResponse {
        SampleResponse { kind: Some(kind.to_string()), ..SampleResponse::err(id, msg) }
    }

    /// A `shed` reply with its backoff hint: the server is over capacity
    /// and the client should retry after roughly `retry_after_ms`.
    pub fn shed(id: u64, retry_after_ms: u64) -> SampleResponse {
        SampleResponse {
            retry_after_ms: Some(retry_after_ms),
            ..SampleResponse::typed_err(id, "shed", "overloaded: queue full")
        }
    }

    /// Serialize to the protocol wire object (optional fields omitted).
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("id", Value::Num(self.id as f64)),
            ("ok", Value::Bool(self.ok)),
            ("n", Value::Num(self.n as f64)),
            ("dim", Value::Num(self.dim as f64)),
            ("nfe", Value::Num(self.nfe as f64)),
            ("wall_ms", Value::Num(self.wall_ms)),
        ];
        if let Some(k) = &self.kind {
            let mut e = vec![("kind", Value::Str(k.clone()))];
            if let Some(m) = &self.error {
                e.push(("message", Value::Str(m.clone())));
            }
            if let Some(r) = self.retry_after_ms {
                e.push(("retry_after_ms", Value::Num(r as f64)));
            }
            fields.push(("error", Value::obj(e)));
        } else if let Some(e) = &self.error {
            fields.push(("error", Value::Str(e.clone())));
        }
        if let Some(f) = self.sim_fid {
            fields.push(("sim_fid", Value::Num(f)));
        }
        if let Some(w) = self.sliced_w2 {
            fields.push(("sliced_w2", Value::Num(w)));
        }
        if let Some(s) = &self.samples {
            fields.push(("samples", Value::arr_f64(s)));
        }
        Value::obj(fields)
    }

    /// Parse a protocol response object. Accepts both error wire forms:
    /// the legacy plain string and the typed object
    /// (`{"kind":...,"message":...,"retry_after_ms":...}`).
    pub fn from_json(v: &Value) -> Result<SampleResponse> {
        let (error, kind, retry_after_ms) = match v.get("error") {
            Some(Value::Str(s)) => (Some(s.clone()), None, None),
            Some(e @ Value::Object(_)) => (
                e.get("message").and_then(Value::as_str).map(String::from),
                e.get("kind").and_then(Value::as_str).map(String::from),
                e.get("retry_after_ms").and_then(Value::as_u64),
            ),
            _ => (None, None, None),
        };
        Ok(SampleResponse {
            id: v.opt_usize("id", 0) as u64,
            ok: v.opt_bool("ok", false),
            error,
            kind,
            retry_after_ms,
            n: v.opt_usize("n", 0),
            dim: v.opt_usize("dim", 0),
            nfe: v.opt_usize("nfe", 0),
            wall_ms: v.opt_f64("wall_ms", 0.0),
            sim_fid: v.get("sim_fid").and_then(Value::as_f64),
            sliced_w2: v.get("sliced_w2").and_then(Value::as_f64),
            samples: v.get("samples").and_then(Value::as_array).map(|a| {
                a.iter().filter_map(Value::as_f64).collect()
            }),
        })
    }

    /// One protocol line (JSON, no trailing newline).
    pub fn to_line(&self) -> String {
        to_string(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite;

    #[test]
    fn request_roundtrip() {
        let r = SampleRequest {
            id: 42,
            workload: "cifar_analog".into(),
            model: "gmm".into(),
            cfg: SamplerConfig::sa_default(),
            n: 8,
            seed: 7,
            return_samples: true,
            want_metrics: true,
            preset: None,
            deadline_ms: None,
            priority: 0,
        };
        let parsed = SampleRequest::from_json(&jsonlite::parse(&r.to_line()).unwrap()).unwrap();
        assert_eq!(r, parsed);
    }

    #[test]
    fn request_deadline_priority_roundtrip() {
        let v = jsonlite::parse(r#"{"n": 4, "deadline_ms": 250, "priority": -3}"#).unwrap();
        let r = SampleRequest::from_json(&v).unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        assert_eq!(r.priority, -3);
        let reparsed = SampleRequest::from_json(&jsonlite::parse(&r.to_line()).unwrap()).unwrap();
        assert_eq!(r, reparsed);
        // Defaults stay off the wire.
        let plain = SampleRequest { deadline_ms: None, priority: 0, ..r };
        assert!(!plain.to_line().contains("deadline_ms"));
        assert!(!plain.to_line().contains("priority"));
    }

    #[test]
    fn request_preset_roundtrip() {
        let v = jsonlite::parse(r#"{"n": 4, "preset": "auto"}"#).unwrap();
        let r = SampleRequest::from_json(&v).unwrap();
        assert_eq!(r.preset.as_deref(), Some("auto"));
        let reparsed = SampleRequest::from_json(&jsonlite::parse(&r.to_line()).unwrap()).unwrap();
        assert_eq!(r, reparsed);
        // Absent field stays absent on the wire.
        let r2 = SampleRequest { preset: None, ..r };
        assert!(!r2.to_line().contains("preset"));
    }

    #[test]
    fn request_defaults() {
        let v = jsonlite::parse(r#"{"id": 1, "n": 4}"#).unwrap();
        let r = SampleRequest::from_json(&v).unwrap();
        assert_eq!(r.workload, "latent_analog");
        assert_eq!(r.model, "gmm");
        assert!(!r.return_samples);
        assert_eq!(r.preset, None);
        assert_eq!(r.deadline_ms, None);
        assert_eq!(r.priority, 0);
    }

    #[test]
    fn request_rejects_bad_n() {
        for bad in [r#"{"n": 0}"#, r#"{"n": 1000000}"#] {
            let v = jsonlite::parse(bad).unwrap();
            assert!(SampleRequest::from_json(&v).is_err());
        }
    }

    #[test]
    fn cancel_line_is_valid_protocol_json() {
        let v = jsonlite::parse(&cancel_line(42)).unwrap();
        assert_eq!(v.opt_str("cmd", ""), "cancel");
        assert_eq!(v.get("id").and_then(Value::as_u64), Some(42));
    }

    #[test]
    fn response_roundtrip() {
        let r = SampleResponse {
            id: 3,
            ok: true,
            error: None,
            kind: None,
            retry_after_ms: None,
            n: 2,
            dim: 2,
            nfe: 20,
            wall_ms: 1.5,
            sim_fid: Some(3.3),
            sliced_w2: None,
            samples: Some(vec![1.0, 2.0, 3.0, 4.0]),
        };
        let parsed = SampleResponse::from_json(&jsonlite::parse(&r.to_line()).unwrap()).unwrap();
        assert_eq!(r, parsed);
    }

    #[test]
    fn error_response() {
        let r = SampleResponse::err(9, "boom");
        assert!(!r.ok);
        assert!(r.to_line().contains("boom"));
        // Untyped errors keep the legacy string wire form.
        assert!(r.to_line().contains(r#""error":"boom""#));
    }

    #[test]
    fn typed_error_roundtrip() {
        let r = SampleResponse::shed(4, 37);
        let line = r.to_line();
        assert!(line.contains(r#""kind":"shed""#), "{line}");
        assert!(line.contains(r#""retry_after_ms":37"#), "{line}");
        let parsed = SampleResponse::from_json(&jsonlite::parse(&line).unwrap()).unwrap();
        assert_eq!(r, parsed);
        assert_eq!(parsed.kind.as_deref(), Some("shed"));
        assert_eq!(parsed.retry_after_ms, Some(37));
        // The message stays accessible the old way.
        assert_eq!(parsed.error.as_deref(), Some("overloaded: queue full"));

        let d = SampleResponse::typed_err(5, "deadline", "deadline exceeded before admission");
        let parsed = SampleResponse::from_json(&jsonlite::parse(&d.to_line()).unwrap()).unwrap();
        assert_eq!(d, parsed);
        assert_eq!(parsed.retry_after_ms, None);
    }

    #[test]
    fn legacy_string_error_still_parses() {
        let v = jsonlite::parse(r#"{"id": 7, "ok": false, "error": "cancelled"}"#).unwrap();
        let r = SampleResponse::from_json(&v).unwrap();
        assert_eq!(r.error.as_deref(), Some("cancelled"));
        assert_eq!(r.kind, None);
        assert_eq!(r.retry_after_ms, None);
    }
}
