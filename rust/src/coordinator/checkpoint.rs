//! The serving checkpoint file: the server's in-flight set, serialized at
//! step boundaries so a crashed or preempted process can be restarted and
//! every interrupted solve resumed bit-identically.
//!
//! Wire shape (schema_version 1 — the registry.rs provenance pattern
//! applied to checkpoints):
//! ```json
//! {
//!   "schema_version": 1,
//!   "created_by": "sadiff 0.1.0",
//!   "groups": [
//!     {"tickets": ["0000000000000001"], "clients": ["00000000000004d2"],
//!      "group": { ...engine::BatchRun::snapshot()... }}
//!   ]
//! }
//! ```
//!
//! Tickets are the server's internal reply ids; `clients[i]` is the
//! client-visible id of `tickets[i]`. Both are serialized as hex (JSON
//! numbers are f64 here and cannot hold every u64). Writes go through a
//! temp file + atomic rename, so a crash mid-write leaves the previous
//! complete checkpoint in place, never a torn file.

use crate::jsonlite::{to_string, Value};
use crate::solvers::snapshot::{check_schema_version, hex_u64_array, u64_to_hex};
use crate::util::error::{Error, Result};

/// One checkpointed in-flight group: the engine-level batch snapshot plus
/// the ticket → client-id pairs its replies route through.
#[derive(Debug, Clone)]
pub struct GroupCheckpoint {
    /// `engine::BatchRun::snapshot()` value.
    pub group: Value,
    /// `(ticket, client_id)` per surviving request, in ticket order.
    pub clients: Vec<(u64, u64)>,
}

impl GroupCheckpoint {
    /// Serialize to the wire form (hex-encoded u64 id lists).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "tickets",
                Value::Array(
                    self.clients.iter().map(|(t, _)| Value::Str(u64_to_hex(*t))).collect(),
                ),
            ),
            (
                "clients",
                Value::Array(
                    self.clients.iter().map(|(_, c)| Value::Str(u64_to_hex(*c))).collect(),
                ),
            ),
            ("group", self.group.clone()),
        ])
    }

    /// Parse the wire form; ticket/client lists must align.
    pub fn from_json(v: &Value) -> Result<GroupCheckpoint> {
        let tickets = hex_u64_array(v, "tickets")?;
        let clients = hex_u64_array(v, "clients")?;
        if tickets.len() != clients.len() {
            return Err(Error::config(format!(
                "checkpoint group has {} tickets but {} client ids",
                tickets.len(),
                clients.len()
            )));
        }
        let group = v
            .get("group")
            .cloned()
            .ok_or_else(|| Error::config("checkpoint group missing 'group'"))?;
        Ok(GroupCheckpoint { group, clients: tickets.into_iter().zip(clients).collect() })
    }
}

/// A whole serving checkpoint: every worker's in-flight groups.
#[derive(Debug, Clone, Default)]
pub struct ServerCheckpoint {
    /// Every worker's in-flight groups, in no particular order.
    pub groups: Vec<GroupCheckpoint>,
}

impl ServerCheckpoint {
    /// Serialize to the versioned wire form.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "schema_version",
                Value::Num(crate::solvers::snapshot::SNAPSHOT_SCHEMA_VERSION as f64),
            ),
            (
                "created_by",
                Value::Str(format!("sadiff {}", env!("CARGO_PKG_VERSION"))),
            ),
            (
                "groups",
                Value::Array(self.groups.iter().map(GroupCheckpoint::to_json).collect()),
            ),
        ])
    }

    /// Parse the wire form; newer schema versions are rejected with a
    /// typed error.
    pub fn from_json(v: &Value) -> Result<ServerCheckpoint> {
        check_schema_version(v, "server checkpoint")?;
        let groups = v
            .get("groups")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("server checkpoint missing 'groups' array"))?
            .iter()
            .map(GroupCheckpoint::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ServerCheckpoint { groups })
    }

    /// Write atomically: temp file in the same directory, then rename over
    /// the target, so readers only ever see a complete checkpoint. The
    /// write (serialize + fs) is recorded as a `checkpoint_write` trace
    /// span on the calling worker's lane.
    pub fn save(&self, path: &str) -> Result<()> {
        let _span = crate::obs::trace::span("checkpoint_write", "io");
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, format!("{}\n", to_string(&self.to_json())))
            .map_err(|e| Error::runtime(format!("cannot write {tmp}: {e}")))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| Error::runtime(format!("cannot rename {tmp} -> {path}: {e}")))
    }

    /// Load and parse a checkpoint file.
    pub fn load(path: &str) -> Result<ServerCheckpoint> {
        Self::from_json(&crate::config::load_json_file(path)?)
    }

    /// Human-readable summary lines for the `sadiff checkpoint` command.
    pub fn describe(&self) -> Vec<String> {
        let mut out = vec![format!("{} in-flight group(s)", self.groups.len())];
        for (i, g) in self.groups.iter().enumerate() {
            let workload = g.group.opt_str("workload", "?");
            let solver = g
                .group
                .get("solver_cfg")
                .map(|c| c.opt_str("solver", "?"))
                .unwrap_or("?");
            let next_step = g.group.opt_usize("next_step", 0);
            let lanes = g
                .group
                .get("stream_keys")
                .and_then(Value::as_array)
                .map_or(0, |a| a.len());
            let clients: Vec<String> =
                g.clients.iter().map(|(_, c)| c.to_string()).collect();
            out.push(format!(
                "group {i}: workload={workload} solver={solver} lanes={lanes} \
                 next_step={next_step} clients=[{}]",
                clients.join(", ")
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite::parse;

    fn checkpoint() -> ServerCheckpoint {
        ServerCheckpoint {
            groups: vec![GroupCheckpoint {
                group: Value::obj(vec![
                    ("workload", Value::Str("latent_analog".into())),
                    ("next_step", Value::Num(3.0)),
                ]),
                clients: vec![(1, 1234), (2, u64::MAX)],
            }],
        }
    }

    #[test]
    fn json_roundtrip_preserves_u64_ids() {
        let ck = checkpoint();
        let back =
            ServerCheckpoint::from_json(&parse(&to_string(&ck.to_json())).unwrap()).unwrap();
        assert_eq!(back.groups.len(), 1);
        assert_eq!(back.groups[0].clients, vec![(1, 1234), (2, u64::MAX)]);
        assert_eq!(back.groups[0].group.opt_usize("next_step", 0), 3);
    }

    #[test]
    fn save_load_roundtrip_is_atomic_over_existing_file() {
        let dir = std::env::temp_dir().join(format!("sadiff_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        let path = path.to_str().unwrap();
        checkpoint().save(path).unwrap();
        // Overwrite with a different checkpoint; the rename replaces it.
        ServerCheckpoint::default().save(path).unwrap();
        let loaded = ServerCheckpoint::load(path).unwrap();
        assert!(loaded.groups.is_empty());
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn newer_schema_rejected() {
        let mut v = checkpoint().to_json();
        if let Value::Object(fields) = &mut v {
            for (k, val) in fields.iter_mut() {
                if k == "schema_version" {
                    *val = Value::Num(99.0);
                }
            }
        }
        let err = ServerCheckpoint::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        assert!(ServerCheckpoint::from_json(&Value::obj(vec![])).is_err());
    }

    #[test]
    fn describe_names_the_groups() {
        let lines = checkpoint().describe();
        assert!(lines[0].contains("1 in-flight"));
        assert!(lines[1].contains("latent_analog"), "{}", lines[1]);
        assert!(lines[1].contains("next_step=3"));
    }
}
