//! Serving metrics: lock-free counters plus log-bucketed latency
//! histograms — one end-to-end request histogram and one per pipeline
//! [`Stage`] (queue wait, batch merge, solver step, model eval,
//! checkpoint write, response write) — snapshotted to JSON for the
//! `stats` protocol command. Percentiles are linearly interpolated
//! within the bucket containing the quantile; observations above the
//! top bound land in a dedicated overflow bucket and report as
//! `Infinity` (serialized as JSON `null` by `jsonlite`).

use crate::jsonlite::Value;
use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram buckets (upper bounds, ms). Log-spaced.
const BUCKET_BOUNDS_MS: [f64; 12] =
    [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 5000.0];

/// Bucket count: one per bound plus the over-the-top-bound overflow
/// bucket. The const assertion pins the invariant that an observation
/// above the last bound is *counted* (in the overflow bucket), never
/// silently dropped.
const BUCKETS: usize = BUCKET_BOUNDS_MS.len() + 1;
const _: () = assert!(BUCKETS == BUCKET_BOUNDS_MS.len() + 1);

/// A measured stage of the serving pipeline. Each stage gets its own
/// latency histogram in [`ServingMetrics`], reported under `stages.<key>`
/// in the `stats` snapshot with interpolated p50/p90/p99.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Request enqueue → admission into an in-flight group.
    QueueWait,
    /// Admission work: merging a compatible group, drawing priors and
    /// warming its steppers (`BatchRun::new`), or restoring a checkpoint.
    BatchMerge,
    /// One scheduler step of one in-flight group (`BatchRun::step`).
    SolverStep,
    /// Model-evaluation wall time inside a step (critical-path shard).
    ModelEval,
    /// One atomic server-checkpoint write.
    CheckpointWrite,
    /// Serializing and writing one protocol reply line.
    ResponseWrite,
}

impl Stage {
    /// Every stage, in snapshot order.
    pub const ALL: [Stage; 6] = [
        Stage::QueueWait,
        Stage::BatchMerge,
        Stage::SolverStep,
        Stage::ModelEval,
        Stage::CheckpointWrite,
        Stage::ResponseWrite,
    ];

    /// The stage's key in the `stats` snapshot (`stages.<key>`) and its
    /// span name in a trace dump.
    pub fn key(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchMerge => "batch_merge",
            Stage::SolverStep => "solver_step",
            Stage::ModelEval => "model_eval",
            Stage::CheckpointWrite => "checkpoint_write",
            Stage::ResponseWrite => "response_write",
        }
    }

    fn index(self) -> usize {
        match self {
            Stage::QueueWait => 0,
            Stage::BatchMerge => 1,
            Stage::SolverStep => 2,
            Stage::ModelEval => 3,
            Stage::CheckpointWrite => 4,
            Stage::ResponseWrite => 5,
        }
    }
}

/// Lock-free log-bucketed latency histogram over [`BUCKET_BOUNDS_MS`]
/// with an overflow bucket. Public so out-of-band consumers (the loadgen
/// reporter) aggregate client-side latencies with the exact same buckets
/// and interpolation the server's `stats` snapshot uses.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation, in milliseconds.
    pub fn observe_ms(&self, ms: f64) {
        let mut idx = BUCKET_BOUNDS_MS.len();
        for (i, ub) in BUCKET_BOUNDS_MS.iter().enumerate() {
            if ms <= *ub {
                idx = i;
                break;
            }
        }
        debug_assert!(idx < BUCKETS, "histogram index past the overflow bucket");
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((ms * 1000.0) as u64, Ordering::Relaxed);
    }

    /// Total observations recorded (overflow included).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Observations above the top bucket bound.
    pub fn overflow(&self) -> u64 {
        self.buckets[BUCKET_BOUNDS_MS.len()].load(Ordering::Relaxed)
    }

    /// Mean of the recorded observations, in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / 1000.0 / n as f64
    }

    /// Quantile `q` ∈ [0, 1], linearly interpolated inside the bucket
    /// containing the quantile (bucket lower bound → upper bound by the
    /// fraction of the bucket's mass below the target rank). Returns 0
    /// for an empty histogram and `f64::INFINITY` when the quantile
    /// falls in the overflow bucket.
    pub fn percentile_ms(&self, q: f64) -> f64 {
        let counts: [u64; BUCKETS] =
            std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            let prev = acc as f64;
            acc += c;
            if *c > 0 && acc as f64 >= target {
                if i == BUCKET_BOUNDS_MS.len() {
                    return f64::INFINITY;
                }
                let lb = if i == 0 { 0.0 } else { BUCKET_BOUNDS_MS[i - 1] };
                let ub = BUCKET_BOUNDS_MS[i];
                let frac = ((target - prev) / *c as f64).clamp(0.0, 1.0);
                return lb + (ub - lb) * frac;
            }
        }
        f64::INFINITY
    }

    /// JSON snapshot: count, overflow, mean and interpolated p50/p90/p99
    /// (an overflow-bucket percentile is `Infinity`, serialized as JSON
    /// `null` by `jsonlite`).
    pub fn snapshot(&self) -> Value {
        Value::obj(vec![
            ("count", Value::Num(self.count() as f64)),
            ("overflow", Value::Num(self.overflow() as f64)),
            ("mean_ms", Value::Num(self.mean_ms())),
            ("p50_ms", Value::Num(self.percentile_ms(0.50))),
            ("p90_ms", Value::Num(self.percentile_ms(0.90))),
            ("p99_ms", Value::Num(self.percentile_ms(0.99))),
        ])
    }
}

/// Process-lifetime serving metrics.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// Counter: sampling requests accepted at ingress.
    pub requests: AtomicU64,
    /// Counter: successful responses routed back.
    pub responses_ok: AtomicU64,
    /// Counter: error responses routed back.
    pub responses_err: AtomicU64,
    /// Counter: requests shed because the queue was full (request count or
    /// queued-lane cap).
    pub shed: AtomicU64,
    /// Counter: connections that gave up waiting for their reply
    /// (`ServerConfig.reply_timeout_ms`); each one also counts in
    /// `responses_err`, and its ticket is cancelled so the lanes stop.
    pub timeouts: AtomicU64,
    /// Counter: requests answered with a typed `deadline` error because
    /// their latency budget expired before admission; written via
    /// [`Self::observe_deadline_miss`].
    deadline_miss: AtomicU64,
    /// Counter: sample lanes produced.
    pub samples: AtomicU64,
    /// Counter: model evaluations spent (batched calls).
    pub model_evals: AtomicU64,
    /// Counter: merged batches executed.
    pub batches: AtomicU64,
    /// Σ batch sizes, for mean occupancy.
    pub batched_requests: AtomicU64,
    /// Gauge: samples currently queued in the batcher (set by the server
    /// after every push/pop under the queue lock).
    queued_samples: AtomicU64,
    /// Counter: solver steps executed by the step-synchronous scheduler
    /// (one per in-flight group per grid step). Written only via
    /// [`Self::observe_step`] so it stays in lockstep with `step_lanes`.
    steps: AtomicU64,
    /// Counter: lane·steps executed (steps weighted by group width).
    step_lanes: AtomicU64,
    /// Counter: requests cancelled (queued or in flight); written via
    /// [`Self::observe_cancel`].
    cancelled: AtomicU64,
    /// Gauge: lane groups currently in flight across all workers.
    inflight_groups: AtomicU64,
    /// Gauge: lanes currently in flight across all workers.
    inflight_lanes: AtomicU64,
    /// Counter: checkpoint files written (each write covers the full
    /// in-flight set); written via [`Self::observe_checkpoint`].
    checkpoints_written: AtomicU64,
    /// Counter: in-flight groups resumed from a checkpoint after a restart;
    /// written via [`Self::observe_recovered`].
    groups_recovered: AtomicU64,
    /// Counter: in-flight groups handed off to another worker through the
    /// `migrate_out` protocol command; written via
    /// [`Self::observe_migrated_out`].
    migrated_out: AtomicU64,
    /// Counter: groups accepted from another worker through `migrate_in`
    /// (they resume through the recovery path); written via
    /// [`Self::observe_migrated_in`].
    migrated_in: AtomicU64,
    /// End-to-end request latency.
    latency: Histogram,
    /// Per-stage latency, indexed by [`Stage::index`].
    stages: [Histogram; 6],
}

impl ServingMetrics {
    /// All-zero metrics.
    pub fn new() -> ServingMetrics {
        ServingMetrics::default()
    }

    /// Record one end-to-end request latency in the histogram.
    pub fn observe_latency_ms(&self, ms: f64) {
        self.latency.observe_ms(ms);
    }

    /// Record one latency observation for a pipeline stage.
    pub fn observe_stage(&self, stage: Stage, ms: f64) {
        self.stages[stage.index()].observe_ms(ms);
    }

    /// Record the batcher's current queue depth (in samples).
    pub fn set_queued_samples(&self, n: usize) {
        self.queued_samples.store(n as u64, Ordering::Relaxed);
    }

    /// One scheduler step of a `lanes`-wide in-flight group.
    pub fn observe_step(&self, lanes: usize) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.step_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
    }

    /// A group entered the in-flight set.
    pub fn group_admitted(&self, lanes: usize) {
        self.inflight_groups.fetch_add(1, Ordering::Relaxed);
        self.inflight_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
    }

    /// A group left the in-flight set with `lanes` lanes still attached.
    pub fn group_retired(&self, lanes: usize) {
        self.inflight_groups.fetch_sub(1, Ordering::Relaxed);
        self.inflight_lanes.fetch_sub(lanes as u64, Ordering::Relaxed);
    }

    /// A cancelled request freed `lanes` in-flight lanes (0 if it was
    /// still queued).
    pub fn observe_cancel(&self, lanes: usize) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
        self.inflight_lanes.fetch_sub(lanes as u64, Ordering::Relaxed);
    }

    /// One checkpoint file written.
    pub fn observe_checkpoint(&self) {
        self.checkpoints_written.fetch_add(1, Ordering::Relaxed);
    }

    /// One checkpointed group resumed into a worker's in-flight set.
    pub fn observe_recovered(&self) {
        self.groups_recovered.fetch_add(1, Ordering::Relaxed);
    }

    /// One in-flight group migrated away via `migrate_out`.
    pub fn observe_migrated_out(&self) {
        self.migrated_out.fetch_add(1, Ordering::Relaxed);
    }

    /// One group accepted from another worker via `migrate_in`.
    pub fn observe_migrated_in(&self) {
        self.migrated_in.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` requests expired (deadline passed before admission) and were
    /// answered with typed `deadline` errors.
    pub fn observe_deadline_miss(&self, n: usize) {
        self.deadline_miss.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record a finished batch: its request count, total lanes and NFE.
    pub fn observe_batch(&self, group_size: usize, total_samples: usize, nfe: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(group_size as u64, Ordering::Relaxed);
        self.samples.fetch_add(total_samples as u64, Ordering::Relaxed);
        self.model_evals.fetch_add(nfe as u64, Ordering::Relaxed);
    }

    /// End-to-end latency percentile, linearly interpolated within the
    /// histogram bucket containing the quantile (`Infinity` when the
    /// quantile sits in the overflow bucket; 0 when empty).
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        self.latency.percentile_ms(q)
    }

    /// A stage's latency percentile (same interpolation as
    /// [`Self::latency_percentile_ms`]).
    pub fn stage_percentile_ms(&self, stage: Stage, q: f64) -> f64 {
        self.stages[stage.index()].percentile_ms(q)
    }

    /// JSON snapshot for the `stats` command.
    pub fn snapshot(&self) -> Value {
        let load = |a: &AtomicU64| Value::Num(a.load(Ordering::Relaxed) as f64);
        let batches = self.batches.load(Ordering::Relaxed);
        let occupancy = if batches > 0 {
            self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
        } else {
            0.0
        };
        let stages: Vec<(String, Value)> = Stage::ALL
            .iter()
            .map(|s| (s.key().to_string(), self.stages[s.index()].snapshot()))
            .collect();
        Value::obj(vec![
            ("requests", load(&self.requests)),
            ("responses_ok", load(&self.responses_ok)),
            ("responses_err", load(&self.responses_err)),
            ("shed", load(&self.shed)),
            ("timeouts", load(&self.timeouts)),
            ("deadline_miss", load(&self.deadline_miss)),
            ("samples", load(&self.samples)),
            ("model_evals", load(&self.model_evals)),
            ("batches", load(&self.batches)),
            ("queued_samples", load(&self.queued_samples)),
            ("steps", load(&self.steps)),
            ("step_lanes", load(&self.step_lanes)),
            ("cancelled", load(&self.cancelled)),
            ("inflight_groups", load(&self.inflight_groups)),
            ("inflight_lanes", load(&self.inflight_lanes)),
            ("checkpoints_written", load(&self.checkpoints_written)),
            ("groups_recovered", load(&self.groups_recovered)),
            ("migrated_out", load(&self.migrated_out)),
            ("migrated_in", load(&self.migrated_in)),
            ("mean_batch_occupancy", Value::Num(occupancy)),
            ("latency_p50_ms", Value::Num(self.latency_percentile_ms(0.5))),
            ("latency_p95_ms", Value::Num(self.latency_percentile_ms(0.95))),
            ("latency_p99_ms", Value::Num(self.latency_percentile_ms(0.99))),
            ("latency_overflow", Value::Num(self.latency.overflow() as f64)),
            ("stages", Value::Object(stages)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9
    }

    #[test]
    fn percentiles_from_buckets() {
        let m = ServingMetrics::new();
        for _ in 0..90 {
            m.observe_latency_ms(1.5); // bucket (1, 2] ms
        }
        for _ in 0..10 {
            m.observe_latency_ms(80.0); // bucket (50, 100] ms
        }
        // Interpolated: target rank 50 falls 50/90 into the (1, 2] bucket.
        assert!(close(m.latency_percentile_ms(0.5), 1.0 + 50.0 / 90.0));
        // Target rank 95 falls 5/10 into the (50, 100] bucket.
        assert!(close(m.latency_percentile_ms(0.95), 75.0));
    }

    #[test]
    fn empty_percentile_zero() {
        let m = ServingMetrics::new();
        assert_eq!(m.latency_percentile_ms(0.9), 0.0);
        assert_eq!(m.stage_percentile_ms(Stage::QueueWait, 0.9), 0.0);
    }

    #[test]
    fn snapshot_contains_occupancy() {
        let m = ServingMetrics::new();
        m.observe_batch(3, 12, 60);
        m.observe_batch(1, 4, 20);
        let s = m.snapshot();
        assert_eq!(s.req_f64("mean_batch_occupancy").unwrap(), 2.0);
        assert_eq!(s.req_f64("samples").unwrap(), 16.0);
        assert_eq!(s.req_f64("model_evals").unwrap(), 80.0);
    }

    #[test]
    fn queued_samples_gauge() {
        let m = ServingMetrics::new();
        assert_eq!(m.snapshot().req_f64("queued_samples").unwrap(), 0.0);
        m.set_queued_samples(17);
        assert_eq!(m.snapshot().req_f64("queued_samples").unwrap(), 17.0);
        m.set_queued_samples(0); // gauge, not a counter
        assert_eq!(m.snapshot().req_f64("queued_samples").unwrap(), 0.0);
    }

    #[test]
    fn scheduler_counters_and_gauges() {
        let m = ServingMetrics::new();
        m.group_admitted(8);
        m.group_admitted(4);
        m.observe_step(8);
        m.observe_step(8);
        m.observe_step(4);
        m.observe_cancel(4); // in-flight cancel frees its lanes
        m.group_retired(8);
        m.group_retired(0); // the cancelled group retires empty
        let s = m.snapshot();
        assert_eq!(s.req_f64("steps").unwrap(), 3.0);
        assert_eq!(s.req_f64("step_lanes").unwrap(), 20.0);
        assert_eq!(s.req_f64("cancelled").unwrap(), 1.0);
        assert_eq!(s.req_f64("inflight_groups").unwrap(), 0.0);
        assert_eq!(s.req_f64("inflight_lanes").unwrap(), 0.0);
    }

    #[test]
    fn timeout_and_deadline_counters() {
        let m = ServingMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.req_f64("timeouts").unwrap(), 0.0);
        assert_eq!(s.req_f64("deadline_miss").unwrap(), 0.0);
        m.timeouts.fetch_add(1, Ordering::Relaxed);
        m.observe_deadline_miss(3);
        let s = m.snapshot();
        assert_eq!(s.req_f64("timeouts").unwrap(), 1.0);
        assert_eq!(s.req_f64("deadline_miss").unwrap(), 3.0);
    }

    #[test]
    fn checkpoint_and_recovery_counters() {
        let m = ServingMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.req_f64("checkpoints_written").unwrap(), 0.0);
        assert_eq!(s.req_f64("groups_recovered").unwrap(), 0.0);
        m.observe_checkpoint();
        m.observe_checkpoint();
        m.observe_recovered();
        let s = m.snapshot();
        assert_eq!(s.req_f64("checkpoints_written").unwrap(), 2.0);
        assert_eq!(s.req_f64("groups_recovered").unwrap(), 1.0);
    }

    #[test]
    fn migration_counters() {
        let m = ServingMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.req_f64("migrated_out").unwrap(), 0.0);
        assert_eq!(s.req_f64("migrated_in").unwrap(), 0.0);
        m.observe_migrated_out();
        m.observe_migrated_in();
        m.observe_migrated_in();
        let s = m.snapshot();
        assert_eq!(s.req_f64("migrated_out").unwrap(), 1.0);
        assert_eq!(s.req_f64("migrated_in").unwrap(), 2.0);
    }

    #[test]
    fn overflow_bucket() {
        let m = ServingMetrics::new();
        m.observe_latency_ms(99999.0); // above the 5000 ms top bound
        assert_eq!(m.latency_percentile_ms(1.0), f64::INFINITY);
        assert_eq!(m.snapshot().req_f64("latency_overflow").unwrap(), 1.0);
    }

    #[test]
    fn bucket_boundary_edge_cases() {
        // Exactly on a bound lands in that bound's bucket (bounds are
        // upper-inclusive): p100 of a single 2.0 ms observation is 2.0.
        let m = ServingMetrics::new();
        m.observe_stage(Stage::SolverStep, 2.0);
        assert!(close(m.stage_percentile_ms(Stage::SolverStep, 1.0), 2.0));

        // Zero lands in the first bucket [0, 0.5].
        let m = ServingMetrics::new();
        m.observe_stage(Stage::QueueWait, 0.0);
        assert!(close(m.stage_percentile_ms(Stage::QueueWait, 1.0), 0.5));
        assert!(close(m.stage_percentile_ms(Stage::QueueWait, 0.0), 0.0));

        // Above the last bound: counted in overflow, reported Infinity.
        let m = ServingMetrics::new();
        m.observe_stage(Stage::CheckpointWrite, 6000.0);
        assert_eq!(m.stage_percentile_ms(Stage::CheckpointWrite, 0.99), f64::INFINITY);
    }

    #[test]
    fn stage_percentile_interpolation_known_distribution() {
        // 90 observations in (1, 2], 10 in (50, 100]:
        //   p50 → 50/90 into (1, 2]        = 1.5555…
        //   p90 → 90/90 into (1, 2]        = 2.0
        //   p99 → (99−90)/10 into (50,100] = 95.0
        let m = ServingMetrics::new();
        for _ in 0..90 {
            m.observe_stage(Stage::ModelEval, 1.5);
        }
        for _ in 0..10 {
            m.observe_stage(Stage::ModelEval, 80.0);
        }
        assert!(close(m.stage_percentile_ms(Stage::ModelEval, 0.50), 1.0 + 50.0 / 90.0));
        assert!(close(m.stage_percentile_ms(Stage::ModelEval, 0.90), 2.0));
        assert!(close(m.stage_percentile_ms(Stage::ModelEval, 0.99), 95.0));
        // Uniform mass in one bucket: the median interpolates to the
        // middle of (1, 2].
        let m = ServingMetrics::new();
        for _ in 0..100 {
            m.observe_stage(Stage::BatchMerge, 1.5);
        }
        assert!(close(m.stage_percentile_ms(Stage::BatchMerge, 0.5), 1.5));
    }

    #[test]
    fn snapshot_stage_shape() {
        let m = ServingMetrics::new();
        m.observe_stage(Stage::QueueWait, 1.5);
        m.observe_stage(Stage::ResponseWrite, 0.1);
        let s = m.snapshot();
        let stages = s.get("stages").expect("stages object");
        for stage in Stage::ALL {
            let entry = stages.get(stage.key()).expect("every stage present");
            for field in ["count", "overflow", "mean_ms", "p50_ms", "p90_ms", "p99_ms"] {
                assert!(
                    entry.req_f64(field).is_ok(),
                    "stage {} missing field {field}",
                    stage.key()
                );
            }
        }
        assert_eq!(stages.get("queue_wait").unwrap().req_f64("count").unwrap(), 1.0);
        assert!(close(
            stages.get("queue_wait").unwrap().req_f64("mean_ms").unwrap(),
            1.5
        ));
    }
}
