//! Serving metrics: lock-free counters plus a log-bucketed latency
//! histogram, snapshotted to JSON for the `stats` protocol command.

use crate::jsonlite::Value;
use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram buckets (upper bounds, ms). Log-spaced.
const BUCKET_BOUNDS_MS: [f64; 12] =
    [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 5000.0];

/// Process-lifetime serving metrics.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// Counter: sampling requests accepted at ingress.
    pub requests: AtomicU64,
    /// Counter: successful responses routed back.
    pub responses_ok: AtomicU64,
    /// Counter: error responses routed back.
    pub responses_err: AtomicU64,
    /// Counter: requests shed because the queue was full.
    pub shed: AtomicU64,
    /// Counter: sample lanes produced.
    pub samples: AtomicU64,
    /// Counter: model evaluations spent (batched calls).
    pub model_evals: AtomicU64,
    /// Counter: merged batches executed.
    pub batches: AtomicU64,
    /// Σ batch sizes, for mean occupancy.
    pub batched_requests: AtomicU64,
    /// Gauge: samples currently queued in the batcher (set by the server
    /// after every push/pop under the queue lock).
    queued_samples: AtomicU64,
    /// Counter: solver steps executed by the step-synchronous scheduler
    /// (one per in-flight group per grid step). Written only via
    /// [`Self::observe_step`] so it stays in lockstep with `step_lanes`.
    steps: AtomicU64,
    /// Counter: lane·steps executed (steps weighted by group width).
    step_lanes: AtomicU64,
    /// Counter: requests cancelled (queued or in flight); written via
    /// [`Self::observe_cancel`].
    cancelled: AtomicU64,
    /// Gauge: lane groups currently in flight across all workers.
    inflight_groups: AtomicU64,
    /// Gauge: lanes currently in flight across all workers.
    inflight_lanes: AtomicU64,
    /// Counter: checkpoint files written (each write covers the full
    /// in-flight set); written via [`Self::observe_checkpoint`].
    checkpoints_written: AtomicU64,
    /// Counter: in-flight groups resumed from a checkpoint after a restart;
    /// written via [`Self::observe_recovered`].
    groups_recovered: AtomicU64,
    latency_buckets: [AtomicU64; 13],
    latency_sum_us: AtomicU64,
}

impl ServingMetrics {
    /// All-zero metrics.
    pub fn new() -> ServingMetrics {
        ServingMetrics::default()
    }

    /// Record one end-to-end request latency in the histogram.
    pub fn observe_latency_ms(&self, ms: f64) {
        let mut idx = BUCKET_BOUNDS_MS.len();
        for (i, ub) in BUCKET_BOUNDS_MS.iter().enumerate() {
            if ms <= *ub {
                idx = i;
                break;
            }
        }
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add((ms * 1000.0) as u64, Ordering::Relaxed);
    }

    /// Record the batcher's current queue depth (in samples).
    pub fn set_queued_samples(&self, n: usize) {
        self.queued_samples.store(n as u64, Ordering::Relaxed);
    }

    /// One scheduler step of a `lanes`-wide in-flight group.
    pub fn observe_step(&self, lanes: usize) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.step_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
    }

    /// A group entered the in-flight set.
    pub fn group_admitted(&self, lanes: usize) {
        self.inflight_groups.fetch_add(1, Ordering::Relaxed);
        self.inflight_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
    }

    /// A group left the in-flight set with `lanes` lanes still attached.
    pub fn group_retired(&self, lanes: usize) {
        self.inflight_groups.fetch_sub(1, Ordering::Relaxed);
        self.inflight_lanes.fetch_sub(lanes as u64, Ordering::Relaxed);
    }

    /// A cancelled request freed `lanes` in-flight lanes (0 if it was
    /// still queued).
    pub fn observe_cancel(&self, lanes: usize) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
        self.inflight_lanes.fetch_sub(lanes as u64, Ordering::Relaxed);
    }

    /// One checkpoint file written.
    pub fn observe_checkpoint(&self) {
        self.checkpoints_written.fetch_add(1, Ordering::Relaxed);
    }

    /// One checkpointed group resumed into a worker's in-flight set.
    pub fn observe_recovered(&self) {
        self.groups_recovered.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a finished batch: its request count, total lanes and NFE.
    pub fn observe_batch(&self, group_size: usize, total_samples: usize, nfe: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(group_size as u64, Ordering::Relaxed);
        self.samples.fetch_add(total_samples as u64, Ordering::Relaxed);
        self.model_evals.fetch_add(nfe as u64, Ordering::Relaxed);
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the bucket containing the quantile).
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return BUCKET_BOUNDS_MS.get(i).copied().unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }

    /// JSON snapshot for the `stats` command.
    pub fn snapshot(&self) -> Value {
        let load = |a: &AtomicU64| Value::Num(a.load(Ordering::Relaxed) as f64);
        let batches = self.batches.load(Ordering::Relaxed);
        let occupancy = if batches > 0 {
            self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
        } else {
            0.0
        };
        Value::obj(vec![
            ("requests", load(&self.requests)),
            ("responses_ok", load(&self.responses_ok)),
            ("responses_err", load(&self.responses_err)),
            ("shed", load(&self.shed)),
            ("samples", load(&self.samples)),
            ("model_evals", load(&self.model_evals)),
            ("batches", load(&self.batches)),
            ("queued_samples", load(&self.queued_samples)),
            ("steps", load(&self.steps)),
            ("step_lanes", load(&self.step_lanes)),
            ("cancelled", load(&self.cancelled)),
            ("inflight_groups", load(&self.inflight_groups)),
            ("inflight_lanes", load(&self.inflight_lanes)),
            ("checkpoints_written", load(&self.checkpoints_written)),
            ("groups_recovered", load(&self.groups_recovered)),
            ("mean_batch_occupancy", Value::Num(occupancy)),
            ("latency_p50_ms", Value::Num(self.latency_percentile_ms(0.5))),
            ("latency_p95_ms", Value::Num(self.latency_percentile_ms(0.95))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_from_buckets() {
        let m = ServingMetrics::new();
        for _ in 0..90 {
            m.observe_latency_ms(1.5); // bucket ≤ 2ms
        }
        for _ in 0..10 {
            m.observe_latency_ms(80.0); // bucket ≤ 100ms
        }
        assert_eq!(m.latency_percentile_ms(0.5), 2.0);
        assert_eq!(m.latency_percentile_ms(0.95), 100.0);
    }

    #[test]
    fn empty_percentile_zero() {
        let m = ServingMetrics::new();
        assert_eq!(m.latency_percentile_ms(0.9), 0.0);
    }

    #[test]
    fn snapshot_contains_occupancy() {
        let m = ServingMetrics::new();
        m.observe_batch(3, 12, 60);
        m.observe_batch(1, 4, 20);
        let s = m.snapshot();
        assert_eq!(s.req_f64("mean_batch_occupancy").unwrap(), 2.0);
        assert_eq!(s.req_f64("samples").unwrap(), 16.0);
        assert_eq!(s.req_f64("model_evals").unwrap(), 80.0);
    }

    #[test]
    fn queued_samples_gauge() {
        let m = ServingMetrics::new();
        assert_eq!(m.snapshot().req_f64("queued_samples").unwrap(), 0.0);
        m.set_queued_samples(17);
        assert_eq!(m.snapshot().req_f64("queued_samples").unwrap(), 17.0);
        m.set_queued_samples(0); // gauge, not a counter
        assert_eq!(m.snapshot().req_f64("queued_samples").unwrap(), 0.0);
    }

    #[test]
    fn scheduler_counters_and_gauges() {
        let m = ServingMetrics::new();
        m.group_admitted(8);
        m.group_admitted(4);
        m.observe_step(8);
        m.observe_step(8);
        m.observe_step(4);
        m.observe_cancel(4); // in-flight cancel frees its lanes
        m.group_retired(8);
        m.group_retired(0); // the cancelled group retires empty
        let s = m.snapshot();
        assert_eq!(s.req_f64("steps").unwrap(), 3.0);
        assert_eq!(s.req_f64("step_lanes").unwrap(), 20.0);
        assert_eq!(s.req_f64("cancelled").unwrap(), 1.0);
        assert_eq!(s.req_f64("inflight_groups").unwrap(), 0.0);
        assert_eq!(s.req_f64("inflight_lanes").unwrap(), 0.0);
    }

    #[test]
    fn checkpoint_and_recovery_counters() {
        let m = ServingMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.req_f64("checkpoints_written").unwrap(), 0.0);
        assert_eq!(s.req_f64("groups_recovered").unwrap(), 0.0);
        m.observe_checkpoint();
        m.observe_checkpoint();
        m.observe_recovered();
        let s = m.snapshot();
        assert_eq!(s.req_f64("checkpoints_written").unwrap(), 2.0);
        assert_eq!(s.req_f64("groups_recovered").unwrap(), 1.0);
    }

    #[test]
    fn overflow_bucket() {
        let m = ServingMetrics::new();
        m.observe_latency_ms(99999.0);
        assert_eq!(m.latency_percentile_ms(1.0), f64::INFINITY);
    }
}
