//! Multi-worker serving router (Layer 3b): ticket ownership, placement,
//! live migration and crash failover over the line protocol.
//!
//! The router owns client connections and the *client-visible* ticket
//! space. Each incoming `sample` request is re-ticketed to a globally
//! unique router ticket, assigned to a worker by a pluggable
//! [`Placement`] policy, and forwarded over the same newline-delimited
//! JSON protocol the workers already speak. Workers are today's
//! [`super::server::Server`]; they register with
//! `{"cmd":"register","addr":...}` (or are listed statically) and are
//! polled every heartbeat with the `snapshot` verb, which doubles as a
//! liveness probe and as the fetch of their latest in-flight group
//! checkpoints.
//!
//! Exactly-once replies by construction: one forwarding thread owns each
//! client request and is the only code path that ever writes that
//! client's reply. Migration and failover never write to clients; they
//! relocate state, and the forwarding thread *chases* the relocation —
//! polling `recover` with `take:true` on the new owner — so the reply is
//! delivered exactly once, bit-identical to an uninterrupted run (the
//! per-lane counter-keyed noise streams make samples independent of
//! where and in how many pieces a group executes).
//!
//! Failover: a worker that misses heartbeats past
//! [`RouterConfig::heartbeat_timeout_ms`] is declared dead; the group
//! checkpoints cached from its last heartbeat are re-assigned to
//! survivors via `migrate_in`. A request whose worker died before any
//! checkpoint was published is re-submitted from scratch — the seeded
//! noise streams make the re-run bitwise equal, so the client cannot
//! tell the difference.
//!
//! Chaos hooks ([`ChaosHooks`]) let tests deterministically drop or
//! delay heartbeats and sever migrations mid-flight; see
//! `testsupport::fleet`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::coordinator::checkpoint::GroupCheckpoint;
use crate::coordinator::metrics::Histogram;
use crate::coordinator::request::{cancel_line, SampleRequest, SampleResponse};
use crate::jsonlite::{parse, to_string, Value};
use crate::util::error::{Error, Result};

/// Router configuration. Mirrors `ServerConfig`'s style: a flat struct
/// with JSON override parsing and CLI-friendly defaults.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Listen address for client connections, e.g. `127.0.0.1:7700`.
    pub addr: String,
    /// Static worker addresses known at startup. Workers may also join
    /// later via the `register` verb.
    pub workers: Vec<String>,
    /// Placement policy name: `least_loaded` (default), `round_robin`
    /// or `sticky`.
    pub placement: String,
    /// Heartbeat poll interval in milliseconds.
    pub heartbeat_ms: u64,
    /// A worker silent for this long is declared dead and failed over.
    pub heartbeat_timeout_ms: u64,
    /// End-to-end reply deadline per client request in milliseconds.
    pub reply_timeout_ms: u64,
    /// TCP connect timeout towards workers in milliseconds.
    pub connect_timeout_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            addr: "127.0.0.1:7700".to_string(),
            workers: Vec::new(),
            placement: "least_loaded".to_string(),
            heartbeat_ms: 500,
            heartbeat_timeout_ms: 2500,
            reply_timeout_ms: 120_000,
            connect_timeout_ms: 1_000,
        }
    }
}

impl RouterConfig {
    /// Parse overrides from a JSON object onto the defaults.
    pub fn from_json(v: &Value) -> Result<RouterConfig> {
        let d = RouterConfig::default();
        let workers = match v.get("workers") {
            Some(Value::Array(items)) => items
                .iter()
                .filter_map(|w| w.as_str().map(str::to_string))
                .collect(),
            _ => d.workers.clone(),
        };
        Ok(RouterConfig {
            addr: v.opt_str("addr", &d.addr).to_string(),
            workers,
            placement: v.opt_str("placement", &d.placement).to_string(),
            heartbeat_ms: v.opt_usize("heartbeat_ms", d.heartbeat_ms as usize) as u64,
            heartbeat_timeout_ms: v
                .opt_usize("heartbeat_timeout_ms", d.heartbeat_timeout_ms as usize)
                as u64,
            reply_timeout_ms: v.opt_usize("reply_timeout_ms", d.reply_timeout_ms as usize) as u64,
            connect_timeout_ms: v.opt_usize("connect_timeout_ms", d.connect_timeout_ms as usize)
                as u64,
        })
    }
}

/// A worker as seen by a [`Placement`] policy: load gauges from the most
/// recent heartbeat plus the router's own outstanding-work bookkeeping.
#[derive(Debug, Clone)]
pub struct WorkerView {
    /// Stable index into the router's worker registry.
    pub index: usize,
    /// Whether the worker answered its most recent heartbeat window.
    pub alive: bool,
    /// Lanes queued but not yet admitted, from the worker's gauges.
    pub queued_lanes: usize,
    /// Lanes currently in flight on the worker.
    pub inflight_lanes: usize,
    /// Router-side estimate of un-acked work: the sum of `n × NFE`
    /// lane-steps forwarded to this worker and not yet replied.
    pub outstanding_lane_steps: u64,
}

/// Pluggable placement policy (spada-sim `assign_jobs` shape): given a
/// request and the current worker views, pick a worker index or `None`
/// to shed. Implementations must only return indices of alive workers.
pub trait Placement: Send + Sync {
    /// Stable policy name, echoed in `stats`.
    fn name(&self) -> &'static str;
    /// Pick a worker for `req`, or `None` if no alive worker exists.
    fn assign(&self, req: &SampleRequest, workers: &[WorkerView]) -> Option<usize>;
}

/// Cost-model placement: pick the worker minimising
/// `outstanding_lane_steps + (queued_lanes + inflight_lanes) × NFE`,
/// i.e. the estimated lane-steps of work ahead of this request.
pub struct LeastLoaded;

impl Placement for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }
    fn assign(&self, req: &SampleRequest, workers: &[WorkerView]) -> Option<usize> {
        workers
            .iter()
            .filter(|w| w.alive)
            .min_by_key(|w| {
                let lanes = (w.queued_lanes + w.inflight_lanes) as u64;
                let cost = w
                    .outstanding_lane_steps
                    .saturating_add(lanes.saturating_mul(req.cfg.nfe as u64));
                (cost, w.index)
            })
            .map(|w| w.index)
    }
}

/// Round-robin placement over alive workers, ignoring load.
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    /// New round-robin policy starting at the first alive worker.
    pub fn new() -> RoundRobin {
        RoundRobin {
            next: AtomicUsize::new(0),
        }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }
    fn assign(&self, _req: &SampleRequest, workers: &[WorkerView]) -> Option<usize> {
        let alive: Vec<usize> = workers.iter().filter(|w| w.alive).map(|w| w.index).collect();
        if alive.is_empty() {
            return None;
        }
        let i = self.next.fetch_add(1, Ordering::Relaxed) % alive.len();
        Some(alive[i])
    }
}

/// Sticky placement: hash `(workload, seed)` onto the alive workers, so
/// repeated submissions of the same request land on the same worker
/// (maximising batcher merges) as long as the fleet is stable.
pub struct Sticky;

impl Placement for Sticky {
    fn name(&self) -> &'static str {
        "sticky"
    }
    fn assign(&self, req: &SampleRequest, workers: &[WorkerView]) -> Option<usize> {
        let alive: Vec<usize> = workers.iter().filter(|w| w.alive).map(|w| w.index).collect();
        if alive.is_empty() {
            return None;
        }
        // FNV-1a over the workload name then the seed bytes.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in req.workload.bytes().chain(req.seed.to_le_bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        Some(alive[(h % alive.len() as u64) as usize])
    }
}

/// Resolve a placement policy by name.
pub fn placement_by_name(name: &str) -> Option<Box<dyn Placement>> {
    match name {
        "least_loaded" => Some(Box::new(LeastLoaded)),
        "round_robin" => Some(Box::new(RoundRobin::new())),
        "sticky" => Some(Box::new(Sticky)),
        _ => None,
    }
}

/// Deterministic fault-injection hooks shared between the router and a
/// test harness. All hooks are no-ops until armed; production routers
/// hold a default (inert) instance.
#[derive(Default)]
pub struct ChaosHooks {
    dropped: Mutex<HashSet<usize>>,
    delay_ms: AtomicU64,
    sever_migrations: AtomicUsize,
}

impl ChaosHooks {
    /// New inert hook set.
    pub fn new() -> Arc<ChaosHooks> {
        Arc::new(ChaosHooks::default())
    }
    /// Start (or stop) swallowing heartbeat polls to `worker`, so the
    /// router sees it as silent even though it is healthy.
    pub fn drop_heartbeats(&self, worker: usize, on: bool) {
        let mut d = self.dropped.lock().unwrap();
        if on {
            d.insert(worker);
        } else {
            d.remove(&worker);
        }
    }
    /// Whether heartbeats to `worker` are currently dropped.
    pub fn is_dropped(&self, worker: usize) -> bool {
        self.dropped.lock().unwrap().contains(&worker)
    }
    /// Delay every heartbeat sweep by `ms` (0 disables).
    pub fn delay_heartbeats(&self, ms: u64) {
        self.delay_ms.store(ms, Ordering::Relaxed);
    }
    /// Current heartbeat delay in milliseconds.
    pub fn heartbeat_delay_ms(&self) -> u64 {
        self.delay_ms.load(Ordering::Relaxed)
    }
    /// Arm one severed migration: the next `migrate_in` attempt is
    /// dropped as if the connection died mid-handoff (the router keeps
    /// the checkpoint and retries).
    pub fn sever_next_migration(&self) {
        self.sever_migrations.fetch_add(1, Ordering::Relaxed);
    }
    /// Consume one armed sever, if any.
    pub fn take_sever(&self) -> bool {
        self.sever_migrations
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
            .is_ok()
    }
}

/// Router-side serving counters and stage histograms.
pub struct RouterMetrics {
    /// Client requests accepted (any line that parses as a sample).
    pub requests: AtomicU64,
    /// Successful sample replies delivered to clients.
    pub responses_ok: AtomicU64,
    /// Error replies delivered to clients.
    pub responses_err: AtomicU64,
    /// Requests shed because no alive worker could take them.
    pub shed: AtomicU64,
    /// Planned migrations completed via the `rebalance` verb.
    pub migrations: AtomicU64,
    /// Dead workers failed over.
    pub failovers: AtomicU64,
    /// Cached groups successfully re-assigned during failovers.
    pub groups_failed_over: AtomicU64,
    /// Requests re-submitted from scratch after a failover found no
    /// checkpoint for them (bit-identical by seeding).
    pub requeued: AtomicU64,
    /// Placement decision latency.
    pub route: Histogram,
    /// Single forward attempt latency (connect + solve + reply).
    pub forward: Histogram,
    /// Migration pause: snapshot-off to restored-on wall time.
    pub migrate: Histogram,
    /// End-to-end client latency through the router.
    pub latency: Histogram,
}

impl RouterMetrics {
    /// Fresh zeroed metrics.
    pub fn new() -> RouterMetrics {
        RouterMetrics {
            requests: AtomicU64::new(0),
            responses_ok: AtomicU64::new(0),
            responses_err: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            groups_failed_over: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            route: Histogram::new(),
            forward: Histogram::new(),
            migrate: Histogram::new(),
            latency: Histogram::new(),
        }
    }

    /// Counters + stage histograms as a JSON object.
    pub fn snapshot(&self) -> Value {
        let load = |c: &AtomicU64| Value::Num(c.load(Ordering::Relaxed) as f64);
        Value::obj(vec![
            ("requests", load(&self.requests)),
            ("responses_ok", load(&self.responses_ok)),
            ("responses_err", load(&self.responses_err)),
            ("shed", load(&self.shed)),
            ("migrations", load(&self.migrations)),
            ("failovers", load(&self.failovers)),
            ("groups_failed_over", load(&self.groups_failed_over)),
            ("requeued", load(&self.requeued)),
            ("route", self.route.snapshot()),
            ("forward", self.forward.snapshot()),
            ("migrate", self.migrate.snapshot()),
            ("latency", self.latency.snapshot()),
        ])
    }
}

impl Default for RouterMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-worker registry entry.
struct WorkerState {
    /// Worker line-protocol address.
    addr: String,
    /// Answered its most recent heartbeat window.
    alive: bool,
    /// Failover for this worker has completed: every cached group was
    /// offered to survivors and all relocations are published. Gate for
    /// the forwarding thread's give-up-and-requeue decision.
    failed_over: bool,
    /// Last successful heartbeat.
    last_seen: Instant,
    /// Gauges from the last heartbeat snapshot.
    queued_lanes: usize,
    queued_requests: usize,
    inflight_lanes: usize,
    inflight_groups: usize,
    /// Worker publishes in-flight snapshots (checkpointing on).
    publishing: bool,
    /// Group checkpoints from the last heartbeat, plus groups moved
    /// here by migration/failover (so a second failure can re-offer
    /// them before this worker's own heartbeat refreshes the cache).
    cached: Vec<GroupCheckpoint>,
    /// Un-acked forwarded work in lane-steps (placement cost input).
    outstanding: u64,
    /// Optional capabilities blob from the `register` handshake.
    capabilities: Option<Value>,
}

impl WorkerState {
    fn new(addr: String) -> WorkerState {
        WorkerState {
            addr,
            alive: true,
            failed_over: false,
            last_seen: Instant::now(),
            queued_lanes: 0,
            queued_requests: 0,
            inflight_lanes: 0,
            inflight_groups: 0,
            publishing: false,
            cached: Vec::new(),
            outstanding: 0,
            capabilities: None,
        }
    }

    fn view(&self, index: usize) -> WorkerView {
        WorkerView {
            index,
            alive: self.alive,
            queued_lanes: self.queued_lanes,
            inflight_lanes: self.inflight_lanes,
            outstanding_lane_steps: self.outstanding,
        }
    }
}

/// Shared router state across accept / forwarding / heartbeat threads.
struct RouterShared {
    cfg: RouterConfig,
    placement: Box<dyn Placement>,
    workers: Mutex<Vec<WorkerState>>,
    /// Router ticket → current owner worker index, updated on every
    /// migration/failover hand-off. Forwarding threads poll this to
    /// chase their request across workers.
    relocated: Mutex<HashMap<u64, usize>>,
    /// Router ticket → original client id, for cancel fan-out.
    forwards: Mutex<HashMap<u64, u64>>,
    next_ticket: AtomicU64,
    shutdown: AtomicBool,
    metrics: RouterMetrics,
    chaos: Arc<ChaosHooks>,
}

/// The router front-end process. Construct with [`Router::bind`], then
/// [`Router::spawn`] to serve.
pub struct Router {
    listener: TcpListener,
    shared: Arc<RouterShared>,
    addr: SocketAddr,
}

impl Router {
    /// Bind the router with inert chaos hooks.
    pub fn bind(cfg: RouterConfig) -> Result<Router> {
        Router::bind_with_chaos(cfg, ChaosHooks::new())
    }

    /// Bind the router with caller-armed [`ChaosHooks`] (test harness).
    pub fn bind_with_chaos(cfg: RouterConfig, chaos: Arc<ChaosHooks>) -> Result<Router> {
        let placement = placement_by_name(&cfg.placement)
            .ok_or_else(|| Error::config(format!("unknown placement policy: {}", cfg.placement)))?;
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let workers = cfg
            .workers
            .iter()
            .map(|a| WorkerState::new(a.clone()))
            .collect();
        let shared = Arc::new(RouterShared {
            cfg,
            placement,
            workers: Mutex::new(workers),
            relocated: Mutex::new(HashMap::new()),
            forwards: Mutex::new(HashMap::new()),
            next_ticket: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            metrics: RouterMetrics::new(),
            chaos,
        });
        Ok(Router {
            listener,
            shared,
            addr,
        })
    }

    /// The bound listen address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Start the accept loop and heartbeat thread; returns a handle the
    /// caller uses to stop the router.
    pub fn spawn(self) -> RouterHandle {
        let shared = Arc::clone(&self.shared);
        let listener = self.listener;
        crate::log_info!("router", "listening on {}", self.addr);
        let accept_shared = Arc::clone(&shared);
        let accept = thread::spawn(move || accept_loop(listener, accept_shared));
        let hb_shared = Arc::clone(&shared);
        let heartbeat = thread::spawn(move || heartbeat_loop(hb_shared));
        RouterHandle {
            addr: self.addr,
            shared,
            accept: Some(accept),
            heartbeat: Some(heartbeat),
        }
    }
}

/// Handle to a running router; dropping it shuts the router down.
pub struct RouterHandle {
    addr: SocketAddr,
    shared: Arc<RouterShared>,
    accept: Option<JoinHandle<()>>,
    heartbeat: Option<JoinHandle<()>>,
}

impl RouterHandle {
    /// The router's listen address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Chaos hooks shared with this router (test harness access).
    pub fn chaos(&self) -> Arc<ChaosHooks> {
        Arc::clone(&self.shared.chaos)
    }

    /// Counters + histograms snapshot (same data as the `stats` verb,
    /// without the per-worker array).
    pub fn metrics_snapshot(&self) -> Value {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting, stop the heartbeat thread, and join both.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Poke the blocking accept loop awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.heartbeat.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<RouterShared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let s = Arc::clone(&shared);
                thread::spawn(move || connection_loop(stream, s));
            }
            Err(e) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                crate::log_warn!("router", "accept error: {e}");
            }
        }
    }
}

fn connection_loop(stream: TcpStream, shared: Arc<RouterShared>) {
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Ok(_) => {}
            Err(_) => return,
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = handle_line(&shared, trimmed);
        if writer
            .write_all(format!("{reply}\n").as_bytes())
            .is_err()
        {
            return;
        }
        let _ = writer.flush();
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

fn handle_line(shared: &Arc<RouterShared>, line: &str) -> String {
    let v = match parse(line) {
        Ok(v) => v,
        Err(e) => return SampleResponse::err(0, format!("bad json: {e}")).to_line(),
    };
    match v.get("cmd").and_then(Value::as_str) {
        Some("ping") => to_string(&Value::obj(vec![("ok", Value::Bool(true))])),
        Some("stats") => to_string(&handle_stats(shared)),
        Some("register") => to_string(&handle_register(shared, &v)),
        Some("rebalance") => to_string(&handle_rebalance(shared, &v)),
        Some("cancel") => to_string(&handle_cancel(shared, &v)),
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            to_string(&Value::obj(vec![("ok", Value::Bool(true))]))
        }
        Some(other) => SampleResponse::err(0, format!("unknown command: {other}")).to_line(),
        None => handle_request(shared, &v).to_line(),
    }
}

fn handle_stats(shared: &Arc<RouterShared>) -> Value {
    let workers: Vec<Value> = {
        let ws = shared.workers.lock().unwrap();
        ws.iter()
            .enumerate()
            .map(|(i, w)| {
                let mut fields = vec![
                    ("index", Value::Num(i as f64)),
                    ("addr", Value::Str(w.addr.clone())),
                    ("alive", Value::Bool(w.alive)),
                    ("failed_over", Value::Bool(w.failed_over)),
                    ("publishing", Value::Bool(w.publishing)),
                    ("queued_lanes", Value::Num(w.queued_lanes as f64)),
                    ("queued_requests", Value::Num(w.queued_requests as f64)),
                    ("inflight_lanes", Value::Num(w.inflight_lanes as f64)),
                    ("inflight_groups", Value::Num(w.inflight_groups as f64)),
                    ("cached_groups", Value::Num(w.cached.len() as f64)),
                    ("outstanding_lane_steps", Value::Num(w.outstanding as f64)),
                ];
                if let Some(c) = &w.capabilities {
                    fields.push(("capabilities", c.clone()));
                }
                Value::obj(fields)
            })
            .collect()
    };
    let mut out: Vec<(String, Value)> = vec![
        ("ok".to_string(), Value::Bool(true)),
        (
            "placement".to_string(),
            Value::Str(shared.placement.name().to_string()),
        ),
        ("workers".to_string(), Value::Array(workers)),
    ];
    if let Value::Object(fields) = shared.metrics.snapshot() {
        out.extend(fields);
    }
    Value::Object(out)
}

fn handle_register(shared: &Arc<RouterShared>, v: &Value) -> Value {
    let Some(addr) = v.get("addr").and_then(Value::as_str) else {
        return Value::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::Str("register requires addr".to_string())),
        ]);
    };
    let caps = v.get("capabilities").cloned();
    let mut ws = shared.workers.lock().unwrap();
    let (index, fresh) = match ws.iter().position(|w| w.addr == addr) {
        Some(i) => {
            // Idempotent re-register: a restarted worker comes back
            // clean, but keeps its registry slot.
            ws[i].alive = true;
            ws[i].failed_over = false;
            ws[i].last_seen = Instant::now();
            if caps.is_some() {
                ws[i].capabilities = caps;
            }
            (i, false)
        }
        None => {
            let mut st = WorkerState::new(addr.to_string());
            st.capabilities = caps;
            ws.push(st);
            (ws.len() - 1, true)
        }
    };
    if fresh {
        crate::log_info!("router", "worker {index} registered at {addr}");
    }
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("index", Value::Num(index as f64)),
        ("workers", Value::Num(ws.len() as f64)),
    ])
}

fn handle_cancel(shared: &Arc<RouterShared>, v: &Value) -> Value {
    let Some(id) = v.get("id").and_then(Value::as_u64) else {
        return Value::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::Str("cancel requires id".to_string())),
        ]);
    };
    // Translate the client id to every router ticket it maps to, then
    // broadcast: the request may have moved since it was forwarded.
    let tickets: Vec<u64> = {
        let fw = shared.forwards.lock().unwrap();
        fw.iter()
            .filter(|(_, c)| **c == id)
            .map(|(t, _)| *t)
            .collect()
    };
    let addrs: Vec<String> = {
        let ws = shared.workers.lock().unwrap();
        ws.iter()
            .filter(|w| w.alive)
            .map(|w| w.addr.clone())
            .collect()
    };
    let mut cancelled = 0u64;
    for t in &tickets {
        let line = cancel_line(*t);
        for addr in &addrs {
            if let Ok(r) = round_trip_addr(shared, addr, &line, Duration::from_millis(2_000)) {
                cancelled += r.get("cancelled_queued").and_then(Value::as_u64).unwrap_or(0);
                cancelled += r.get("cancel_pending").and_then(Value::as_u64).unwrap_or(0);
            }
        }
    }
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("tickets", Value::Num(tickets.len() as f64)),
        ("cancelled", Value::Num(cancelled as f64)),
    ])
}

fn handle_rebalance(shared: &Arc<RouterShared>, v: &Value) -> Value {
    let t0 = Instant::now();
    let err = |msg: String| {
        Value::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::Str(msg)),
        ])
    };
    let (from, to_pref) = {
        let ws = shared.workers.lock().unwrap();
        let from = match v.get("from").and_then(Value::as_u64) {
            Some(i) => i as usize,
            None => {
                // Hottest alive worker with anything in flight.
                match ws
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| w.alive && w.inflight_lanes > 0)
                    .max_by_key(|(_, w)| w.inflight_lanes)
                    .map(|(i, _)| i)
                {
                    Some(i) => i,
                    None => return err("no worker has in-flight work".to_string()),
                }
            }
        };
        if from >= ws.len() {
            return err(format!("no such worker: {from}"));
        }
        let to_pref = v
            .get("to")
            .and_then(Value::as_u64)
            .map(|i| i as usize)
            .or_else(|| {
                // Idlest alive worker other than the source.
                ws.iter()
                    .enumerate()
                    .filter(|(i, w)| *i != from && w.alive)
                    .min_by_key(|(i, w)| (w.outstanding as u128 + w.inflight_lanes as u128, *i))
                    .map(|(i, _)| i)
            });
        (from, to_pref)
    };
    let timeout_ms = v.opt_usize("timeout_ms", 3_000) as u64;
    let out_line = to_string(&Value::obj(vec![
        ("cmd", Value::Str("migrate_out".to_string())),
        ("timeout_ms", Value::Num(timeout_ms as f64)),
    ]));
    let reply = match round_trip_worker(
        shared,
        from,
        &out_line,
        Duration::from_millis(timeout_ms + 2_000),
    ) {
        Ok(r) => r,
        Err(e) => return err(format!("migrate_out on worker {from} failed: {e}")),
    };
    if !reply.opt_bool("ok", false) {
        let msg = match reply.get("error") {
            Some(Value::Str(s)) => s.clone(),
            Some(e @ Value::Object(_)) => e
                .get("message")
                .and_then(Value::as_str)
                .unwrap_or("migrate_out refused")
                .to_string(),
            _ => "migrate_out refused".to_string(),
        };
        return err(msg);
    }
    let gck = match reply.get("group") {
        Some(g) => match GroupCheckpoint::from_json(g) {
            Ok(gck) => gck,
            Err(e) => return err(format!("bad group checkpoint from worker {from}: {e}")),
        },
        None => return err("migrate_out reply missing group".to_string()),
    };
    let lanes = reply.get("lanes").and_then(Value::as_u64).unwrap_or(0);
    match place_group(shared, &gck, to_pref, None) {
        Some(dst) => {
            remove_cached(shared, from, &gck);
            shared.metrics.migrations.fetch_add(1, Ordering::Relaxed);
            let pause = t0.elapsed().as_secs_f64() * 1e3;
            shared.metrics.migrate.observe_ms(pause);
            crate::log_info!(
                "router",
                "rebalanced {lanes} lane(s) from worker {from} to worker {dst} in {pause:.1} ms"
            );
            Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("from", Value::Num(from as f64)),
                ("to", Value::Num(dst as f64)),
                ("requests", Value::Num(gck.clients.len() as f64)),
                ("lanes", Value::Num(lanes as f64)),
                ("pause_ms", Value::Num(pause)),
            ])
        }
        None => err(format!(
            "no worker accepted the group migrated off worker {from}"
        )),
    }
}

/// One client `sample` request, owned end-to-end by this thread: assign,
/// forward, chase relocations, reply exactly once.
fn handle_request(shared: &Arc<RouterShared>, v: &Value) -> SampleResponse {
    let t_start = Instant::now();
    let req = match SampleRequest::from_json(v) {
        Ok(r) => r,
        Err(e) => {
            let id = v.opt_usize("id", 0) as u64;
            shared.metrics.responses_err.fetch_add(1, Ordering::Relaxed);
            return SampleResponse::err(id, e.to_string());
        }
    };
    shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
    let client_id = req.id;
    let budget = match req.deadline_ms {
        Some(ms) if ms > 0 => ms.min(shared.cfg.reply_timeout_ms),
        _ => shared.cfg.reply_timeout_ms,
    };
    let deadline = t_start + Duration::from_millis(budget);
    let cost = (req.n as u64).saturating_mul(req.cfg.nfe as u64);

    let mut resp = loop {
        // Re-ticket: each (re)submission gets a fresh router ticket so a
        // late reply for an abandoned attempt can never be confused with
        // the live one.
        let ticket = shared.next_ticket.fetch_add(1, Ordering::Relaxed);
        let mut fwd_req = req.clone();
        fwd_req.id = ticket;
        shared.forwards.lock().unwrap().insert(ticket, client_id);

        let t_route = Instant::now();
        let assigned = {
            let ws = shared.workers.lock().unwrap();
            let views: Vec<WorkerView> = ws.iter().enumerate().map(|(i, w)| w.view(i)).collect();
            shared.placement.assign(&fwd_req, &views)
        };
        shared
            .metrics
            .route
            .observe_ms(t_route.elapsed().as_secs_f64() * 1e3);
        let Some(w) = assigned else {
            shared.forwards.lock().unwrap().remove(&ticket);
            shared.metrics.shed.fetch_add(1, Ordering::Relaxed);
            break SampleResponse::shed(client_id, (shared.cfg.heartbeat_ms * 2).max(50));
        };
        {
            let mut ws = shared.workers.lock().unwrap();
            ws[w].outstanding = ws[w].outstanding.saturating_add(cost);
        }

        let t_fwd = Instant::now();
        let outcome = forward_once(shared, w, &fwd_req, deadline);
        shared
            .metrics
            .forward
            .observe_ms(t_fwd.elapsed().as_secs_f64() * 1e3);
        {
            let mut ws = shared.workers.lock().unwrap();
            ws[w].outstanding = ws[w].outstanding.saturating_sub(cost);
        }

        let settled = match outcome {
            ForwardOutcome::Reply(r) if r.kind.as_deref() != Some("migrated") => Some(r),
            ForwardOutcome::Timeout => Some(SampleResponse::typed_err(
                client_id,
                "timeout",
                "router reply deadline exceeded",
            )),
            // Migrated away, worker died, or a relocation was published
            // while we were blocked: chase the request's new home.
            ForwardOutcome::Reply(_) | ForwardOutcome::Dead | ForwardOutcome::Relocated => None,
        };
        if let Some(r) = settled {
            shared.forwards.lock().unwrap().remove(&ticket);
            shared.relocated.lock().unwrap().remove(&ticket);
            break r;
        }

        match await_relocation(shared, ticket, w, deadline) {
            ChaseOutcome::Recovered(r) => {
                shared.forwards.lock().unwrap().remove(&ticket);
                shared.relocated.lock().unwrap().remove(&ticket);
                break r;
            }
            ChaseOutcome::Timeout => {
                shared.forwards.lock().unwrap().remove(&ticket);
                shared.relocated.lock().unwrap().remove(&ticket);
                break SampleResponse::typed_err(
                    client_id,
                    "timeout",
                    "router reply deadline exceeded while chasing relocation",
                );
            }
            ChaseOutcome::NotRelocated => {
                // Worker died before any checkpoint covered this request:
                // re-submit from scratch. Per-lane seeded noise makes the
                // re-run bitwise equal to what the dead worker would have
                // produced, so exactly-once still holds at the client.
                shared.forwards.lock().unwrap().remove(&ticket);
                shared.metrics.requeued.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "router",
                    "no checkpoint for ticket {ticket} after worker {w} failover; re-queueing"
                );
                continue;
            }
        }
    };

    // Restore the client's own id on the reply.
    resp.id = client_id;
    if resp.ok {
        shared.metrics.responses_ok.fetch_add(1, Ordering::Relaxed);
    } else {
        shared.metrics.responses_err.fetch_add(1, Ordering::Relaxed);
    }
    shared
        .metrics
        .latency
        .observe_ms(t_start.elapsed().as_secs_f64() * 1e3);
    resp
}

enum ForwardOutcome {
    /// Worker replied (may be a typed `migrated` error).
    Reply(SampleResponse),
    /// Connection refused/dropped, or the heartbeat declared the worker
    /// dead while we were waiting.
    Dead,
    /// A relocation for this ticket appeared while waiting.
    Relocated,
    /// Client deadline exceeded.
    Timeout,
}

/// Forward a request to worker `w` and wait for its reply, watching for
/// death/relocation. Reads with a short poll timeout so an in-process
/// `kill()`ed worker (whose sockets never EOF) cannot wedge us.
fn forward_once(
    shared: &Arc<RouterShared>,
    w: usize,
    req: &SampleRequest,
    deadline: Instant,
) -> ForwardOutcome {
    let ticket = req.id;
    let addr = { shared.workers.lock().unwrap()[w].addr.clone() };
    let Some(sock) = resolve(&addr) else {
        return ForwardOutcome::Dead;
    };
    let mut stream = match TcpStream::connect_timeout(
        &sock,
        Duration::from_millis(shared.cfg.connect_timeout_ms),
    ) {
        Ok(s) => s,
        Err(_) => return ForwardOutcome::Dead,
    };
    if stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .is_err()
    {
        return ForwardOutcome::Dead;
    }
    let line = format!("{}\n", req.to_line());
    if stream.write_all(line.as_bytes()).is_err() {
        return ForwardOutcome::Dead;
    }
    // Accumulate raw bytes until a newline: BufReader::read_line drops
    // partial data when the poll timeout fires mid-line, so we read
    // manually and keep everything.
    let mut acc: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 8192];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return ForwardOutcome::Dead,
            Ok(n) => {
                acc.extend_from_slice(&chunk[..n]);
                if acc.contains(&b'\n') {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if Instant::now() >= deadline || shared.shutdown.load(Ordering::SeqCst) {
                    return ForwardOutcome::Timeout;
                }
                if shared.relocated.lock().unwrap().contains_key(&ticket) {
                    return ForwardOutcome::Relocated;
                }
                if !shared.workers.lock().unwrap()[w].alive {
                    return ForwardOutcome::Dead;
                }
            }
            Err(_) => return ForwardOutcome::Dead,
        }
    }
    let end = acc.iter().position(|b| *b == b'\n').unwrap_or(acc.len());
    let text = String::from_utf8_lossy(&acc[..end]);
    match parse(text.trim()) {
        Ok(v) => match SampleResponse::from_json(&v) {
            Ok(r) => ForwardOutcome::Reply(r),
            Err(_) => ForwardOutcome::Dead,
        },
        Err(_) => ForwardOutcome::Dead,
    }
}

enum ChaseOutcome {
    Recovered(SampleResponse),
    /// Failover completed and published no relocation for this ticket —
    /// the group was never checkpointed; caller re-submits from scratch.
    NotRelocated,
    Timeout,
}

/// The request left worker `orig` (migration or failover). Poll the
/// relocation map and the new owner's recovered-result store until the
/// reply is ready, the failover declares no checkpoint existed, or the
/// deadline passes.
fn await_relocation(
    shared: &Arc<RouterShared>,
    ticket: u64,
    orig: usize,
    deadline: Instant,
) -> ChaseOutcome {
    loop {
        if Instant::now() >= deadline || shared.shutdown.load(Ordering::SeqCst) {
            return ChaseOutcome::Timeout;
        }
        let owner = shared.relocated.lock().unwrap().get(&ticket).copied();
        match owner {
            Some(w) => match recover_poll(shared, w, ticket) {
                Ok(Some(resp)) => return ChaseOutcome::Recovered(resp),
                Ok(None) => {} // still solving (or moving again)
                Err(()) => {}  // owner unreachable; failover will re-relocate
            },
            None => {
                let failed_over = {
                    let ws = shared.workers.lock().unwrap();
                    !ws[orig].alive && ws[orig].failed_over
                };
                // Re-check after observing failed_over: relocations are
                // published before the flag flips, so a miss here is
                // authoritative.
                if failed_over && !shared.relocated.lock().unwrap().contains_key(&ticket) {
                    return ChaseOutcome::NotRelocated;
                }
            }
        }
        thread::sleep(Duration::from_millis(20));
    }
}

/// One `recover take:true` poll against worker `w`. `Ok(None)` means the
/// result is not ready yet (still solving, or mid-move); `Err(())` means
/// the worker was unreachable.
fn recover_poll(
    shared: &Arc<RouterShared>,
    w: usize,
    ticket: u64,
) -> std::result::Result<Option<SampleResponse>, ()> {
    let addr = { shared.workers.lock().unwrap()[w].addr.clone() };
    let line = to_string(&Value::obj(vec![
        ("cmd", Value::Str("recover".to_string())),
        ("id", Value::Num(ticket as f64)),
        ("take", Value::Bool(true)),
    ]));
    let v = round_trip_addr(shared, &addr, &line, Duration::from_millis(2_000)).map_err(|_| ())?;
    let resp = SampleResponse::from_json(&v).map_err(|_| ())?;
    if resp.ok {
        return Ok(Some(resp));
    }
    let msg = resp.error.as_deref().unwrap_or("");
    if msg.contains("recovery pending") || msg.contains("no recovered result") {
        // Still in flight — or the group moved again and the relocation
        // map will shortly point somewhere new. Keep polling.
        return Ok(None);
    }
    // A terminal per-request error (e.g. restore failure) is a real
    // reply; deliver it.
    Ok(Some(resp))
}

/// Offer `gck` to workers until one accepts it via `migrate_in`, then
/// publish the relocations and cache the checkpoint under the acceptor.
/// `preferred` is tried first; `exclude` (the dead worker) never.
fn place_group(
    shared: &Arc<RouterShared>,
    gck: &GroupCheckpoint,
    preferred: Option<usize>,
    exclude: Option<usize>,
) -> Option<usize> {
    let mut queue: VecDeque<usize> = {
        let ws = shared.workers.lock().unwrap();
        let mut order: Vec<usize> = Vec::new();
        if let Some(p) = preferred {
            if p < ws.len() && ws[p].alive && Some(p) != exclude {
                order.push(p);
            }
        }
        let mut rest: Vec<usize> = (0..ws.len())
            .filter(|i| ws[*i].alive && Some(*i) != exclude && !order.contains(i))
            .collect();
        rest.sort_by_key(|i| (ws[*i].outstanding as u128 + ws[*i].inflight_lanes as u128, *i));
        order.extend(rest);
        order.into()
    };
    let line = to_string(&Value::obj(vec![
        ("cmd", Value::Str("migrate_in".to_string())),
        ("group", gck.to_json()),
    ]));
    let mut severed = 0usize;
    while let Some(dst) = queue.pop_front() {
        if shared.chaos.take_sever() && severed < 4 {
            severed += 1;
            crate::log_warn!(
                "router",
                "chaos: severed migrate_in attempt to worker {dst}; retrying"
            );
            queue.push_back(dst);
            continue;
        }
        match round_trip_worker(shared, dst, &line, Duration::from_millis(5_000)) {
            Ok(r) if r.opt_bool("ok", false) => {
                {
                    let mut rel = shared.relocated.lock().unwrap();
                    for (_, client) in &gck.clients {
                        rel.insert(*client, dst);
                    }
                }
                shared.workers.lock().unwrap()[dst].cached.push(gck.clone());
                return Some(dst);
            }
            _ => continue,
        }
    }
    None
}

/// Drop a just-migrated group from `from`'s cache so a failover of the
/// (still alive) source cannot re-offer a group it no longer owns.
fn remove_cached(shared: &Arc<RouterShared>, from: usize, gck: &GroupCheckpoint) {
    let mut ws = shared.workers.lock().unwrap();
    if from < ws.len() {
        ws[from].cached.retain(|g| g.clients != gck.clients);
    }
}

fn heartbeat_loop(shared: Arc<RouterShared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        thread::sleep(Duration::from_millis(shared.cfg.heartbeat_ms));
        let delay = shared.chaos.heartbeat_delay_ms();
        if delay > 0 {
            thread::sleep(Duration::from_millis(delay));
        }
        let n = { shared.workers.lock().unwrap().len() };
        for w in 0..n {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if shared.chaos.is_dropped(w) {
                missed_heartbeat(&shared, w);
                continue;
            }
            match poll_snapshot(&shared, w) {
                Ok(snap) => apply_snapshot(&shared, w, &snap),
                Err(_) => missed_heartbeat(&shared, w),
            }
        }
    }
}

fn poll_snapshot(shared: &Arc<RouterShared>, w: usize) -> Result<Value> {
    let addr = { shared.workers.lock().unwrap()[w].addr.clone() };
    let line = to_string(&Value::obj(vec![(
        "cmd",
        Value::Str("snapshot".to_string()),
    )]));
    let v = round_trip_addr(shared, &addr, &line, Duration::from_millis(2_000))?;
    if !v.opt_bool("ok", false) {
        return Err(Error::protocol("snapshot poll refused"));
    }
    Ok(v)
}

fn apply_snapshot(shared: &Arc<RouterShared>, w: usize, snap: &Value) {
    let groups: Vec<GroupCheckpoint> = match snap.get("groups") {
        Some(Value::Array(items)) => items
            .iter()
            .filter_map(|g| GroupCheckpoint::from_json(g).ok())
            .collect(),
        _ => Vec::new(),
    };
    let mut ws = shared.workers.lock().unwrap();
    let st = &mut ws[w];
    let was_dead = !st.alive;
    st.alive = true;
    st.last_seen = Instant::now();
    st.queued_lanes = snap.opt_usize("queued_lanes", st.queued_lanes);
    st.queued_requests = snap.opt_usize("queued_requests", st.queued_requests);
    st.inflight_lanes = snap.opt_usize("inflight_lanes", st.inflight_lanes);
    st.inflight_groups = snap.opt_usize("inflight_groups", st.inflight_groups);
    st.publishing = snap.opt_bool("publishing", st.publishing);
    if st.publishing {
        st.cached = groups;
    }
    if was_dead {
        crate::log_info!("router", "worker {w} ({}) is back", st.addr);
    }
}

fn missed_heartbeat(shared: &Arc<RouterShared>, w: usize) {
    let overdue = {
        let ws = shared.workers.lock().unwrap();
        let st = &ws[w];
        st.alive
            && st.last_seen.elapsed() >= Duration::from_millis(shared.cfg.heartbeat_timeout_ms)
    };
    if overdue {
        failover(shared, w);
    }
}

/// A worker is dead: mark it, then offer every group checkpoint cached
/// from its last heartbeat to survivors. Relocations are published per
/// group as hand-offs succeed; `failed_over` flips last, so a forwarding
/// thread that sees `failed_over` with no relocation for its ticket
/// knows, authoritatively, that no checkpoint covered its request.
fn failover(shared: &Arc<RouterShared>, w: usize) {
    let t0 = Instant::now();
    let (addr, groups) = {
        let mut ws = shared.workers.lock().unwrap();
        if !ws[w].alive {
            return;
        }
        ws[w].alive = false;
        (ws[w].addr.clone(), std::mem::take(&mut ws[w].cached))
    };
    crate::log_warn!(
        "router",
        "worker {w} ({addr}) missed heartbeats; failing over {} cached group(s)",
        groups.len()
    );
    for gck in groups {
        match place_group(shared, &gck, None, Some(w)) {
            Some(dst) => {
                shared
                    .metrics
                    .groups_failed_over
                    .fetch_add(1, Ordering::Relaxed);
                crate::log_info!(
                    "router",
                    "failover: group with {} request(s) moved from worker {w} to worker {dst}",
                    gck.clients.len()
                );
            }
            None => crate::log_warn!(
                "router",
                "failover: no survivor accepted a group from worker {w}; its clients will re-queue or time out"
            ),
        }
    }
    shared.metrics.failovers.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .migrate
        .observe_ms(t0.elapsed().as_secs_f64() * 1e3);
    shared.workers.lock().unwrap()[w].failed_over = true;
}

fn resolve(addr: &str) -> Option<SocketAddr> {
    addr.to_socket_addrs().ok().and_then(|mut it| it.next())
}

fn round_trip_worker(
    shared: &Arc<RouterShared>,
    w: usize,
    line: &str,
    timeout: Duration,
) -> Result<Value> {
    let addr = {
        let ws = shared.workers.lock().unwrap();
        if w >= ws.len() {
            return Err(Error::protocol(format!("no such worker: {w}")));
        }
        ws[w].addr.clone()
    };
    round_trip_addr(shared, &addr, line, timeout)
}

/// One connect → one line out → one line back, bounded by `timeout`.
fn round_trip_addr(
    shared: &Arc<RouterShared>,
    addr: &str,
    line: &str,
    timeout: Duration,
) -> Result<Value> {
    let sock = resolve(addr).ok_or_else(|| Error::protocol(format!("cannot resolve {addr}")))?;
    let stream = TcpStream::connect_timeout(
        &sock,
        Duration::from_millis(shared.cfg.connect_timeout_ms),
    )?;
    stream.set_read_timeout(Some(timeout))?;
    let mut writer = stream.try_clone()?;
    writer.write_all(format!("{line}\n").as_bytes())?;
    writer.flush()?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    let n = reader.read_line(&mut reply)?;
    if n == 0 {
        return Err(Error::protocol(format!("{addr} closed the connection")));
    }
    parse(reply.trim())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig;

    fn view(index: usize, alive: bool, queued: usize, inflight: usize, out: u64) -> WorkerView {
        WorkerView {
            index,
            alive,
            queued_lanes: queued,
            inflight_lanes: inflight,
            outstanding_lane_steps: out,
        }
    }

    fn req(workload: &str, seed: u64, nfe: usize) -> SampleRequest {
        SampleRequest {
            id: 1,
            workload: workload.to_string(),
            model: "gmm".to_string(),
            cfg: SamplerConfig {
                nfe,
                ..SamplerConfig::sa_default()
            },
            n: 8,
            seed,
            return_samples: false,
            want_metrics: false,
            preset: None,
            deadline_ms: None,
            priority: 0,
        }
    }

    #[test]
    fn least_loaded_prefers_cheapest_worker() {
        let p = LeastLoaded;
        let r = req("gmm", 1, 100);
        let ws = vec![
            view(0, true, 4, 4, 0),   // (4+4)*100 = 800
            view(1, true, 0, 0, 100), // 100
            view(2, false, 0, 0, 0),  // dead
        ];
        assert_eq!(p.assign(&r, &ws), Some(1));
        assert_eq!(p.assign(&r, &[view(0, false, 0, 0, 0)]), None);
    }

    #[test]
    fn round_robin_cycles_alive_workers() {
        let p = RoundRobin::new();
        let r = req("gmm", 1, 10);
        let ws = vec![
            view(0, true, 0, 0, 0),
            view(1, false, 0, 0, 0),
            view(2, true, 0, 0, 0),
        ];
        let picks: Vec<Option<usize>> = (0..4).map(|_| p.assign(&r, &ws)).collect();
        assert_eq!(picks, vec![Some(0), Some(2), Some(0), Some(2)]);
    }

    #[test]
    fn sticky_is_stable_and_spreads() {
        let p = Sticky;
        let ws = vec![view(0, true, 0, 0, 0), view(1, true, 0, 0, 0)];
        let a1 = p.assign(&req("gmm", 7, 10), &ws);
        let a2 = p.assign(&req("gmm", 7, 10), &ws);
        assert_eq!(a1, a2, "same request must stick to the same worker");
        let spread: HashSet<usize> = (0..64)
            .filter_map(|s| p.assign(&req("gmm", s, 10), &ws))
            .collect();
        assert_eq!(spread.len(), 2, "seeds should spread over both workers");
    }

    #[test]
    fn placement_by_name_resolves_all_policies() {
        for name in ["least_loaded", "round_robin", "sticky"] {
            assert_eq!(placement_by_name(name).unwrap().name(), name);
        }
        assert!(placement_by_name("nope").is_none());
    }

    #[test]
    fn chaos_hooks_arm_and_consume() {
        let c = ChaosHooks::new();
        assert!(!c.is_dropped(0));
        c.drop_heartbeats(0, true);
        assert!(c.is_dropped(0));
        c.drop_heartbeats(0, false);
        assert!(!c.is_dropped(0));
        assert!(!c.take_sever());
        c.sever_next_migration();
        assert!(c.take_sever());
        assert!(!c.take_sever());
        c.delay_heartbeats(5);
        assert_eq!(c.heartbeat_delay_ms(), 5);
    }

    #[test]
    fn router_config_from_json_overrides() {
        let v = parse(
            r#"{"addr":"127.0.0.1:0","workers":["a:1","b:2"],"placement":"sticky","heartbeat_ms":25}"#,
        )
        .unwrap();
        let cfg = RouterConfig::from_json(&v).unwrap();
        assert_eq!(cfg.addr, "127.0.0.1:0");
        assert_eq!(cfg.workers, vec!["a:1".to_string(), "b:2".to_string()]);
        assert_eq!(cfg.placement, "sticky");
        assert_eq!(cfg.heartbeat_ms, 25);
        assert_eq!(
            cfg.heartbeat_timeout_ms,
            RouterConfig::default().heartbeat_timeout_ms
        );
    }
}
