//! Dynamic batcher: groups compatible pending requests into one solver
//! loop. Compatibility = same (workload, model, solver-config) — those fix
//! the timestep grid and per-step coefficients, so merged requests share
//! every model evaluation.
//!
//! Pure data structure (no threads) so policy is unit-testable; the server
//! owns the locking and the deadline clock.

use crate::coordinator::request::SampleRequest;
use crate::jsonlite::to_string;
use std::collections::VecDeque;
use std::time::Instant;

/// Batch compatibility key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// Workload name.
    pub workload: String,
    /// Model selector.
    pub model: String,
    /// Canonical JSON of the solver config (cheap structural hash).
    pub cfg_json: String,
}

impl BatchKey {
    /// The compatibility key of one request.
    pub fn of(req: &SampleRequest) -> BatchKey {
        BatchKey {
            workload: req.workload.clone(),
            model: req.model.clone(),
            cfg_json: to_string(&req.cfg.to_json()),
        }
    }
}

/// A queued request with its arrival time and precomputed batch key
/// (computing the key serializes the solver config — do it once at push,
/// not per comparison during group extraction; see bench_perf).
#[derive(Debug)]
pub struct Pending {
    /// The queued request.
    pub request: SampleRequest,
    /// When it was enqueued (drives the batching deadline).
    pub arrived: Instant,
    key: BatchKey,
}

/// FIFO queue with compatibility-grouped extraction.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<Pending>,
    /// Total queued samples (for shedding decisions).
    queued_samples: usize,
}

impl Batcher {
    /// An empty queue.
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total samples across queued requests (for shedding decisions).
    pub fn queued_samples(&self) -> usize {
        self.queued_samples
    }

    /// Enqueue a request.
    pub fn push(&mut self, request: SampleRequest) {
        self.queued_samples += request.n;
        let key = BatchKey::of(&request);
        self.queue.push_back(Pending { request, arrived: Instant::now(), key });
    }

    /// Age of the oldest pending request.
    pub fn oldest_age(&self) -> Option<std::time::Duration> {
        self.queue.front().map(|p| p.arrived.elapsed())
    }

    /// Remove every queued request matching `pred` (cancellation before
    /// admission), preserving the order of the rest. Returns the removed
    /// requests so the caller can route their replies.
    pub fn remove_where(&mut self, pred: impl Fn(&SampleRequest) -> bool) -> Vec<SampleRequest> {
        let mut removed = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        while let Some(p) = self.queue.pop_front() {
            if pred(&p.request) {
                self.queued_samples -= p.request.n;
                removed.push(p.request);
            } else {
                kept.push_back(p);
            }
        }
        self.queue = kept;
        removed
    }

    /// Pop the oldest request plus up to `max_batch − 1` *compatible*
    /// requests (FIFO order preserved within the group; incompatible
    /// requests keep their positions).
    pub fn pop_group(&mut self, max_batch: usize) -> Vec<SampleRequest> {
        self.pop_group_pending(max_batch).into_iter().map(|p| p.request).collect()
    }

    /// [`Batcher::pop_group`] keeping each request's queue metadata
    /// (arrival time), so the server can attribute queue-wait latency at
    /// admission.
    pub fn pop_group_pending(&mut self, max_batch: usize) -> Vec<Pending> {
        let Some(first) = self.queue.pop_front() else {
            return Vec::new();
        };
        self.queued_samples -= first.request.n;
        let key = first.key.clone();
        let mut group = vec![first];
        let mut kept = VecDeque::with_capacity(self.queue.len());
        while let Some(p) = self.queue.pop_front() {
            if group.len() < max_batch && p.key == key {
                self.queued_samples -= p.request.n;
                group.push(p);
            } else {
                kept.push_back(p);
            }
        }
        self.queue = kept;
        group
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig;

    fn req(id: u64, nfe: usize, workload: &str) -> SampleRequest {
        SampleRequest {
            id,
            workload: workload.into(),
            model: "gmm".into(),
            cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
            n: 2,
            seed: id,
            return_samples: false,
            want_metrics: false,
            preset: None,
        }
    }

    #[test]
    fn groups_compatible_requests() {
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(req(2, 20, "latent_analog"));
        b.push(req(3, 40, "latent_analog")); // different nfe → incompatible
        b.push(req(4, 20, "latent_analog"));
        assert_eq!(b.queued_samples(), 8);
        let g = b.pop_group(8);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 4]);
        assert_eq!(b.len(), 1);
        assert_eq!(b.queued_samples(), 2);
        let g2 = b.pop_group(8);
        assert_eq!(g2[0].id, 3);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new();
        for id in 0..5 {
            b.push(req(id, 10, "cifar_analog"));
        }
        let g = b.pop_group(3);
        assert_eq!(g.len(), 3);
        assert_eq!(b.len(), 2);
        // Order preserved for the remainder.
        let g2 = b.pop_group(3);
        assert_eq!(g2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn different_workloads_never_merge() {
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(req(2, 20, "cifar_analog"));
        let g = b.pop_group(8);
        assert_eq!(g.len(), 1);
    }

    #[test]
    fn remove_where_cancels_queued_requests() {
        let mut b = Batcher::new();
        for id in 0..5 {
            b.push(req(id, 10, "latent_analog"));
        }
        assert_eq!(b.queued_samples(), 10);
        let removed = b.remove_where(|r| r.id % 2 == 1);
        assert_eq!(removed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.queued_samples(), 6);
        // Order of the survivors is preserved.
        let g = b.pop_group(8);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        // No match → no-op.
        assert!(b.remove_where(|_| true).is_empty());
    }

    #[test]
    fn pop_empty_is_empty() {
        let mut b = Batcher::new();
        assert!(b.pop_group(4).is_empty());
        assert!(b.oldest_age().is_none());
    }

    #[test]
    fn remove_where_emptying_the_queue_leaves_no_zero_lane_group() {
        // Regression: cancellation that removes EVERY queued request must
        // leave the batcher truly empty — a later pop_group must return an
        // empty vec (the worker drops it instead of admitting a zero-lane
        // group), the sample gauge must read 0, and the empty queue must
        // not report an oldest age (which would keep waking the deadline
        // clock for work that no longer exists).
        let mut b = Batcher::new();
        for id in 0..4 {
            b.push(req(id, 10, "latent_analog"));
        }
        let removed = b.remove_where(|_| true);
        assert_eq!(removed.len(), 4);
        assert!(b.is_empty());
        assert_eq!(b.queued_samples(), 0);
        assert!(b.oldest_age().is_none());
        assert!(b.pop_group(8).is_empty(), "empty queue must never yield a group");
        // The batcher keeps working after being emptied by cancellation.
        b.push(req(9, 10, "latent_analog"));
        let g = b.pop_group(8);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 9);
    }

    #[test]
    fn remove_where_twice_for_same_id_is_a_clean_no_op() {
        // Double-cancel of the same ticket: the second pass finds nothing
        // and removes nothing (the server turns this into a zero-count
        // reply, not an error or a double-routed response).
        let mut b = Batcher::new();
        b.push(req(1, 10, "latent_analog"));
        b.push(req(2, 10, "latent_analog"));
        let first = b.remove_where(|r| r.id == 1);
        assert_eq!(first.len(), 1);
        let second = b.remove_where(|r| r.id == 1);
        assert!(second.is_empty());
        assert_eq!(b.len(), 1);
        assert_eq!(b.queued_samples(), 2);
    }

    #[test]
    fn preset_requests_merge_with_manual_requests() {
        // The server resolves `"preset"` to a concrete config at ingress,
        // so by the time requests reach the batcher only the resolved
        // config matters: a resolved-preset request and a manual request
        // with the same config must share a key (and a batch).
        let manual = req(1, 20, "cifar_analog");
        let via_preset =
            SampleRequest { preset: Some("auto".into()), ..req(2, 20, "cifar_analog") };
        assert_eq!(BatchKey::of(&manual), BatchKey::of(&via_preset));
        let mut b = Batcher::new();
        b.push(manual);
        b.push(via_preset);
        assert_eq!(b.pop_group(8).len(), 2);
    }

    #[test]
    fn key_sensitive_to_solver_fields() {
        let mut a = req(1, 20, "w");
        let mut c = req(2, 20, "w");
        assert_eq!(BatchKey::of(&a), BatchKey::of(&c));
        c.cfg.tau = 0.5;
        assert_ne!(BatchKey::of(&a), BatchKey::of(&c));
        a.model = "artifact:dit".into();
        assert_ne!(BatchKey::of(&a), BatchKey::of(&req(3, 20, "w")));
    }
}
