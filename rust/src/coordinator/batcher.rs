//! Dynamic batcher: groups compatible pending requests into one solver
//! loop. Compatibility = same (workload, model, solver-config) — those fix
//! the timestep grid and per-step coefficients, so merged requests share
//! every model evaluation.
//!
//! Pure data structure (no threads) so policy is unit-testable; the server
//! owns the locking and the deadline clock.

use crate::coordinator::request::SampleRequest;
use crate::jsonlite::to_string;
use std::cmp::Ordering;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batch compatibility key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// Workload name.
    pub workload: String,
    /// Model selector.
    pub model: String,
    /// Canonical JSON of the solver config (cheap structural hash).
    pub cfg_json: String,
}

impl BatchKey {
    /// The compatibility key of one request.
    pub fn of(req: &SampleRequest) -> BatchKey {
        BatchKey {
            workload: req.workload.clone(),
            model: req.model.clone(),
            cfg_json: to_string(&req.cfg.to_json()),
        }
    }
}

/// A queued request with its arrival time and precomputed batch key
/// (computing the key serializes the solver config — do it once at push,
/// not per comparison during group extraction; see bench_perf).
#[derive(Debug)]
pub struct Pending {
    /// The queued request.
    pub request: SampleRequest,
    /// When it was enqueued (drives the batching deadline).
    pub arrived: Instant,
    /// Absolute deadline (`arrived + request.deadline_ms`), precomputed at
    /// push so scheduling comparisons are a plain `Instant` compare.
    pub deadline: Option<Instant>,
    key: BatchKey,
}

/// Scheduling order between two queued requests: higher priority first,
/// then earlier deadline (EDF; no deadline sorts last), ties broken by the
/// caller's scan order (arrival / FIFO). With default priorities and no
/// deadlines this is `Equal` everywhere, so extraction degenerates to the
/// original FIFO behavior.
fn sched_cmp(a: &Pending, b: &Pending) -> Ordering {
    b.request
        .priority
        .cmp(&a.request.priority)
        .then_with(|| match (a.deadline, b.deadline) {
            (Some(x), Some(y)) => x.cmp(&y),
            (Some(_), None) => Ordering::Less,
            (None, Some(_)) => Ordering::Greater,
            (None, None) => Ordering::Equal,
        })
}

/// FIFO queue with compatibility-grouped extraction.
#[derive(Debug, Default)]
pub struct Batcher {
    queue: VecDeque<Pending>,
    /// Total queued samples (for shedding decisions).
    queued_samples: usize,
}

impl Batcher {
    /// An empty queue.
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total samples across queued requests (for shedding decisions).
    pub fn queued_samples(&self) -> usize {
        self.queued_samples
    }

    /// Enqueue a request.
    pub fn push(&mut self, request: SampleRequest) {
        self.queued_samples += request.n;
        let key = BatchKey::of(&request);
        let arrived = Instant::now();
        let deadline = request
            .deadline_ms
            .and_then(|ms| arrived.checked_add(Duration::from_millis(ms)));
        self.queue.push_back(Pending { request, arrived, deadline, key });
    }

    /// Index of the best-scheduled request: highest priority, then
    /// earliest deadline, then arrival order. This is the seed the next
    /// popped group forms around.
    fn best_index(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.queue.len() {
            match best {
                None => best = Some(i),
                // Strict `Less` keeps the earliest index on ties (FIFO).
                Some(b) if sched_cmp(&self.queue[i], &self.queue[b]) == Ordering::Less => {
                    best = Some(i)
                }
                _ => {}
            }
        }
        best
    }

    /// Age of the oldest pending request.
    pub fn oldest_age(&self) -> Option<std::time::Duration> {
        self.queue.front().map(|p| p.arrived.elapsed())
    }

    /// Remove every queued request matching `pred` (cancellation before
    /// admission), preserving the order of the rest. Returns the removed
    /// requests so the caller can route their replies.
    pub fn remove_where(&mut self, pred: impl Fn(&SampleRequest) -> bool) -> Vec<SampleRequest> {
        let mut removed = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        while let Some(p) = self.queue.pop_front() {
            if pred(&p.request) {
                self.queued_samples -= p.request.n;
                removed.push(p.request);
            } else {
                kept.push_back(p);
            }
        }
        self.queue = kept;
        removed
    }

    /// Number of requests compatible with the next group's seed (the
    /// best-scheduled queued request) — the size the next popped group
    /// *could* reach, uncapped. The server's full-batch admission trigger
    /// compares this, not total queue length: a queue full of mutually
    /// incompatible requests must not force-admit an undersized group
    /// before its batching deadline.
    pub fn head_group_len(&self) -> usize {
        let Some(i) = self.best_index() else {
            return 0;
        };
        let key = &self.queue[i].key;
        self.queue.iter().filter(|p| &p.key == key).count()
    }

    /// Lane count (`n`) of the next group's seed request, for per-step
    /// lane-budget admission checks.
    pub fn head_lanes(&self) -> Option<usize> {
        self.best_index().map(|i| self.queue[i].request.n)
    }

    /// Pop the best-scheduled request plus up to `max_batch − 1`
    /// *compatible* requests (incompatible requests keep their queue
    /// positions). With default priorities and no deadlines this pops the
    /// oldest request's group in FIFO order, exactly as before.
    pub fn pop_group(&mut self, max_batch: usize) -> Vec<SampleRequest> {
        self.pop_group_pending(max_batch, usize::MAX)
            .into_iter()
            .map(|p| p.request)
            .collect()
    }

    /// [`Batcher::pop_group`] keeping each request's queue metadata
    /// (arrival time, deadline), so the server can attribute queue-wait
    /// latency and expire deadlines at admission.
    ///
    /// Group extraction is scheduling-aware: the *seed* is the
    /// best-scheduled queued request (highest priority, then earliest
    /// deadline, then arrival), and compatible members join in that same
    /// order — so when a compatibility group is oversubscribed, its most
    /// urgent members ride the first batch. `max_lanes` bounds the group's
    /// total lanes (`Σ n`); the seed is always included even when it alone
    /// exceeds the budget, so an oversized request can still make progress
    /// on an otherwise idle worker. Reordering is bit-identity-safe:
    /// every lane draws from its own request-seeded Philox stream, so a
    /// request's samples do not depend on when or with whom it ran.
    pub fn pop_group_pending(&mut self, max_batch: usize, max_lanes: usize) -> Vec<Pending> {
        let Some(seed_idx) = self.best_index() else {
            return Vec::new();
        };
        let key = self.queue[seed_idx].key.clone();
        // Compatible candidates in scheduling order (stable on ties →
        // arrival order).
        let mut cand: Vec<usize> =
            (0..self.queue.len()).filter(|&i| self.queue[i].key == key).collect();
        cand.sort_by(|&a, &b| sched_cmp(&self.queue[a], &self.queue[b]).then(a.cmp(&b)));
        let mut selected: Vec<usize> = Vec::new();
        let mut lanes = 0usize;
        for &i in &cand {
            if selected.len() >= max_batch {
                break;
            }
            let n = self.queue[i].request.n;
            if !selected.is_empty() && lanes.saturating_add(n) > max_lanes {
                continue; // over budget; a smaller member may still fit
            }
            lanes = lanes.saturating_add(n);
            selected.push(i);
        }
        // Extract the selected set in scheduling order; everyone else keeps
        // their queue position.
        let mut slot_of = std::collections::HashMap::with_capacity(selected.len());
        for (slot, &i) in selected.iter().enumerate() {
            slot_of.insert(i, slot);
        }
        let mut group: Vec<Option<Pending>> = (0..selected.len()).map(|_| None).collect();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for (i, p) in std::mem::take(&mut self.queue).into_iter().enumerate() {
            match slot_of.get(&i) {
                Some(&slot) => {
                    self.queued_samples -= p.request.n;
                    group[slot] = Some(p);
                }
                None => kept.push_back(p),
            }
        }
        self.queue = kept;
        group.into_iter().map(|p| p.expect("selected index extracted")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig;

    fn req(id: u64, nfe: usize, workload: &str) -> SampleRequest {
        SampleRequest {
            id,
            workload: workload.into(),
            model: "gmm".into(),
            cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
            n: 2,
            seed: id,
            return_samples: false,
            want_metrics: false,
            preset: None,
            deadline_ms: None,
            priority: 0,
        }
    }

    #[test]
    fn groups_compatible_requests() {
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(req(2, 20, "latent_analog"));
        b.push(req(3, 40, "latent_analog")); // different nfe → incompatible
        b.push(req(4, 20, "latent_analog"));
        assert_eq!(b.queued_samples(), 8);
        let g = b.pop_group(8);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 4]);
        assert_eq!(b.len(), 1);
        assert_eq!(b.queued_samples(), 2);
        let g2 = b.pop_group(8);
        assert_eq!(g2[0].id, 3);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new();
        for id in 0..5 {
            b.push(req(id, 10, "cifar_analog"));
        }
        let g = b.pop_group(3);
        assert_eq!(g.len(), 3);
        assert_eq!(b.len(), 2);
        // Order preserved for the remainder.
        let g2 = b.pop_group(3);
        assert_eq!(g2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn different_workloads_never_merge() {
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(req(2, 20, "cifar_analog"));
        let g = b.pop_group(8);
        assert_eq!(g.len(), 1);
    }

    #[test]
    fn remove_where_cancels_queued_requests() {
        let mut b = Batcher::new();
        for id in 0..5 {
            b.push(req(id, 10, "latent_analog"));
        }
        assert_eq!(b.queued_samples(), 10);
        let removed = b.remove_where(|r| r.id % 2 == 1);
        assert_eq!(removed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.queued_samples(), 6);
        // Order of the survivors is preserved.
        let g = b.pop_group(8);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        // No match → no-op.
        assert!(b.remove_where(|_| true).is_empty());
    }

    #[test]
    fn pop_empty_is_empty() {
        let mut b = Batcher::new();
        assert!(b.pop_group(4).is_empty());
        assert!(b.oldest_age().is_none());
    }

    #[test]
    fn remove_where_emptying_the_queue_leaves_no_zero_lane_group() {
        // Regression: cancellation that removes EVERY queued request must
        // leave the batcher truly empty — a later pop_group must return an
        // empty vec (the worker drops it instead of admitting a zero-lane
        // group), the sample gauge must read 0, and the empty queue must
        // not report an oldest age (which would keep waking the deadline
        // clock for work that no longer exists).
        let mut b = Batcher::new();
        for id in 0..4 {
            b.push(req(id, 10, "latent_analog"));
        }
        let removed = b.remove_where(|_| true);
        assert_eq!(removed.len(), 4);
        assert!(b.is_empty());
        assert_eq!(b.queued_samples(), 0);
        assert!(b.oldest_age().is_none());
        assert!(b.pop_group(8).is_empty(), "empty queue must never yield a group");
        // The batcher keeps working after being emptied by cancellation.
        b.push(req(9, 10, "latent_analog"));
        let g = b.pop_group(8);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 9);
    }

    #[test]
    fn remove_where_twice_for_same_id_is_a_clean_no_op() {
        // Double-cancel of the same ticket: the second pass finds nothing
        // and removes nothing (the server turns this into a zero-count
        // reply, not an error or a double-routed response).
        let mut b = Batcher::new();
        b.push(req(1, 10, "latent_analog"));
        b.push(req(2, 10, "latent_analog"));
        let first = b.remove_where(|r| r.id == 1);
        assert_eq!(first.len(), 1);
        let second = b.remove_where(|r| r.id == 1);
        assert!(second.is_empty());
        assert_eq!(b.len(), 1);
        assert_eq!(b.queued_samples(), 2);
    }

    #[test]
    fn preset_requests_merge_with_manual_requests() {
        // The server resolves `"preset"` to a concrete config at ingress,
        // so by the time requests reach the batcher only the resolved
        // config matters: a resolved-preset request and a manual request
        // with the same config must share a key (and a batch).
        let manual = req(1, 20, "cifar_analog");
        let via_preset =
            SampleRequest { preset: Some("auto".into()), ..req(2, 20, "cifar_analog") };
        assert_eq!(BatchKey::of(&manual), BatchKey::of(&via_preset));
        let mut b = Batcher::new();
        b.push(manual);
        b.push(via_preset);
        assert_eq!(b.pop_group(8).len(), 2);
    }

    #[test]
    fn head_group_len_counts_only_the_compatible_head_group() {
        // Regression (premature admission): the old full-batch trigger
        // compared *total* queue length against max_batch, so a queue of
        // mutually incompatible requests force-admitted an undersized head
        // group before its deadline. head_group_len must count only the
        // seed-compatible requests.
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(req(2, 20, "cifar_analog")); // incompatible
        b.push(req(3, 40, "latent_analog")); // incompatible (nfe)
        b.push(req(4, 20, "latent_analog")); // compatible with 1
        assert_eq!(b.len(), 4);
        assert_eq!(b.head_group_len(), 2, "only ids 1 and 4 share the head key");
        assert_eq!(b.head_lanes(), Some(2));
        // The old failure shape: len() >= max_batch=4 says "full batch",
        // but the group that would actually pop has just 2 members.
        assert!(b.len() >= 4 && b.head_group_len() < 4);
        let g = b.pop_group(8);
        assert_eq!(g.len(), 2);
        assert!(b.head_group_len() >= 1);
        assert_eq!(Batcher::new().head_group_len(), 0);
        assert_eq!(Batcher::new().head_lanes(), None);
    }

    #[test]
    fn priority_orders_group_extraction() {
        // Three compatible requests, the last one high-priority, max_batch
        // 2: the high-priority request must ride the first batch (seed),
        // joined by the oldest default-priority one.
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(req(2, 20, "latent_analog"));
        b.push(SampleRequest { priority: 5, ..req(3, 20, "latent_analog") });
        let g = b.pop_group(2);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 1]);
        let g2 = b.pop_group(2);
        assert_eq!(g2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn priority_selects_the_seed_across_incompatible_groups() {
        // A high-priority request in a *different* compatibility group
        // becomes the seed: its group pops first even though it arrived
        // last.
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog"));
        b.push(SampleRequest { priority: 9, ..req(2, 20, "cifar_analog") });
        assert_eq!(b.head_group_len(), 1);
        let g = b.pop_group(8);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(b.pop_group(8)[0].id, 1);
    }

    #[test]
    fn earliest_deadline_first_within_priority() {
        // Equal priority: the tighter deadline wins the seed slot; a
        // request with no deadline sorts after any deadlined one.
        let mut b = Batcher::new();
        b.push(req(1, 20, "latent_analog")); // no deadline
        b.push(SampleRequest { deadline_ms: Some(5_000), ..req(2, 20, "latent_analog") });
        b.push(SampleRequest { deadline_ms: Some(100), ..req(3, 20, "latent_analog") });
        let g = b.pop_group(8);
        assert_eq!(g.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 2, 1]);
        // Priority dominates deadline.
        let mut b = Batcher::new();
        b.push(SampleRequest { deadline_ms: Some(1), ..req(1, 20, "latent_analog") });
        b.push(SampleRequest { priority: 1, ..req(2, 20, "latent_analog") });
        assert_eq!(b.pop_group(8).iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 1]);
    }

    #[test]
    fn lane_budget_bounds_group_width() {
        // req() pushes n=2 lanes each; budget 5 fits the seed plus one
        // member (4 lanes) but not a third (6 > 5).
        let mut b = Batcher::new();
        for id in 0..4 {
            b.push(req(id, 10, "latent_analog"));
        }
        let g = b.pop_group_pending(8, 5);
        assert_eq!(g.len(), 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b.queued_samples(), 4);
        // The seed is always admitted, even alone over budget — otherwise
        // an oversized request would starve forever.
        let g = b.pop_group_pending(8, 1);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].request.id, 2);
    }

    #[test]
    fn default_requests_preserve_fifo_extraction() {
        // No priorities, no deadlines: extraction must be byte-for-byte
        // the old FIFO behavior (seed = front, members in arrival order).
        let mut b = Batcher::new();
        for id in 0..5 {
            b.push(req(id, 10, "latent_analog"));
        }
        let g = b.pop_group_pending(3, usize::MAX);
        assert_eq!(g.iter().map(|p| p.request.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(
            b.pop_group(8).iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![3, 4]
        );
    }

    #[test]
    fn key_sensitive_to_solver_fields() {
        let mut a = req(1, 20, "w");
        let mut c = req(2, 20, "w");
        assert_eq!(BatchKey::of(&a), BatchKey::of(&c));
        c.cfg.tau = 0.5;
        assert_ne!(BatchKey::of(&a), BatchKey::of(&c));
        a.model = "artifact:dit".into();
        assert_ne!(BatchKey::of(&a), BatchKey::of(&req(3, 20, "w")));
    }
}
