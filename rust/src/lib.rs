//! # sadiff — SA-Solver diffusion sampling framework
//!
//! Reproduction of *SA-Solver: Stochastic Adams Solver for Fast Sampling of
//! Diffusion Models* (Xue et al., NeurIPS 2023) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the solver machinery (stochastic Adams
//!   predictor/corrector, the full baseline-solver zoo as incremental
//!   `solvers::stepper::Stepper`s, noise schedules, τ-functions,
//!   exponentially weighted coefficient engine) plus a production sampling
//!   server (request router, dynamic batcher, step-synchronous scheduler
//!   with continuous batching and cancellation, metrics).
//! * **Layer 2 (python/compile, build-time)** — JAX denoiser models (tiny
//!   DiT, analytic GMM posterior mean) lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels, build-time)** — Pallas kernels for
//!   the per-step hot spots (fused attention, fused SA update).
//!
//! Python never runs on the request path: `runtime` loads the
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate, behind
//! the `pjrt` feature — the default build uses a hermetic stub; see
//! `runtime`). The `exec` module provides the deterministic lane-parallel
//! executor every solver loop runs on.
//!
//! Quickstart:
//! ```no_run
//! use sadiff::prelude::*;
//! let wl = sadiff::workloads::by_name("cifar_analog").unwrap();
//! let model = wl.model();
//! let cfg = SamplerConfig { nfe: 31, tau: 1.0, ..SamplerConfig::sa_default() };
//! let out = sadiff::coordinator::engine::sample(&*model, &wl, &cfg, 256, 7);
//! println!("generated {} samples of dim {}", out.n, out.dim);
//! ```

// Crate-wide lint posture for `clippy -- -D warnings` in CI: indexed loops
// over multiple parallel slices are the clearest form for the fused numeric
// kernels here, and a few lints only exist on newer clippy versions (hence
// `unknown_lints` first so the allow list itself stays portable).
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::io_other_error,
    clippy::uninlined_format_args
)]
// Rustdoc gate: every public item in the documented core — `linalg`,
// `solvers` (the stepper/snapshot layer), `coordinator`, `exec`, `obs`,
// `loadgen` —
// carries a doc comment; CI enforces it via `RUSTDOCFLAGS="-D warnings" cargo doc
// --no-deps`. Modules still outside the documented core opt out
// explicitly below so the warning stays meaningful where it is on.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod config;
pub mod coordinator;
pub mod exec;
#[allow(missing_docs)]
pub mod exps;
#[allow(missing_docs)]
pub mod gmm;
#[allow(missing_docs)]
pub mod jsonlite;
#[allow(missing_docs)]
pub mod lagrange;
pub mod linalg;
pub mod loadgen;
#[allow(missing_docs)]
pub mod metrics;
#[allow(missing_docs)]
pub mod models;
pub mod obs;
#[allow(missing_docs)]
pub mod quad;
#[allow(missing_docs)]
pub mod rng;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod schedule;
pub mod solvers;
#[allow(missing_docs)]
pub mod tau;
#[allow(missing_docs)]
pub mod testsupport;
#[allow(missing_docs)]
pub mod tuner;
#[allow(missing_docs)]
pub mod util;
#[allow(missing_docs)]
pub mod workloads;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::config::{SamplerConfig, SolverKind};
    pub use crate::exec::Executor;
    pub use crate::models::ModelEval;
    pub use crate::rng::Philox4x32;
    pub use crate::schedule::{NoiseSchedule, ScheduleKind, StepSelector};
    pub use crate::solvers::sa::{SaSolver, SaSolverOpts};
    pub use crate::solvers::stepper::{make_stepper, Stepper};
    pub use crate::tau::TauFn;
    pub use crate::tuner::{PresetRegistry, SearchSpace, TuneOptions};
    pub use crate::util::error::{Error, Result};
}
