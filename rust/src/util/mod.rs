//! Small shared utilities: error type, logging, timing, float helpers.

pub mod error;
pub mod log;
pub mod timing;

/// Relative-or-absolute closeness check used throughout tests and numerics.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// `expm1`-stable evaluation of `(1 - e^{-x})` for `x >= 0`.
pub fn one_minus_exp_neg(x: f64) -> f64 {
    -(-x).exp_m1()
}

/// Linear interpolation.
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation over a *sorted* slice; `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    lerp(sorted[lo], sorted[hi], pos - lo as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_basic() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!close(1.0, 1.1, 1e-3, 0.0));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn one_minus_exp_neg_small_x_stable() {
        let x = 1e-12;
        let v = one_minus_exp_neg(x);
        assert!(close(v, x, 1e-6, 0.0), "got {v}");
        assert!(close(one_minus_exp_neg(2.0), 1.0 - (-2.0f64).exp(), 1e-14, 0.0));
    }

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(close(mean(&xs), 2.5, 1e-15, 0.0));
        assert!(close(std_dev(&xs), (5.0f64 / 3.0).sqrt(), 1e-12, 0.0));
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 5.0);
        assert!(close(percentile_sorted(&xs, 0.5), 3.0, 1e-15, 0.0));
        assert!(close(percentile_sorted(&xs, 0.25), 2.0, 1e-15, 0.0));
    }
}
