//! Crate error type. We deliberately keep a single flat enum: the failure
//! domains (config, runtime/PJRT, protocol, numerics) are few and the
//! coordinator wants cheap `?` propagation across all of them.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error.
#[derive(Debug)]
pub enum Error {
    /// Configuration / CLI problems (bad flag, missing field, bad value).
    Config(String),
    /// JSON parse or encode failures.
    Json(String),
    /// PJRT / artifact loading and execution failures.
    Runtime(String),
    /// Wire-protocol violations on the sampling server.
    Protocol(String),
    /// Numerical preconditions violated (non-PSD matrix, empty sample set...).
    Numerics(String),
    /// I/O wrapper.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Numerics(m) => write!(f, "numerics error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    pub fn config(m: impl Into<String>) -> Self {
        Error::Config(m.into())
    }
    pub fn json(m: impl Into<String>) -> Self {
        Error::Json(m.into())
    }
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
    pub fn protocol(m: impl Into<String>) -> Self {
        Error::Protocol(m.into())
    }
    pub fn numerics(m: impl Into<String>) -> Self {
        Error::Numerics(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_roundtrip() {
        let e = Error::config("bad nfe");
        assert_eq!(e.to_string(), "config error: bad nfe");
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "x").into();
        assert!(e.to_string().contains("io error"));
    }
}
