//! Wall-clock timing helpers for benches and serving metrics.

use std::time::Instant;

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Run `f` `iters` times and return (mean, min) seconds per iteration.
/// Used by the in-repo bench harness (criterion is unavailable offline).
pub fn time_it<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64) {
    assert!(iters > 0);
    let mut min = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        total += dt;
        if dt < min {
            min = dt;
        }
    }
    (total / iters as f64, min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a);
        let lap = sw.lap();
        assert!(lap >= 0.0);
    }

    #[test]
    fn time_it_counts() {
        let mut n = 0;
        let (mean, min) = time_it(5, || n += 1);
        assert_eq!(n, 5);
        assert!(mean >= min);
    }
}
