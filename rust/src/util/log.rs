//! Minimal leveled logger. The serving hot path logs nothing by default;
//! level is process-global and read with a relaxed atomic so a disabled
//! log line costs one load.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level name ("error".."trace") and set the global level. An
/// unknown name is an error and leaves the level unchanged — a typo like
/// `--log tracee` must be reported at the CLI, not silently mapped to
/// Info.
pub fn set_level_by_name(name: &str) -> Result<(), String> {
    let lvl = match name.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        other => {
            return Err(format!(
                "unknown log level '{other}' (expected error|warn|info|debug|trace)"
            ))
        }
    };
    set_level(lvl);
    Ok(())
}

/// Whether `level` is currently enabled.
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one log line to stderr. Use through the `log_*!` macros.
pub fn emit(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0);
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{now} {tag} {module}] {msg}");
}

/// `log_info!(module, fmt, args...)` and friends.
#[macro_export]
macro_rules! log_error {
    ($m:expr, $($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, $m, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($m:expr, $($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, $m, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($m:expr, $($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, $m, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($m:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::emit($crate::util::log::Level::Debug, $m, &format!($($arg)*))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn name_parse() {
        assert!(set_level_by_name("debug").is_ok());
        assert!(enabled(Level::Debug));
        // Unknown names error and leave the level exactly where it was.
        let err = set_level_by_name("tracee").unwrap_err();
        assert!(err.contains("tracee"), "{err}");
        assert!(enabled(Level::Debug));
        assert!(set_level_by_name("INFO").is_ok()); // case-insensitive; restore default
    }
}
