//! The persistent parked worker pool behind [`Executor`](super::Executor).
//!
//! Lifecycle: `Pool::new(workers)` spawns `workers` OS threads once, each
//! named `sadiff-exec-{index}` for the lifetime of the pool (so trace
//! lanes and `ps -T` output are stable — one Perfetto lane per pool
//! worker, not one per dispatch). Between dispatches every worker is
//! parked on a condvar; nothing spins.
//!
//! Dispatch protocol (an epoch barrier plus a completion latch):
//!
//! 1. The dispatching caller serializes on `dispatch_lock` (two engine
//!    workers sharing one server pool never interleave epochs, and the
//!    active thread count stays bounded by the pool width no matter how
//!    many callers share it), then publishes under the state mutex: a
//!    type-erased pointer to its borrowed chunk task, the participating
//!    part count, and a bumped `epoch`.
//! 2. Workers wake on the epoch change. Worker `w` runs part `w + 1` iff
//!    `w < parts - 1` — the caller itself runs part `0` inline, so a
//!    pool of `threads - 1` workers serves `threads`-wide dispatches.
//!    Parts are *statically assigned* — no queue, no stealing — so which
//!    thread computes which chunk is a pure function of the dispatch
//!    shape, and the determinism argument of the scoped-spawn era
//!    carries over unchanged.
//! 3. Each participating worker decrements `remaining`; the last one
//!    signals the completion latch the caller is blocked on. The caller
//!    clears the task pointer before returning, so the erased borrow
//!    never outlives the dispatch.
//!
//! Panic safety: the caller's part and every worker part run under
//! `catch_unwind`. A panicking part still decrements the latch (no
//! deadlocked caller); the caller re-raises — its own payload, or a
//! summary panic for worker failures — and every lock acquisition
//! shrugs off poisoning, so the pool remains usable for subsequent
//! dispatches. Teardown on `Drop` flips `shutdown`, wakes everyone and
//! joins all handles; [`live_pool_workers`] exposes a process-wide count
//! so tests can prove no thread leaks across create/drop cycles.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use crate::obs::trace;

/// Process-wide count of live pool worker threads (incremented at spawn,
/// decremented as each worker exits). Test hook for the no-leak
/// contract: repeated `Executor` create/drop cycles must return this to
/// its baseline.
pub fn live_pool_workers() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Poison-tolerant lock: a panicking chunk task must leave the pool
/// usable, not wedge every later dispatch on a poisoned mutex. The
/// guarded state is plain bookkeeping (epoch/counters), valid at every
/// instruction boundary, so recovering the guard is sound.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Type-erased pointer to the caller's borrowed chunk task: a thin data
/// pointer plus a monomorphized call shim. The erasure drops the borrow
/// lifetime, but the pointer is published under the state mutex,
/// dereferenced only by workers participating in the current epoch, and
/// cleared before `dispatch` returns — and `dispatch` blocks on the
/// completion latch, so the borrow it erases is live for every
/// dereference.
#[derive(Clone, Copy)]
struct Task {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: `data` points at a `Sync` closure (shared `&`-calls from many
// threads are fine) and `Task` is only a capability to make such calls;
// handing it to pool workers is the scoped-spawn pattern without the
// scope, with the latch standing in for the join.
unsafe impl Send for Task {}

/// The `call` shim instantiated per concrete closure type by
/// [`Pool::dispatch`].
///
/// # Safety
/// `data` must point to a live `F` (guaranteed by the dispatch latch).
unsafe fn call_erased<F: Fn(usize)>(data: *const (), part: usize) {
    (*data.cast::<F>())(part)
}

/// Barrier state shared between the dispatcher and the parked workers.
struct State {
    /// Bumped once per dispatch; workers wake when it passes their view.
    epoch: u64,
    /// Pool is tearing down — workers exit instead of parking.
    shutdown: bool,
    /// The current dispatch's chunk task (`None` between dispatches).
    task: Option<Task>,
    /// Trace-span name for the current dispatch's worker parts.
    span_name: &'static str,
    /// Number of *worker* parts in the current dispatch (the caller's
    /// part 0 excluded). Worker `w` participates iff `w < parts`.
    parts: usize,
    /// Completion latch: worker parts not yet finished this epoch.
    remaining: usize,
    /// Worker parts that panicked this epoch.
    panicked: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between dispatches.
    work_cv: Condvar,
    /// The dispatching caller parks here until `remaining == 0`.
    done_cv: Condvar,
}

/// A fixed set of parked worker threads; see the module docs for the
/// dispatch protocol.
pub(super) struct Pool {
    shared: Arc<Shared>,
    /// Serializes concurrent dispatches from independent callers (e.g.
    /// several server engine workers sharing the one server pool).
    dispatch_lock: Mutex<()>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn `workers` parked threads. The pool serves dispatches up to
    /// `workers + 1` parts wide — the caller runs part 0 itself.
    pub(super) fn new(workers: usize) -> Pool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                shutdown: false,
                task: None,
                span_name: "exec_chunk",
                parts: 0,
                remaining: 0,
                panicked: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("sadiff-exec-{w}"))
                    .spawn(move || worker_main(&shared, w))
                    .expect("spawn exec pool worker")
            })
            .collect();
        Pool { shared, dispatch_lock: Mutex::new(()), workers, handles }
    }

    /// Maximum dispatch width this pool serves (worker count plus the
    /// caller's own part).
    pub(super) fn width(&self) -> usize {
        self.workers + 1
    }

    /// Run `task(part)` for every `part in 0..parts`: part 0 inline on
    /// the caller, parts `1..parts` on pool workers, blocking until all
    /// parts complete. Panics (after the latch opens) if any part
    /// panicked.
    pub(super) fn dispatch<F>(&self, parts: usize, span_name: &'static str, task: &F)
    where
        F: Fn(usize) + Sync,
    {
        debug_assert!(parts >= 1 && parts <= self.width(), "dispatch wider than the pool");
        if parts == 1 {
            let _span = trace::span(span_name, "exec");
            task(0);
            return;
        }
        let _serialize = lock(&self.dispatch_lock);
        let worker_parts = parts - 1;
        let data = (task as *const F).cast::<()>();
        {
            let mut st = lock(&self.shared.state);
            st.epoch += 1;
            st.task = Some(Task { data, call: call_erased::<F> });
            st.span_name = span_name;
            st.parts = worker_parts;
            st.remaining = worker_parts;
            st.panicked = 0;
        }
        self.shared.work_cv.notify_all();
        let caller = catch_unwind(AssertUnwindSafe(|| {
            let _span = trace::span(span_name, "exec");
            task(0);
        }));
        // Always wait out the latch — even when part 0 panicked — so no
        // worker can still hold the erased pointer once we unwind.
        let worker_panics = {
            let mut st = lock(&self.shared.state);
            while st.remaining > 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.task = None;
            st.panicked
        };
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if worker_panics > 0 {
            panic!("exec pool: {worker_panics} worker chunk task(s) panicked (pool still usable)");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        lock(&self.shared.state).shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            // A worker's task panic is caught inside `worker_main`; join
            // only fails if the thread died outside it, which teardown
            // doesn't amplify.
            let _ = h.join();
        }
    }
}

fn worker_main(shared: &Shared, index: usize) {
    struct Live;
    impl Drop for Live {
        fn drop(&mut self) {
            LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _live = Live;
    let mut seen = 0u64;
    loop {
        let (task, span_name) = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    seen = st.epoch;
                    if index < st.parts {
                        break (st.task.expect("dispatch published no task"), st.span_name);
                    }
                    // Not assigned a part this epoch; park again. The
                    // dispatcher cannot start the next epoch before this
                    // one's latch opens, so skipping is race-free.
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            let _span = trace::span(span_name, "exec");
            // SAFETY: the dispatcher blocks on the completion latch we
            // have not yet decremented, so the erased borrow is live.
            unsafe { (task.call)(task.data, index + 1) }
        }))
        .is_err();
        let mut st = lock(&shared.state);
        if panicked {
            st.panicked += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_one();
        }
    }
}
