//! Deterministic lane-parallel executor: a std-thread chunked worker pool
//! (tokio/rayon are not in the offline vendor set — see
//! `coordinator::server`) that splits the `n` independent lanes of a solve
//! into per-thread contiguous chunks.
//!
//! Determinism contract: every per-lane computation in this codebase is
//! keyed by the lane's *global* index — Philox noise streams use
//! `(stream = lane, step)` counters and model evaluations are row-wise —
//! so executing lanes `[lo, hi)` on a worker with a lane-offset noise
//! source produces bit-identical results to the same lanes inside a
//! sequential full-batch run. `solvers::run_chunked` relies on exactly
//! this invariant (asserted for every `SolverKind` in `solvers::tests`),
//! which is the same invariant `coordinator::engine` already maintains for
//! request batching.
//!
//! Scheduling is static (equal-size contiguous chunks) rather than
//! work-stealing: lanes of one solve are homogeneous, so static chunks
//! avoid any cross-thread queue traffic on the hot path.

use std::num::NonZeroUsize;
use std::ops::Range;

/// Number of worker threads the `0 = auto` knob resolves to: the
/// `SADIFF_THREADS` env var when set to a positive integer (global
/// override for benches/experiments without a CLI knob), else one per
/// available core.
pub fn auto_threads() -> usize {
    if let Some(n) = std::env::var("SADIFF_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        if n > 0 {
            return n;
        }
    }
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// Split `0..n` into at most `parts` contiguous, non-empty, balanced
/// ranges (sizes differ by at most one; earlier chunks are larger).
pub fn chunks(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// A fixed-width worker pool. Threads are scoped per call (no idle pool to
/// manage or shut down); the thread count is the only state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Executor {
    threads: usize,
}

impl Executor {
    /// `threads = 0` means auto (one per available core).
    pub fn new(threads: usize) -> Executor {
        let threads = if threads == 0 { auto_threads() } else { threads };
        Executor { threads }
    }

    /// One worker per available core.
    pub fn auto() -> Executor {
        Executor::new(0)
    }

    /// Single-threaded executor (runs everything inline on the caller).
    pub fn sequential() -> Executor {
        Executor { threads: 1 }
    }

    /// Resolved worker count (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` once per chunk of `0..n` (at most [`Self::threads`] chunks,
    /// one scoped thread each) and return the per-chunk results in chunk
    /// order. With one chunk, `f` runs inline on the caller thread.
    pub fn run_chunks<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        let ranges = chunks(n, self.threads);
        if ranges.len() <= 1 {
            return ranges.into_iter().map(f).collect();
        }
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .into_iter()
                .map(|r| {
                    std::thread::Builder::new()
                        .name(format!("sadiff-exec-{}", r.start))
                        .spawn_scoped(s, move || {
                            let _span = crate::obs::trace::span("exec_chunk", "exec");
                            f(r)
                        })
                        .expect("spawn exec worker")
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("exec worker panicked")).collect()
        })
    }

    /// Run `f` once per item with exclusive access, one scoped thread per
    /// item (callers pass at most [`Self::threads`] items — the step-level
    /// scheduler's lane shards). With one thread (or ≤ 1 item) everything
    /// runs inline on the caller.
    ///
    /// Threads are spawned per call, so a step-level driver pays one
    /// spawn/join cycle per shard per step when `threads > 1`. That
    /// overhead is measured by `bench_perf`'s stepper section
    /// (`per_step_overhead_us` in `BENCH_stepper.json`); the serving
    /// default (`ServerConfig.threads = 1`) takes the inline path and
    /// pays nothing.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let f = &f;
        std::thread::scope(|s| {
            for (i, item) in items.iter_mut().enumerate() {
                std::thread::Builder::new()
                    .name(format!("sadiff-step-{i}"))
                    .spawn_scoped(s, move || {
                        let _span = crate::obs::trace::span("exec_chunk", "exec");
                        f(i, item)
                    })
                    .expect("spawn step worker");
            }
        });
    }

    /// Parallel map over independent items, preserving item order. Each
    /// worker handles one contiguous chunk of the item list.
    pub fn map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(usize, &I) -> T + Sync,
    {
        self.run_chunks(items.len(), |r| r.map(|i| f(i, &items[i])).collect::<Vec<T>>())
            .into_iter()
            .flatten()
            .collect()
    }
}

impl Default for Executor {
    fn default() -> Self {
        Executor::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_and_balance() {
        // n % threads != 0: sizes differ by at most one, cover 0..n in order.
        let cs = chunks(10, 4);
        assert_eq!(cs, vec![0..3, 3..6, 6..8, 8..10]);
        // n < threads: one lane per chunk, no empty chunks.
        let cs = chunks(3, 8);
        assert_eq!(cs, vec![0..1, 1..2, 2..3]);
        // threads = 1: a single full-width chunk.
        assert_eq!(chunks(7, 1), vec![0..7]);
        // n = 0: nothing to do.
        assert!(chunks(0, 4).is_empty());
        // Exact division.
        assert_eq!(chunks(8, 4), vec![0..2, 2..4, 4..6, 6..8]);
    }

    #[test]
    fn executor_resolves_thread_count() {
        assert!(Executor::new(0).threads() >= 1);
        assert_eq!(Executor::new(3).threads(), 3);
        assert_eq!(Executor::sequential().threads(), 1);
        assert_eq!(Executor::default().threads(), Executor::auto().threads());
    }

    #[test]
    fn run_chunks_matches_sequential_order() {
        for (n, threads) in [(10usize, 4usize), (3, 8), (7, 1), (16, 4), (1, 4), (0, 2)] {
            let exec = Executor::new(threads);
            let got: Vec<usize> = exec
                .run_chunks(n, |r| r.collect::<Vec<_>>())
                .into_iter()
                .flatten()
                .collect();
            let want: Vec<usize> = (0..n).collect();
            assert_eq!(got, want, "n={n} threads={threads}");
        }
    }

    #[test]
    fn for_each_mut_visits_every_item_once() {
        for threads in [1usize, 2, 8] {
            let mut items: Vec<u64> = (0..5).collect();
            Executor::new(threads).for_each_mut(&mut items, |i, v| {
                assert_eq!(*v, i as u64);
                *v += 100;
            });
            assert_eq!(items, vec![100, 101, 102, 103, 104]);
        }
        let mut empty: Vec<u64> = Vec::new();
        Executor::new(4).for_each_mut(&mut empty, |_, _| panic!("no items"));
    }

    #[test]
    fn map_preserves_order_and_indices() {
        let items: Vec<u64> = (0..23).collect();
        for threads in [1usize, 2, 5, 64] {
            let exec = Executor::new(threads);
            let got = exec.map(&items, |i, v| (i, v * 2));
            for (i, (gi, gv)) in got.iter().enumerate() {
                assert_eq!(*gi, i);
                assert_eq!(*gv, items[i] * 2);
            }
        }
    }

    #[test]
    fn parallel_equals_sequential_reduction() {
        let seq: Vec<u64> = Executor::sequential()
            .run_chunks(100, |r| r.map(|i| (i as u64) * (i as u64)).sum::<u64>());
        let par: u64 = Executor::new(7)
            .run_chunks(100, |r| r.map(|i| (i as u64) * (i as u64)).sum::<u64>())
            .into_iter()
            .sum();
        assert_eq!(seq.into_iter().sum::<u64>(), par);
    }
}
