//! Deterministic lane-parallel executor: a **persistent parked worker
//! pool** (tokio/rayon are not in the offline vendor set — see
//! `coordinator::server`) that splits the `n` independent lanes of a
//! solve into per-thread contiguous chunks.
//!
//! Threads are created once per [`Executor`] (named `sadiff-exec-N`,
//! stable for the pool's lifetime) and parked on a condvar between
//! dispatches; each `run_chunks`/`for_each_mut`/`map` call publishes a
//! borrowed closure through an epoch barrier, workers claim their
//! statically assigned chunk and the caller blocks on a completion
//! latch. The per-call cost is one mutex/condvar round-trip instead of a
//! thread spawn/join cycle per chunk — the difference is measured in the
//! `exec` section of `BENCH_perf.json` (`bench_perf`). `threads == 1`
//! keeps the zero-cost inline path (no pool is created at all), and
//! dispatching allocates nothing, so the stepper's zero-allocs/step
//! contract holds with the pool active (`integration_alloc`).
//!
//! Determinism contract: every per-lane computation in this codebase is
//! keyed by the lane's *global* index — Philox noise streams use
//! `(stream = lane, step)` counters and model evaluations are row-wise —
//! so executing lanes `[lo, hi)` on a worker with a lane-offset noise
//! source produces bit-identical results to the same lanes inside a
//! sequential full-batch run. `solvers::run_chunked` relies on exactly
//! this invariant (asserted for every `SolverKind` in `solvers::tests`),
//! which is the same invariant `coordinator::engine` already maintains
//! for request batching.
//!
//! Scheduling is static (equal-size contiguous chunks, same [`chunks`]
//! math as ever) rather than work-stealing: lanes of one solve are
//! homogeneous, so static chunks avoid any cross-thread queue traffic on
//! the hot path — and which chunk runs where is a pure function of
//! `(n, parts)`, so the pool preserves every bit-identity contract the
//! scoped-spawn executor satisfied.

mod pool;

pub use pool::live_pool_workers;

use std::num::NonZeroUsize;
use std::ops::Range;
use std::sync::Arc;

use pool::Pool;

/// Number of worker threads the `0 = auto` knob resolves to: the
/// `SADIFF_THREADS` env var when set to a positive integer (global
/// override for benches/experiments without a CLI knob), else one per
/// available core. A set-but-unusable value (unparsable, or zero) is
/// rejected with a logged warning naming it, then falls through to the
/// core count.
pub fn auto_threads() -> usize {
    if let Ok(v) = std::env::var("SADIFF_THREADS") {
        match v.parse::<usize>() {
            Ok(n) if n > 0 => return n,
            _ => crate::log_warn!(
                "exec",
                "ignoring SADIFF_THREADS={v:?}: expected a positive integer; \
                 falling back to the available-core count"
            ),
        }
    }
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// Split `0..n` into at most `parts` contiguous, non-empty, balanced
/// ranges (sizes differ by at most one; earlier chunks are larger).
pub fn chunks(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let mut out = Vec::with_capacity(parts);
    for i in 0..parts {
        out.push(chunk_of(n, parts, i));
    }
    debug_assert_eq!(out.last().map(|r| r.end), Some(n));
    out
}

/// Chunk `i` of [`chunks`]`(n, parts)` without materializing the table —
/// the same balanced-contiguous math, O(1) and allocation-free. The
/// pool's `for_each_mut` dispatch path uses this so a warm dispatch
/// touches no heap. `parts` must already be clamped to `1..=n`.
fn chunk_of(n: usize, parts: usize, i: usize) -> Range<usize> {
    debug_assert!(parts >= 1 && parts <= n && i < parts);
    let base = n / parts;
    let rem = n % parts;
    let start = i * base + i.min(rem);
    start..start + base + usize::from(i < rem)
}

/// Raw-pointer wrapper the dispatch closures use to hand workers
/// exclusive access to disjoint regions of a caller-owned buffer. Each
/// use site carries its own disjointness argument; the pointer is only
/// live for the duration of the (blocking) dispatch.
#[derive(Clone, Copy)]
struct SharedPtr<T>(*mut T);

// SAFETY: `SharedPtr` is a capability to reach `T`s across the dispatch
// threads; the per-site disjointness invariants make the accesses
// exclusive, so `T: Send` is the only requirement.
unsafe impl<T: Send> Send for SharedPtr<T> {}
unsafe impl<T: Send> Sync for SharedPtr<T> {}

/// A fixed-width executor over a persistent parked worker pool. The pool
/// (`threads - 1` OS threads; the dispatching caller always runs chunk 0
/// itself) is spawned once in [`Executor::new`] and joined when the last
/// clone drops; `threads == 1` creates no pool and runs everything
/// inline. Clones share the same pool, so a server hands every engine
/// worker one long-lived pool instead of re-deriving executors;
/// concurrent dispatches from independent callers are serialized, which
/// also bounds the active thread count at the pool width no matter how
/// many callers share it.
#[derive(Clone)]
pub struct Executor {
    threads: usize,
    pool: Option<Arc<Pool>>,
}

impl Executor {
    /// `threads = 0` means auto (one per available core, see
    /// [`auto_threads`]). Spawns the `threads - 1` pool workers eagerly so
    /// the first dispatch pays no setup.
    pub fn new(threads: usize) -> Executor {
        let threads = if threads == 0 { auto_threads() } else { threads };
        let pool = if threads > 1 { Some(Arc::new(Pool::new(threads - 1))) } else { None };
        Executor { threads, pool }
    }

    /// One worker per available core.
    pub fn auto() -> Executor {
        Executor::new(0)
    }

    /// Single-threaded executor (runs everything inline on the caller;
    /// never spawns a pool).
    pub fn sequential() -> Executor {
        Executor { threads: 1, pool: None }
    }

    /// Resolved worker count (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` once per chunk of `0..n` (at most [`Self::threads`] chunks,
    /// statically assigned to pool workers) and return the per-chunk
    /// results in chunk order. With one chunk, `f` runs inline on the
    /// caller thread.
    pub fn run_chunks<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        let ranges = chunks(n, self.threads);
        let (pool, parts) = match (&self.pool, ranges.len()) {
            (Some(pool), parts) if parts > 1 => (pool, parts),
            _ => return ranges.into_iter().map(f).collect(),
        };
        let mut slots: Vec<Option<T>> = (0..parts).map(|_| None).collect();
        let slots_ptr = SharedPtr(slots.as_mut_ptr());
        let ranges = &ranges;
        let f = &f;
        pool.dispatch(parts, "exec_chunk", &move |part| {
            let value = f(ranges[part].clone());
            // SAFETY: part indices are distinct within a dispatch and
            // `slots` has exactly `parts` elements, so each part writes
            // its own slot exclusively; the caller blocks until all
            // parts finish before touching `slots` again.
            unsafe { *slots_ptr.0.add(part) = Some(value) };
        });
        slots.into_iter().map(|s| s.expect("exec pool part did not run")).collect()
    }

    /// Run `f` once per item with exclusive access, items statically
    /// chunked over the pool (callers typically pass at most
    /// [`Self::threads`] items — the step-level scheduler's lane shards —
    /// giving one item per part). With one thread (or ≤ 1 item)
    /// everything runs inline on the caller.
    ///
    /// The dispatch reuses parked pool workers and allocates nothing, so
    /// a step-level driver pays one condvar round-trip per step instead
    /// of the scoped-spawn era's spawn/join cycle per shard per step
    /// (before/after numbers: `per_step_overhead_us` in
    /// `BENCH_stepper.json` and the `exec` section of `BENCH_perf.json`).
    /// Shard dispatches record `exec_shard` spans, distinct from
    /// `run_chunks`'s `exec_chunk` spans.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let (pool, parts) = match (&self.pool, n.min(self.threads)) {
            (Some(pool), parts) if parts > 1 => (pool, parts),
            _ => {
                for (i, item) in items.iter_mut().enumerate() {
                    f(i, item);
                }
                return;
            }
        };
        let items_ptr = SharedPtr(items.as_mut_ptr());
        let f = &f;
        pool.dispatch(parts, "exec_shard", &move |part| {
            // SAFETY: `chunk_of` ranges partition `0..n`, so parts touch
            // disjoint items; the caller blocks until every part
            // finishes before reusing the borrow.
            for i in chunk_of(n, parts, part) {
                f(i, unsafe { &mut *items_ptr.0.add(i) });
            }
        });
    }

    /// Parallel map over independent items, preserving item order. Each
    /// worker handles one contiguous chunk of the item list.
    pub fn map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(usize, &I) -> T + Sync,
    {
        self.run_chunks(items.len(), |r| r.map(|i| f(i, &items[i])).collect::<Vec<T>>())
            .into_iter()
            .flatten()
            .collect()
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("threads", &self.threads)
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl Default for Executor {
    fn default() -> Self {
        Executor::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_and_balance() {
        // n % threads != 0: sizes differ by at most one, cover 0..n in order.
        let cs = chunks(10, 4);
        assert_eq!(cs, vec![0..3, 3..6, 6..8, 8..10]);
        // n < threads: one lane per chunk, no empty chunks.
        let cs = chunks(3, 8);
        assert_eq!(cs, vec![0..1, 1..2, 2..3]);
        // threads = 1: a single full-width chunk.
        assert_eq!(chunks(7, 1), vec![0..7]);
        // n = 0: nothing to do.
        assert!(chunks(0, 4).is_empty());
        // Exact division.
        assert_eq!(chunks(8, 4), vec![0..2, 2..4, 4..6, 6..8]);
    }

    #[test]
    fn chunk_of_matches_chunk_table() {
        // The O(1) per-part math the pool dispatch path uses must agree
        // with the materialized table for every (n, parts, i).
        for n in 1usize..40 {
            for parts in 1..=n {
                let table = chunks(n, parts);
                for (i, want) in table.iter().enumerate() {
                    assert_eq!(chunk_of(n, parts, i), *want, "n={n} parts={parts} i={i}");
                }
            }
        }
    }

    #[test]
    fn executor_resolves_thread_count() {
        assert!(Executor::new(0).threads() >= 1);
        assert_eq!(Executor::new(3).threads(), 3);
        assert_eq!(Executor::sequential().threads(), 1);
        assert_eq!(Executor::default().threads(), Executor::auto().threads());
    }

    #[test]
    fn run_chunks_matches_sequential_order() {
        for (n, threads) in [(10usize, 4usize), (3, 8), (7, 1), (16, 4), (1, 4), (0, 2)] {
            let exec = Executor::new(threads);
            let got: Vec<usize> = exec
                .run_chunks(n, |r| r.collect::<Vec<_>>())
                .into_iter()
                .flatten()
                .collect();
            let want: Vec<usize> = (0..n).collect();
            assert_eq!(got, want, "n={n} threads={threads}");
        }
    }

    #[test]
    fn for_each_mut_visits_every_item_once() {
        for threads in [1usize, 2, 8] {
            let mut items: Vec<u64> = (0..5).collect();
            Executor::new(threads).for_each_mut(&mut items, |i, v| {
                assert_eq!(*v, i as u64);
                *v += 100;
            });
            assert_eq!(items, vec![100, 101, 102, 103, 104]);
        }
        // More items than threads: parts chunk the item list.
        let mut items: Vec<u64> = (0..37).collect();
        Executor::new(4).for_each_mut(&mut items, |i, v| *v = v.wrapping_add(i as u64));
        assert!(items.iter().enumerate().all(|(i, v)| *v == 2 * i as u64));
        let mut empty: Vec<u64> = Vec::new();
        Executor::new(4).for_each_mut(&mut empty, |_, _| panic!("no items"));
    }

    #[test]
    fn map_preserves_order_and_indices() {
        let items: Vec<u64> = (0..23).collect();
        for threads in [1usize, 2, 5, 64] {
            let exec = Executor::new(threads);
            let got = exec.map(&items, |i, v| (i, v * 2));
            for (i, (gi, gv)) in got.iter().enumerate() {
                assert_eq!(*gi, i);
                assert_eq!(*gv, items[i] * 2);
            }
        }
    }

    #[test]
    fn parallel_equals_sequential_reduction() {
        let seq: Vec<u64> = Executor::sequential()
            .run_chunks(100, |r| r.map(|i| (i as u64) * (i as u64)).sum::<u64>());
        let par: u64 = Executor::new(7)
            .run_chunks(100, |r| r.map(|i| (i as u64) * (i as u64)).sum::<u64>())
            .into_iter()
            .sum();
        assert_eq!(seq.into_iter().sum::<u64>(), par);
    }

    #[test]
    fn clones_share_one_pool_and_dispatch_repeatedly() {
        let exec = Executor::new(4);
        let clone = exec.clone();
        for round in 0..200u64 {
            let sums = exec.run_chunks(64, |r| r.map(|i| i as u64 + round).sum::<u64>());
            let sums2 = clone.run_chunks(64, |r| r.map(|i| i as u64 + round).sum::<u64>());
            assert_eq!(sums, sums2);
        }
    }
}
