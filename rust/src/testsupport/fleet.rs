//! In-process fleet harness: a router plus K workers with deterministic
//! fault injection, for the chaos/migration integration suite.
//!
//! Faults are described by a [`FaultPlan`] — a seeded schedule of
//! kill/drop/delay/sever events keyed by worker id and a *step index*
//! trigger (the fleet-wide solver-step counter) — so every chaos test
//! names its seed and replays exactly. `FaultPlan::generate(seed, ..)`
//! is a pure function of its arguments; logging the seed is logging the
//! full schedule.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::coordinator::router::{ChaosHooks, Router, RouterConfig, RouterHandle};
use crate::coordinator::server::{Client, Server, ServerHandle};
use crate::jsonlite::{parse, to_string, Value};
use crate::rng::Xoshiro256pp;

/// One injectable fault, triggered when the fleet-wide solver-step
/// counter reaches `at_step`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultEvent {
    /// Crash worker `worker` (no drain, no goodbye — like `kill -9`).
    KillWorker { worker: usize, at_step: u64 },
    /// Swallow heartbeat polls to `worker` for `for_ms` milliseconds;
    /// the worker stays healthy but looks silent to the router.
    DropHeartbeats { worker: usize, at_step: u64, for_ms: u64 },
    /// Delay every heartbeat sweep by `ms` for the rest of the run.
    DelayHeartbeats { at_step: u64, ms: u64 },
    /// Sever the next `migrate_in` connection mid-handoff; the router
    /// must keep the checkpoint and retry.
    SeverMigration { at_step: u64 },
}

impl FaultEvent {
    /// The solver-step trigger for this event.
    pub fn at_step(&self) -> u64 {
        match self {
            FaultEvent::KillWorker { at_step, .. }
            | FaultEvent::DropHeartbeats { at_step, .. }
            | FaultEvent::DelayHeartbeats { at_step, .. }
            | FaultEvent::SeverMigration { at_step } => *at_step,
        }
    }
}

/// A seeded, fully deterministic schedule of fault events. Two plans
/// generated with the same `(seed, workers, max_step)` are identical.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// The seed this plan was generated from (the replay key).
    pub seed: u64,
    /// Events, sorted by their step trigger.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Generate 1..=3 events from `seed`, with step triggers in
    /// `0..max_step` and worker ids in `0..workers`.
    pub fn generate(seed: u64, workers: usize, max_step: u64) -> FaultPlan {
        let mut rng = Xoshiro256pp::new(seed);
        let n = 1 + rng.below(3) as usize;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let at_step = rng.below(max_step.max(1));
            let worker = rng.below(workers.max(1) as u64) as usize;
            let ev = match rng.below(4) {
                0 => FaultEvent::KillWorker { worker, at_step },
                1 => FaultEvent::DropHeartbeats {
                    worker,
                    at_step,
                    for_ms: 20 + rng.below(80),
                },
                2 => FaultEvent::DelayHeartbeats {
                    at_step,
                    ms: 1 + rng.below(20),
                },
                _ => FaultEvent::SeverMigration { at_step },
            };
            events.push(ev);
        }
        events.sort_by_key(|e| e.at_step());
        FaultPlan { seed, events }
    }

    /// One-line description for seed logs and failure messages.
    pub fn describe(&self) -> String {
        format!("FaultPlan seed={} events={:?}", self.seed, self.events)
    }
}

/// Fleet shape: worker count, placement policy, heartbeat cadence and
/// the per-worker server template (address is always overridden to an
/// ephemeral port and snapshot publishing is forced on).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of worker processes (in-process servers).
    pub workers: usize,
    /// Placement policy name handed to the router.
    pub placement: String,
    /// Router heartbeat poll interval (fast, for tests).
    pub heartbeat_ms: u64,
    /// Dead-worker declaration threshold.
    pub heartbeat_timeout_ms: u64,
    /// Worker config template.
    pub server: ServerConfig,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            workers: 2,
            placement: "least_loaded".to_string(),
            heartbeat_ms: 25,
            heartbeat_timeout_ms: 150,
            server: ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                publish_snapshots: true,
                checkpoint_every: 8,
                ..ServerConfig::default()
            },
        }
    }
}

/// A running router + K workers, all in-process.
pub struct Fleet {
    router: Option<RouterHandle>,
    workers: Vec<Option<ServerHandle>>,
    /// Worker line-protocol addresses, indexed like the router registry.
    pub worker_addrs: Vec<String>,
    /// Chaos hooks shared with the router.
    pub chaos: Arc<ChaosHooks>,
}

impl Fleet {
    /// Spawn the workers and the router, and wait until the router's
    /// first heartbeat has marked every worker alive.
    pub fn spawn(cfg: FleetConfig) -> Fleet {
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut worker_addrs = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            let scfg = ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                publish_snapshots: true,
                ..cfg.server.clone()
            };
            let h = Server::bind(scfg)
                .expect("fleet: worker bind")
                .spawn()
                .expect("fleet: worker spawn");
            worker_addrs.push(h.addr.to_string());
            workers.push(Some(h));
        }
        let chaos = ChaosHooks::new();
        let rcfg = RouterConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: worker_addrs.clone(),
            placement: cfg.placement.clone(),
            heartbeat_ms: cfg.heartbeat_ms,
            heartbeat_timeout_ms: cfg.heartbeat_timeout_ms,
            ..RouterConfig::default()
        };
        let router = Router::bind_with_chaos(rcfg, Arc::clone(&chaos))
            .expect("fleet: router bind")
            .spawn();
        let fleet = Fleet {
            router: Some(router),
            workers,
            worker_addrs,
            chaos,
        };
        fleet.wait_alive(Duration::from_secs(10));
        fleet
    }

    /// The router's client-facing address.
    pub fn router_addr(&self) -> String {
        self.router
            .as_ref()
            .expect("fleet: router already shut down")
            .addr()
            .to_string()
    }

    /// A fresh client connected to the router.
    pub fn client(&self) -> Client {
        Client::connect(&self.router_addr()).expect("fleet: client connect")
    }

    /// A fresh client connected directly to worker `i`.
    pub fn worker_client(&self, i: usize) -> Client {
        Client::connect(&self.worker_addrs[i]).expect("fleet: worker client connect")
    }

    /// Router `stats` verb as JSON.
    pub fn router_stats(&self) -> Value {
        self.client().stats().expect("fleet: router stats")
    }

    /// Worker `i`'s cumulative solver-step count, `None` if unreachable
    /// (e.g. killed).
    pub fn worker_steps(&self, i: usize) -> Option<u64> {
        let mut c = Client::connect(&self.worker_addrs[i]).ok()?;
        let v = c.stats().ok()?;
        v.get("steps").and_then(Value::as_f64).map(|f| f as u64)
    }

    /// Sum of solver steps across all reachable workers.
    pub fn fleet_steps(&self) -> u64 {
        (0..self.worker_addrs.len())
            .filter_map(|i| self.worker_steps(i))
            .sum()
    }

    /// Crash worker `i` without draining (idempotent).
    pub fn kill_worker(&mut self, i: usize) {
        if let Some(h) = self.workers[i].take() {
            h.kill();
        }
    }

    /// Gracefully stop worker `i` (idempotent).
    pub fn shutdown_worker(&mut self, i: usize) {
        if let Some(h) = self.workers[i].take() {
            h.shutdown();
        }
    }

    /// Ask the router to migrate one in-flight group off the hottest
    /// worker; returns the rebalance reply.
    pub fn rebalance(&self) -> Value {
        let line = to_string(&Value::obj(vec![(
            "cmd",
            Value::Str("rebalance".to_string()),
        )]));
        let mut c = self.client();
        let reply = c.round_trip(&line).expect("fleet: rebalance round trip");
        parse(reply.trim()).expect("fleet: rebalance reply parse")
    }

    /// Block until the router reports every spawned-and-not-killed
    /// worker alive; panics on timeout.
    pub fn wait_alive(&self, timeout: Duration) {
        let t0 = Instant::now();
        loop {
            let stats = self.router_stats();
            let all_alive = match stats.get("workers") {
                Some(Value::Array(ws)) => {
                    ws.len() == self.worker_addrs.len()
                        && ws
                            .iter()
                            .enumerate()
                            .all(|(i, w)| {
                                self.workers[i].is_none() || w.opt_bool("alive", false)
                            })
                }
                _ => false,
            };
            if all_alive {
                return;
            }
            assert!(
                t0.elapsed() < timeout,
                "fleet: workers not alive after {timeout:?}: {}",
                to_string(&stats)
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Block until the router has cached at least `min_groups` group
    /// checkpoints for worker `i`; panics on timeout.
    pub fn wait_cached_groups(&self, i: usize, min_groups: usize, timeout: Duration) {
        let t0 = Instant::now();
        loop {
            let stats = self.router_stats();
            let cached = stats
                .get("workers")
                .and_then(|ws| match ws {
                    Value::Array(items) => items.get(i),
                    _ => None,
                })
                .map(|w| w.opt_usize("cached_groups", 0))
                .unwrap_or(0);
            if cached >= min_groups {
                return;
            }
            assert!(
                t0.elapsed() < timeout,
                "fleet: worker {i} never cached {min_groups} group(s): {}",
                to_string(&stats)
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Block until the fleet-wide step counter reaches `target`. Returns
    /// `true` if reached, `false` if `timeout` passed first (callers
    /// fire their fault anyway — the trigger is best-effort by design,
    /// determinism comes from the plan, not the wall clock).
    pub fn wait_fleet_steps(&self, target: u64, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if self.fleet_steps() >= target {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        false
    }

    /// Fire every event in `plan`, each once its step trigger is
    /// reached (bounded wait per event, then fire regardless so a plan
    /// can never hang a test).
    pub fn run_plan(&mut self, plan: &FaultPlan) {
        for ev in &plan.events {
            self.wait_fleet_steps(ev.at_step(), Duration::from_secs(5));
            match ev {
                FaultEvent::KillWorker { worker, .. } => self.kill_worker(*worker),
                FaultEvent::DropHeartbeats { worker, for_ms, .. } => {
                    self.chaos.drop_heartbeats(*worker, true);
                    std::thread::sleep(Duration::from_millis(*for_ms));
                    self.chaos.drop_heartbeats(*worker, false);
                }
                FaultEvent::DelayHeartbeats { ms, .. } => self.chaos.delay_heartbeats(*ms),
                FaultEvent::SeverMigration { .. } => self.chaos.sever_next_migration(),
            }
        }
    }

    /// Stop the router first (so it stops forwarding), then the workers.
    pub fn shutdown(&mut self) {
        if let Some(mut r) = self.router.take() {
            r.shutdown();
        }
        for w in &mut self.workers {
            if let Some(h) = w.take() {
                h.shutdown();
            }
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_is_deterministic_per_seed() {
        let a = FaultPlan::generate(42, 3, 100);
        let b = FaultPlan::generate(42, 3, 100);
        assert_eq!(a, b, "same seed must give the same plan");
        assert!(!a.events.is_empty() && a.events.len() <= 3);
        for ev in &a.events {
            assert!(ev.at_step() < 100);
        }
        let distinct: std::collections::HashSet<String> =
            (0..32).map(|s| format!("{:?}", FaultPlan::generate(s, 3, 100).events)).collect();
        assert!(distinct.len() > 1, "seeds should produce distinct plans");
        assert!(a.describe().contains("seed=42"));
    }

    #[test]
    fn fault_plan_events_are_sorted_by_trigger() {
        for seed in 0..32u64 {
            let p = FaultPlan::generate(seed, 4, 1000);
            for w in p.events.windows(2) {
                assert!(w[0].at_step() <= w[1].at_step(), "{}", p.describe());
            }
        }
    }
}
