//! A miniature property-testing harness (proptest is not in the offline
//! vendor set): seeded generators over a fixed number of cases with
//! first-failure reporting. Deterministic per seed so failures reproduce.

use crate::rng::Xoshiro256pp;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xA11CE }
    }
}

/// Generator context handed to each case.
pub struct Gen {
    pub rng: Xoshiro256pp,
    pub case: usize,
}

impl Gen {
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Strictly increasing vector of `len` values in (lo, hi).
    pub fn increasing(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..len).map(|_| self.f64_in(lo, hi)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Nudge duplicates apart.
        for i in 1..v.len() {
            if v[i] <= v[i - 1] {
                v[i] = v[i - 1] + 1e-9 * (1.0 + v[i - 1].abs());
            }
        }
        v
    }
}

/// Run `prop` for `cfg.cases` cases; panic with the failing case index and
/// seed on the first failure (the message is enough to reproduce).
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(cfg: PropConfig, mut prop: F) {
    for case in 0..cfg.cases {
        let mut g = Gen { rng: Xoshiro256pp::new(cfg.seed.wrapping_add(case as u64)), case };
        if let Err(msg) = prop(&mut g) {
            panic!("property failed at case {case} (seed {}): {msg}", cfg.seed);
        }
    }
}

/// Helper for building failure messages in properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial() {
        check(PropConfig { cases: 16, seed: 1 }, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failure() {
        check(PropConfig { cases: 8, seed: 2 }, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!(x < 0.5, "x={x} >= 0.5");
            Ok(())
        });
    }

    #[test]
    fn increasing_is_increasing() {
        check(PropConfig::default(), |g| {
            let v = g.increasing(10, -5.0, 5.0);
            for w in v.windows(2) {
                prop_assert!(w[1] > w[0], "not increasing: {v:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut first = Vec::new();
        check(PropConfig { cases: 4, seed: 9 }, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second = Vec::new();
        check(PropConfig { cases: 4, seed: 9 }, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
