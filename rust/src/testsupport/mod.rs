//! A miniature property-testing harness (proptest is not in the offline
//! vendor set): seeded generators over a fixed number of cases with
//! first-failure reporting. Deterministic per seed so failures reproduce.
//! Also home to the counting global allocator ([`alloc`]) behind the
//! allocation-budget assertions.

pub mod alloc;
pub mod fleet;

use crate::config::{SamplerConfig, SolverKind};
use crate::rng::Xoshiro256pp;
use crate::schedule::StepSelector;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xA11CE }
    }
}

/// Generator context handed to each case.
pub struct Gen {
    pub rng: Xoshiro256pp,
    pub case: usize,
}

impl Gen {
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Strictly increasing vector of `len` values in (lo, hi).
    pub fn increasing(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..len).map(|_| self.f64_in(lo, hi)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Nudge duplicates apart.
        for i in 1..v.len() {
            if v[i] <= v[i - 1] {
                v[i] = v[i - 1] + 1e-9 * (1.0 + v[i - 1].abs());
            }
        }
        v
    }
}

/// Run `prop` for `cfg.cases` cases; panic with the failing case index and
/// seed on the first failure (the message is enough to reproduce).
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(cfg: PropConfig, mut prop: F) {
    for case in 0..cfg.cases {
        let mut g = Gen { rng: Xoshiro256pp::new(cfg.seed.wrapping_add(case as u64)), case };
        if let Err(msg) = prop(&mut g) {
            panic!("property failed at case {case} (seed {}): {msg}", cfg.seed);
        }
    }
}

/// Like [`check`], but every case (and, on failure, the shrunk repro line)
/// is appended to a seed-log file so CI can upload the trail as an artifact
/// when the property fails. The failing `Gen` seed in the log/panic is the
/// full repro: rerun with `PropConfig { cases: case + 1, seed }` and only
/// the last case matters.
pub fn check_logged<F: FnMut(&mut Gen) -> Result<(), String>>(
    cfg: PropConfig,
    log_path: &str,
    mut prop: F,
) {
    truncate_log(log_path);
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Xoshiro256pp::new(case_seed), case };
        if let Err(msg) = prop(&mut g) {
            let line = format!(
                "FAIL case {case}: run seed {} (case seed {case_seed}): {msg}",
                cfg.seed
            );
            append_log(log_path, &line);
            panic!("property failed at case {case} (seed {}): {msg}", cfg.seed);
        }
        append_log(log_path, &format!("ok case {case}: case seed {case_seed}"));
    }
}

fn truncate_log(path: &str) {
    if let Some(dir) = std::path::Path::new(path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let _ = std::fs::write(path, "");
}

fn append_log(path: &str, line: &str) {
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "{line}");
    }
}

/// One sampled configuration of the snapshot/restore property sweep: a
/// random point in (solver, grid kind, NFE, co-batch layout, snapshot
/// boundary, executor widths on both sides of the restore).
#[derive(Debug, Clone)]
pub struct SnapshotCase {
    pub solver: SolverKind,
    pub selector: StepSelector,
    pub nfe: usize,
    /// Lane count per co-batched request (1..=3 requests).
    pub lane_counts: Vec<usize>,
    /// Per-request noise seeds.
    pub seeds: Vec<u64>,
    /// Where to snapshot, as a fraction of the grid (0 = right after the
    /// warm-up `init`, 1 = the final boundary, after the last step).
    pub boundary_frac: f64,
    /// Executor width driving the run up to the snapshot.
    pub threads_before: usize,
    /// Executor width after the restore (the migrated process).
    pub threads_after: usize,
}

impl SnapshotCase {
    pub fn sample(g: &mut Gen) -> SnapshotCase {
        let solver = *g.choice(SolverKind::all());
        let selector = *g.choice(StepSelector::all());
        let nfe = g.usize_in(1, 20);
        let n_requests = g.usize_in(1, 3);
        let lane_counts: Vec<usize> = (0..n_requests).map(|_| g.usize_in(1, 5)).collect();
        let seeds: Vec<u64> =
            (0..n_requests).map(|_| g.usize_in(0, 1_000_000) as u64).collect();
        SnapshotCase {
            solver,
            selector,
            nfe,
            lane_counts,
            seeds,
            boundary_frac: g.f64_in(0.0, 1.0),
            threads_before: *g.choice(&[1usize, 2, 4]),
            threads_after: *g.choice(&[1usize, 4]),
        }
    }

    /// The sampled solver config (selector + NFE applied to the solver's
    /// family defaults).
    pub fn config(&self) -> SamplerConfig {
        let mut cfg = SamplerConfig::for_solver(self.solver);
        cfg.nfe = self.nfe;
        cfg.selector = self.selector;
        cfg
    }

    /// The snapshot boundary as a step index in `0..=m`.
    pub fn boundary(&self, m: usize) -> usize {
        ((self.boundary_frac * m as f64).round() as usize).min(m)
    }

    /// One-line description for the seed log / failure message.
    pub fn describe(&self) -> String {
        format!(
            "solver={} selector={} nfe={} lanes={:?} seeds={:?} frac={:.3} threads {}→{}",
            self.solver.name(),
            self.selector.name(),
            self.nfe,
            self.lane_counts,
            self.seeds,
            self.boundary_frac,
            self.threads_before,
            self.threads_after
        )
    }
}

/// Helper for building failure messages in properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial() {
        check(PropConfig { cases: 16, seed: 1 }, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failure() {
        check(PropConfig { cases: 8, seed: 2 }, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!(x < 0.5, "x={x} >= 0.5");
            Ok(())
        });
    }

    #[test]
    fn increasing_is_increasing() {
        check(PropConfig::default(), |g| {
            let v = g.increasing(10, -5.0, 5.0);
            for w in v.windows(2) {
                prop_assert!(w[1] > w[0], "not increasing: {v:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn snapshot_case_sampling_is_deterministic_and_in_range() {
        let mut first: Vec<String> = Vec::new();
        check(PropConfig { cases: 12, seed: 5 }, |g| {
            let c = SnapshotCase::sample(g);
            prop_assert!((1..=20).contains(&c.nfe), "nfe={}", c.nfe);
            prop_assert!(!c.lane_counts.is_empty(), "no requests");
            prop_assert!(c.lane_counts.iter().all(|n| (1..=5).contains(n)), "{:?}", c.lane_counts);
            prop_assert!((0.0..=1.0).contains(&c.boundary_frac), "{}", c.boundary_frac);
            let m = c.config().steps_for_nfe();
            prop_assert!(c.boundary(m) <= m, "boundary past the grid");
            first.push(c.describe());
            Ok(())
        });
        let mut second: Vec<String> = Vec::new();
        check(PropConfig { cases: 12, seed: 5 }, |g| {
            second.push(SnapshotCase::sample(g).describe());
            Ok(())
        });
        assert_eq!(first, second, "sampling must be deterministic per seed");
    }

    #[test]
    fn check_logged_writes_the_trail() {
        let path = std::env::temp_dir()
            .join(format!("sadiff_seedlog_{}.log", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        check_logged(PropConfig { cases: 3, seed: 8 }, &path, |_| Ok(()));
        let log = std::fs::read_to_string(&path).unwrap();
        assert_eq!(log.lines().count(), 3, "{log}");
        assert!(log.contains("ok case 2"));
        let failed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check_logged(PropConfig { cases: 2, seed: 8 }, &path, |g| {
                if g.case == 1 {
                    Err("boom".into())
                } else {
                    Ok(())
                }
            });
        }));
        assert!(failed.is_err());
        let log = std::fs::read_to_string(&path).unwrap();
        assert!(log.contains("FAIL case 1"), "{log}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_per_seed() {
        let mut first = Vec::new();
        check(PropConfig { cases: 4, seed: 9 }, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second = Vec::new();
        check(PropConfig { cases: 4, seed: 9 }, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
