//! A counting global allocator for allocation-budget assertions: tests and
//! benches install [`CountingAlloc`] with `#[global_allocator]` and read
//! the process-wide allocation counter around a region of interest.
//!
//! This is what enforces the stepper hot-path contract — **zero heap
//! allocations per `Stepper::step` call after `init`** — and what
//! `bench_perf` uses to report allocations-per-step for the monolithic
//! reference loop vs the stepper driver in `BENCH_perf.json`.
//!
//! The counter is a single relaxed atomic incremented on `alloc`,
//! `alloc_zeroed` and `realloc` (deallocations are free and not counted),
//! so readings taken while *other* threads allocate include their traffic:
//! keep measured regions single-threaded (the allocation-budget test runs
//! as the only test in its binary).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Number of heap allocations (alloc / alloc_zeroed / realloc calls) made
/// process-wide since startup, when [`CountingAlloc`] is installed as the
/// global allocator. Always 0 otherwise.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// A [`System`]-backed allocator that counts allocation calls. Install in
/// a test or bench binary with:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: sadiff::testsupport::alloc::CountingAlloc =
///     sadiff::testsupport::alloc::CountingAlloc;
/// ```
pub struct CountingAlloc;

// SAFETY: defers every operation to `System`, which upholds the
// `GlobalAlloc` contract; the counter increment has no effect on layout or
// pointer validity.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}
