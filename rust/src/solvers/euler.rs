//! Euler–Maruyama discretization of the variance-controlled reverse SDE
//! (Eq. (7)) in t-space — the classical first-order stochastic baseline
//! the paper contrasts with (its §5 motivates SA-Solver by the inadequacy
//! of such one-step schemes).
//!
//!   x ← x + [f(t) x − ((1+τ²)/2) g²(t) ŝ(x,t)] Δt + τ √(g²(t)) √(−Δt) ξ
//!
//! with ŝ(x, t) = −(x − α x₀̂)/σ² the model-induced score and Δt < 0.

use crate::linalg::Scratch;
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::schedule::NoiseSchedule;
use crate::solvers::stepper::Stepper;
use crate::solvers::{step_noise, Grid};

/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`EulerStepper`]).
pub fn solve(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    grid: &Grid,
    tau: f64,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0 = vec![0.0; n * dim];
    let mut xi = vec![0.0; n * dim];
    for i in 0..m {
        let t = grid.ts[i];
        model.eval_batch(x, &grid.ctx(i), &mut x0);
        step_noise(noise, i, dim, n, &mut xi);
        let dt = grid.ts[i + 1] - t; // negative
        let f = sch.dlog_alpha_dt(t);
        let g2 = sch.g2(t);
        let alpha = grid.alphas[i];
        let sigma2 = grid.sigmas[i] * grid.sigmas[i];
        let noise_scale = tau * g2.sqrt() * (-dt).max(0.0).sqrt();
        let half = 0.5 * (1.0 + tau * tau) * g2;
        for k in 0..n * dim {
            let score = (alpha * x0[k] - x[k]) / sigma2;
            x[k] += (f * x[k] - half * score) * dt + noise_scale * xi[k];
        }
    }
}

/// Euler–Maruyama as an incremental [`Stepper`]; holds the schedule by
/// value (`NoiseSchedule` is `Copy`) because the drift terms f(t), g²(t)
/// are evaluated off-grid. Memoryless: the only state is a two-slot
/// [`Scratch`] arena, sized at `init` so the step path never allocates.
pub struct EulerStepper {
    sch: NoiseSchedule,
    tau: f64,
    scr: Scratch,
}

impl EulerStepper {
    /// A stepper over `sch` with stochasticity `tau`.
    pub fn new(sch: NoiseSchedule, tau: f64) -> Self {
        EulerStepper { sch, tau, scr: Scratch::default() }
    }
}

impl Stepper for EulerStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        self.scr = Scratch::new(2, n * model.dim());
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let [x0, xi] = self.scr.split(n * dim);
        let t = grid.ts[i];
        model.eval_batch(x, &grid.ctx(i), x0);
        step_noise(noise, i, dim, n, xi);
        let dt = grid.ts[i + 1] - t; // negative
        let f = self.sch.dlog_alpha_dt(t);
        let g2 = self.sch.g2(t);
        let alpha = grid.alphas[i];
        let sigma2 = grid.sigmas[i] * grid.sigmas[i];
        let noise_scale = self.tau * g2.sqrt() * (-dt).max(0.0).sqrt();
        let half = 0.5 * (1.0 + self.tau * self.tau) * g2;
        for k in 0..n * dim {
            let score = (alpha * x0[k] - x[k]) / sigma2;
            x[k] += (f * x[k] - half * score) * dt + noise_scale * xi[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::GmmAnalytic;
    use crate::rng::normal::{PhiloxNormal, ZeroNormal};
    use crate::schedule::{timesteps, StepSelector};
    use crate::util::close;

    #[test]
    fn tau_zero_is_deterministic() {
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformT, 20));
        let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.0, 9));
        let mut a = vec![0.5, -0.5];
        let mut b = a.clone();
        solve(&model, &sch, &grid, 0.0, &mut a, 1, &mut PhiloxNormal::new(1));
        solve(&model, &sch, &grid, 0.0, &mut b, 1, &mut PhiloxNormal::new(2));
        assert_eq!(a, b);
    }

    #[test]
    fn fine_steps_recover_moments() {
        // EM with many steps on τ=1 approximately samples the target.
        let gmm = Gmm::new(vec![1.0], vec![vec![0.0]], vec![vec![1.0]]);
        let model = GmmAnalytic::new(gmm);
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformT, 400));
        let n = 1500;
        let mut noise = PhiloxNormal::new(21);
        let mut x = crate::solvers::prior_sample(&grid, 1, n, &mut noise);
        solve(&model, &sch, &grid, 1.0, &mut x, n, &mut noise);
        let var = x.iter().map(|v| v * v).sum::<f64>() / n as f64;
        assert!(close(var, 1.0, 0.15, 0.0), "var={var}");
    }

    #[test]
    fn matches_ode_limit_with_zero_noise_source() {
        // τ=1 but a ZeroNormal source: EM then integrates the *SDE drift*,
        // which differs from the PF-ODE — just assert finiteness and that
        // it differs from τ=0 drift.
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformT, 50));
        let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.0, 9));
        let mut a = vec![0.5, -0.5];
        let mut b = a.clone();
        solve(&model, &sch, &grid, 0.0, &mut a, 1, &mut ZeroNormal);
        solve(&model, &sch, &grid, 1.0, &mut b, 1, &mut ZeroNormal);
        assert!(a.iter().chain(&b).all(|v| v.is_finite()));
        assert_ne!(a, b);
    }
}
