//! Sampling algorithms. `run` is the single entry point used by the
//! coordinator: it builds the timestep grid, draws the prior state, and
//! dispatches to the configured solver.
//!
//! All solvers share the same conventions:
//! * state is a row-major `n × dim` batch evolved in place;
//! * the model is a *data-prediction* oracle (`ModelEval`); noise-prediction
//!   solvers derive ε̂ = (x − α x₀̂)/σ internally, which reproduces the
//!   paper's parameterization comparison because the *interpolation space*
//!   is what differs (Remark 1);
//! * per-sample noise comes from a counter RNG keyed by (stream = sample
//!   lane, step), so results are independent of batch composition.

#[allow(missing_docs)]
pub mod adaptive;
#[allow(missing_docs)]
pub mod coeffs;
#[allow(missing_docs)]
pub mod ddim;
#[allow(missing_docs)]
pub mod ddpm;
#[allow(missing_docs)]
pub mod dpm;
#[allow(missing_docs)]
pub mod edm;
#[allow(missing_docs)]
pub mod euler;
#[allow(missing_docs)]
pub mod sa;
pub mod snapshot;
pub mod stepper;
#[allow(missing_docs)]
pub mod unipc;

use crate::config::{SamplerConfig, SolverKind};
use crate::exec::{chunks, Executor};
use crate::models::{CountingModel, EvalCtx, ModelEval};
use crate::rng::normal::{NormalSource, PhiloxNormal, SplitNoise};
use crate::schedule::{timesteps, NoiseSchedule};

/// Result of one solve.
#[derive(Debug, Clone)]
pub struct SolveOutput {
    /// Row-major `n × dim` samples at t_min.
    pub samples: Vec<f64>,
    /// Number of sample lanes.
    pub n: usize,
    /// Data dimension per lane.
    pub dim: usize,
    /// Model evaluations actually performed (batched calls).
    pub nfe: usize,
}

/// Precomputed per-grid-point schedule quantities.
#[derive(Debug, Clone)]
pub struct Grid {
    /// Timestep per grid point, decreasing along the reverse-time grid.
    pub ts: Vec<f64>,
    /// α(t) per grid point.
    pub alphas: Vec<f64>,
    /// σ(t) per grid point.
    pub sigmas: Vec<f64>,
    /// λ(t) = log(α/σ) per grid point, increasing along the grid.
    pub lams: Vec<f64>,
}

impl Grid {
    /// Evaluate the schedule at every timestep of `ts`.
    pub fn new(sch: &NoiseSchedule, ts: Vec<f64>) -> Self {
        let alphas = ts.iter().map(|t| sch.alpha(*t)).collect();
        let sigmas = ts.iter().map(|t| sch.sigma(*t)).collect();
        let lams = ts.iter().map(|t| sch.lambda(*t)).collect();
        Grid { ts, alphas, sigmas, lams }
    }

    /// Number of solver steps (grid points minus one).
    pub fn m(&self) -> usize {
        self.ts.len() - 1
    }

    /// Model-evaluation context at grid point `i`.
    pub fn ctx(&self, i: usize) -> EvalCtx {
        EvalCtx { t: self.ts[i], alpha: self.alphas[i], sigma: self.sigmas[i] }
    }
}

/// Noise stream id used for the prior draw (distinct from any step index).
pub const PRIOR_STEP: u64 = u64::MAX;

/// Draw the prior state x_T ~ N(0, σ_T² I) into a caller-provided
/// `n × dim` buffer, one Philox stream per lane.
pub fn prior_sample_into(
    grid: &Grid,
    dim: usize,
    n: usize,
    noise: &mut dyn NormalSource,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), n * dim);
    let sigma_t = grid.sigmas[0];
    for lane in 0..n {
        noise.fill(lane as u64, PRIOR_STEP, &mut out[lane * dim..(lane + 1) * dim]);
    }
    for v in out.iter_mut() {
        *v *= sigma_t;
    }
}

/// Draw the prior state x_T ~ N(0, σ_T² I), one Philox stream per lane.
pub fn prior_sample(grid: &Grid, dim: usize, n: usize, noise: &mut dyn NormalSource) -> Vec<f64> {
    let mut x = vec![0.0; n * dim];
    prior_sample_into(grid, dim, n, noise, &mut x);
    x
}

/// Fill per-lane step noise (keeps samples independent of batching).
pub fn step_noise(
    noise: &mut dyn NormalSource,
    step: usize,
    dim: usize,
    n: usize,
    out: &mut [f64],
) {
    for lane in 0..n {
        noise.fill(lane as u64, step as u64, &mut out[lane * dim..(lane + 1) * dim]);
    }
}

/// Run the configured solver for `n` samples with the given seed.
pub fn run(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> SolveOutput {
    let mut noise = PhiloxNormal::new(seed);
    run_with_noise(model, sch, cfg, n, &mut noise)
}

/// Like [`run`], but lane-chunked across `exec`'s worker pool. Bit-identical
/// to [`run`] for every solver (per-lane Philox streams + row-wise models).
pub fn run_parallel(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
    exec: &Executor,
) -> SolveOutput {
    run_chunked(model, sch, cfg, n, &PhiloxNormal::new(seed), exec)
}

/// Lane-chunked execution path shared by the whole solver zoo: split the
/// `n` lanes into contiguous chunks and run [`run_with_noise_into`] per
/// chunk with a lane-offset slice of `noise`'s Philox streams, each chunk
/// writing its slice of one shared output buffer. The per-lane stream
/// keying makes the result bit-identical to the sequential run regardless
/// of thread count (asserted in tests for every [`SolverKind`]). The
/// chunk dispatch reuses `exec`'s persistent parked pool — repeated
/// `run_chunked` calls on one executor pay a condvar round-trip each, not
/// a thread spawn/join cycle per chunk.
pub fn run_chunked(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    noise: &dyn SplitNoise,
    exec: &Executor,
) -> SolveOutput {
    if exec.threads() <= 1 || n <= 1 {
        let mut local = noise.split_lanes(0);
        return run_with_noise(model, sch, cfg, n, &mut *local);
    }
    let dim = model.dim();
    // One output buffer for the whole batch, split into disjoint per-chunk
    // slices the workers write straight into — no per-chunk result vectors
    // and no concatenation copy on the join side.
    let mut samples = vec![0.0; n * dim];
    let mut parts: Vec<(std::ops::Range<usize>, &mut [f64], usize)> = Vec::new();
    {
        let mut rest: &mut [f64] = &mut samples;
        for range in chunks(n, exec.threads()) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(range.len() * dim);
            parts.push((range, head, 0));
            rest = tail;
        }
    }
    exec.for_each_mut(&mut parts, |_, (range, out, nfe)| {
        let mut local = noise.split_lanes(range.start);
        *nfe = run_with_noise_into(model, sch, cfg, range.len(), &mut *local, out);
    });
    // NFE accounting invariant: model calls are per *step*, not per lane,
    // and every chunk walks the same grid, so all chunks must report the
    // same count; one chunk's count is the whole batch's NFE (this is what
    // keeps batched-vs-parallel accounting equal to sequential). A chunk
    // disagreeing means a solver made its call pattern depend on lane
    // count — a bug worth failing loudly on in debug builds.
    let nfe = parts.first().map_or(0, |p| p.2);
    debug_assert!(
        parts.iter().all(|p| p.2 == nfe),
        "chunks disagree on NFE: {:?} (solver call pattern depends on lane count)",
        parts.iter().map(|p| p.2).collect::<Vec<_>>()
    );
    drop(parts);
    SolveOutput { samples, n, dim, nfe }
}

/// Same as [`run`] but with a caller-supplied noise source (tests use this
/// to couple Brownian paths across solvers).
///
/// This is a thin generic driver over the [`stepper::Stepper`] trait:
/// build the grid, draw the prior, then `init` + `step` × M + `finish`.
/// Bit-identical to the monolithic per-solver loops ([`run_reference`])
/// for every [`SolverKind`] — asserted per-step in the equivalence suite.
pub fn run_with_noise(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    noise: &mut dyn NormalSource,
) -> SolveOutput {
    let dim = model.dim();
    let mut samples = vec![0.0; n * dim];
    let nfe = run_with_noise_into(model, sch, cfg, n, noise, &mut samples);
    SolveOutput { samples, n, dim, nfe }
}

/// [`run_with_noise`] writing into a caller-provided `n × dim` buffer
/// (the prior draw and every step happen in place); returns the NFE.
/// This is what lets [`run_chunked`] hand workers disjoint slices of one
/// batch-wide output buffer instead of allocating per chunk.
pub fn run_with_noise_into(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    noise: &mut dyn NormalSource,
    out: &mut [f64],
) -> usize {
    let dim = model.dim();
    debug_assert_eq!(out.len(), n * dim);
    let m = cfg.steps_for_nfe();
    let grid = Grid::new(sch, timesteps(sch, cfg.selector, m));
    let counting = CountingModel::new(model);
    prior_sample_into(&grid, dim, n, noise, out);
    let mut st = stepper::make_stepper(cfg, sch);
    stepper::drive(&mut *st, &counting, &grid, out, n, noise);
    counting.count()
}

/// The seed-era monolithic dispatch: every solver runs its own whole-grid
/// `solve()` loop. Retained verbatim as the *reference implementation* for
/// the stepper equivalence contract — tests assert [`run_with_noise`]
/// (the incremental driver) reproduces this path bitwise for every
/// [`SolverKind`]. Not used on any production path.
pub fn run_reference(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    seed: u64,
) -> SolveOutput {
    let mut noise = PhiloxNormal::new(seed);
    run_reference_with_noise(model, sch, cfg, n, &mut noise)
}

/// [`run_reference`] with a caller-supplied noise source.
pub fn run_reference_with_noise(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    cfg: &SamplerConfig,
    n: usize,
    noise: &mut dyn NormalSource,
) -> SolveOutput {
    let dim = model.dim();
    let m = cfg.steps_for_nfe();
    let grid = Grid::new(sch, timesteps(sch, cfg.selector, m));
    let counting = CountingModel::new(model);
    let mut x = prior_sample(&grid, dim, n, noise);
    match cfg.solver {
        SolverKind::Sa => {
            let opts = sa::SaSolverOpts::from_config(cfg);
            sa::SaSolver::new(opts).solve(&counting, &grid, &mut x, n, noise);
        }
        SolverKind::Ddim => ddim::solve(&counting, &grid, cfg.eta, &mut x, n, noise),
        SolverKind::Ddpm => ddpm::solve(&counting, &grid, &mut x, n, noise),
        SolverKind::EulerMaruyama => {
            euler::solve(&counting, sch, &grid, cfg.tau, &mut x, n, noise)
        }
        SolverKind::DpmSolver2 => dpm::solve_dpm2(&counting, sch, &grid, &mut x, n),
        SolverKind::DpmSolverPp2m => dpm::solve_pp2m(&counting, &grid, &mut x, n),
        SolverKind::UniPc => {
            unipc::solve(&counting, &grid, cfg.predictor_steps, cfg.corrector_steps, &mut x, n)
        }
        SolverKind::Heun => edm::solve_heun(&counting, &grid, &mut x, n),
        SolverKind::EdmSde => edm::solve_sde(
            &counting,
            &grid,
            edm::ChurnParams {
                churn: cfg.churn,
                s_noise: cfg.s_noise,
                s_tmin: cfg.s_tmin,
                s_tmax: cfg.s_tmax,
            },
            &mut x,
            n,
            noise,
        ),
    }
    SolveOutput { samples: x, n, dim, nfe: counting.count() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig;
    use crate::gmm::Gmm;
    use crate::models::GmmAnalytic;

    fn tiny_model() -> GmmAnalytic {
        GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 3))
    }

    #[test]
    fn grid_shapes() {
        let sch = NoiseSchedule::vp_linear();
        let ts = timesteps(&sch, crate::schedule::StepSelector::UniformLambda, 5);
        let g = Grid::new(&sch, ts);
        assert_eq!(g.m(), 5);
        assert_eq!(g.alphas.len(), 6);
        // λ increasing along the reverse-time grid.
        for w in g.lams.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn all_solvers_produce_finite_samples() {
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        for kind in SolverKind::all() {
            let mut cfg = SamplerConfig::for_solver(*kind);
            cfg.nfe = 12;
            let out = run(&model, &sch, &cfg, 8, 42);
            assert_eq!(out.samples.len(), 16);
            assert!(
                out.samples.iter().all(|v| v.is_finite()),
                "{kind:?} produced non-finite samples"
            );
            assert!(out.nfe > 0, "{kind:?} reported zero NFE");
        }
    }

    #[test]
    fn nfe_matches_budget() {
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        for kind in [SolverKind::Sa, SolverKind::Ddim, SolverKind::UniPc, SolverKind::Heun] {
            let mut cfg = SamplerConfig::for_solver(kind);
            cfg.nfe = 16;
            let out = run(&model, &sch, &cfg, 4, 1);
            // Within one eval of the requested budget (Heun's trailing
            // Euler step saves one).
            assert!(
                out.nfe <= 16 && out.nfe >= 14,
                "{kind:?}: nfe={} for budget 16",
                out.nfe
            );
        }
    }

    #[test]
    fn determinism_per_seed() {
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        let cfg = SamplerConfig { nfe: 10, ..SamplerConfig::sa_default() };
        let a = run(&model, &sch, &cfg, 4, 7);
        let b = run(&model, &sch, &cfg, 4, 7);
        let c = run(&model, &sch, &cfg, 4, 8);
        assert_eq!(a.samples, b.samples);
        assert_ne!(a.samples, c.samples);
    }

    #[test]
    fn parallel_executor_bit_identical_for_every_solver() {
        // The executor determinism contract: for every solver in the zoo,
        // a lane-chunked parallel run equals the sequential run bitwise,
        // across chunk-boundary shapes (n % threads != 0, n < threads).
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        for kind in SolverKind::all() {
            let mut cfg = SamplerConfig::for_solver(*kind);
            cfg.nfe = 10;
            for (n, threads) in [(13usize, 4usize), (3, 8), (8, 2), (5, 1)] {
                let seq = run(&model, &sch, &cfg, n, 77);
                let par = run_parallel(&model, &sch, &cfg, n, 77, &Executor::new(threads));
                assert_eq!(
                    seq.samples, par.samples,
                    "{kind:?}: parallel (n={n}, threads={threads}) diverged from sequential"
                );
                assert_eq!(seq.nfe, par.nfe, "{kind:?}: NFE accounting diverged");
                assert_eq!((par.n, par.dim), (seq.n, seq.dim));
            }
        }
    }

    #[test]
    fn stepper_driver_matches_monolithic_reference() {
        // run() now goes through the incremental stepper driver; it must
        // reproduce the seed-era monolithic dispatch bitwise (NFE included)
        // for every solver in the zoo.
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        for kind in SolverKind::all() {
            let mut cfg = SamplerConfig::for_solver(*kind);
            cfg.nfe = 11;
            let new = run(&model, &sch, &cfg, 7, 123);
            let old = run_reference(&model, &sch, &cfg, 7, 123);
            assert_eq!(new.samples, old.samples, "{kind:?}: driver diverged from reference");
            assert_eq!(new.nfe, old.nfe, "{kind:?}: NFE accounting diverged");
        }
    }

    #[test]
    fn run_chunked_single_thread_is_sequential() {
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let seq = run(&model, &sch, &cfg, 6, 3);
        let one = run_parallel(&model, &sch, &cfg, 6, 3, &Executor::sequential());
        assert_eq!(seq.samples, one.samples);
    }

    #[test]
    fn batch_composition_invariance() {
        // Lane k of a batch of 8 equals lane k of a batch of 3 — the
        // serving reproducibility invariant (per-lane Philox streams).
        let model = tiny_model();
        let sch = NoiseSchedule::vp_linear();
        let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
        let big = run(&model, &sch, &cfg, 8, 9);
        let small = run(&model, &sch, &cfg, 3, 9);
        assert_eq!(&big.samples[..3 * 2], &small.samples[..]);
    }
}
