//! Ancestral DDPM sampling, schedule-general form: each step samples the
//! exact forward posterior q(x_{t_{i+1}} | x_{t_i}, x₀̂).
//!
//! With s = t_{i+1} (less noisy), t = t_i and the conditional forward
//! kernel x_t | x_s ~ N((α_t/α_s) x_s, σ_{t|s}²), σ_{t|s}² = σ_t² −
//! (α_t/α_s)² σ_s², linear-Gaussian conditioning gives
//!
//!   mean = α_s x₀̂ + (α_t/α_s)(σ_s²/σ_t²)(x_t − α_t x₀̂)
//!   var  = σ_s² σ_{t|s}² / σ_t²
//!
//! On the VP-linear schedule this is exactly Ho et al.'s sampler with the
//! "small" posterior variance; it is also DDIM-η at η = 1 up to the σ̂
//! parameterization.

use crate::linalg::Scratch;
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::solvers::stepper::Stepper;
use crate::solvers::{step_noise, Grid};

/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`DdpmStepper`]).
pub fn solve(
    model: &dyn ModelEval,
    grid: &Grid,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0 = vec![0.0; n * dim];
    let mut xi = vec![0.0; n * dim];
    for i in 0..m {
        model.eval_batch(x, &grid.ctx(i), &mut x0);
        step_noise(noise, i, dim, n, &mut xi);
        let (a_t, a_s) = (grid.alphas[i], grid.alphas[i + 1]);
        let (s_t, s_s) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let ratio = a_t / a_s;
        let sig_ts2 = (s_t * s_t - ratio * ratio * s_s * s_s).max(0.0);
        let gain = ratio * s_s * s_s / (s_t * s_t);
        let post_std = (s_s * s_s * sig_ts2 / (s_t * s_t)).max(0.0).sqrt();
        for k in 0..n * dim {
            let mean = a_s * x0[k] + gain * (x[k] - a_t * x0[k]);
            x[k] = mean + post_std * xi[k];
        }
    }
}

/// Ancestral DDPM as an incremental [`Stepper`] (memoryless): the only
/// state is a two-slot [`Scratch`] arena, sized at `init` so the step
/// path never allocates.
#[derive(Default)]
pub struct DdpmStepper {
    scr: Scratch,
}

impl DdpmStepper {
    /// A fresh stepper; sized at [`Stepper::init`].
    pub fn new() -> Self {
        DdpmStepper::default()
    }
}

impl Stepper for DdpmStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        self.scr = Scratch::new(2, n * model.dim());
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let [x0, xi] = self.scr.split(n * dim);
        model.eval_batch(x, &grid.ctx(i), x0);
        step_noise(noise, i, dim, n, xi);
        let (a_t, a_s) = (grid.alphas[i], grid.alphas[i + 1]);
        let (s_t, s_s) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let ratio = a_t / a_s;
        let sig_ts2 = (s_t * s_t - ratio * ratio * s_s * s_s).max(0.0);
        let gain = ratio * s_s * s_s / (s_t * s_t);
        let post_std = (s_s * s_s * sig_ts2 / (s_t * s_t)).max(0.0).sqrt();
        for k in 0..n * dim {
            let mean = a_s * x0[k] + gain * (x[k] - a_t * x0[k]);
            x[k] = mean + post_std * xi[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::GmmAnalytic;
    use crate::rng::normal::PhiloxNormal;
    use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
    use crate::util::close;

    #[test]
    fn posterior_variance_formula_vp() {
        // Cross-check against the textbook DDPM β̃ on a 2-point grid.
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformT, 4));
        let i = 1;
        let (a_t, a_s) = (grid.alphas[i], grid.alphas[i + 1]);
        let (s_t, s_s) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let ratio = a_t / a_s;
        let beta_eff = (s_t * s_t - ratio * ratio * s_s * s_s).max(0.0);
        // β̃ = σ_s²/σ_t² · β_eff (Ho et al. Eq. 7 in (α,σ) form).
        let want = s_s * s_s / (s_t * s_t) * beta_eff;
        let got = s_s * s_s * beta_eff / (s_t * s_t);
        assert!(close(got, want, 1e-15, 0.0));
    }

    #[test]
    fn many_steps_recover_single_gaussian_moments() {
        // DDPM with many steps samples ≈ the data distribution; for a
        // single Gaussian the terminal second moment is analytic.
        let gmm = Gmm::new(vec![1.0], vec![vec![0.0]], vec![vec![1.5]]);
        let model = GmmAnalytic::new(gmm);
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 200));
        let n = 2000;
        let mut noise = PhiloxNormal::new(11);
        let mut x = crate::solvers::prior_sample(&grid, 1, n, &mut noise);
        solve(&model, &grid, &mut x, n, &mut noise);
        let var = x.iter().map(|v| v * v).sum::<f64>() / n as f64;
        assert!(close(var, 1.5, 0.12, 0.0), "var={var}");
        let mean = crate::util::mean(&x);
        assert!(mean.abs() < 0.1, "mean={mean}");
    }
}
