//! SA-Solver (Algorithm 1): the s-step stochastic Adams predictor
//! (Eq. (14)) and ŝ-step corrector (Eq. (17)) on the variance-controlled
//! diffusion SDE, with the paper's warm-up schedule and a single shared ξ
//! per step for predictor and corrector.
//!
//! The expensive part of a step is the model evaluation; everything here is
//! O(s² + n·dim·s) with coefficients computed once per step (they depend on
//! the λ grid and τ only, not on data) and the state update fused into a
//! single pass per buffer entry.

use crate::config::{Prediction, SamplerConfig};
use crate::jsonlite::Value;
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::solvers::coeffs::{coefficients, StepCoeffs, StepEnds};
use crate::solvers::snapshot::StepperState;
use crate::solvers::stepper::{retain_rows, HistoryRing, Stepper};
use crate::solvers::{step_noise, Grid};
use crate::tau::TauFn;
use crate::util::error::{Error, Result};
use std::collections::VecDeque;

/// SA-Solver options.
#[derive(Debug, Clone)]
pub struct SaSolverOpts {
    /// Predictor steps s ≥ 1 (Eq. 14).
    pub predictor_steps: usize,
    /// Corrector steps ŝ ≥ 0; 0 disables the corrector (predictor-only).
    pub corrector_steps: usize,
    pub prediction: Prediction,
    pub tau: TauFn,
}

impl SaSolverOpts {
    pub fn from_config(cfg: &SamplerConfig) -> Self {
        SaSolverOpts {
            predictor_steps: cfg.predictor_steps.max(1),
            corrector_steps: cfg.corrector_steps,
            prediction: cfg.prediction,
            tau: cfg.tau_fn(),
        }
    }
}

/// One buffered model evaluation.
struct Entry {
    /// Grid index of the evaluation point.
    idx: usize,
    /// The value the solver interpolates: x₀̂ for data prediction, ε̂ for
    /// noise prediction (converted eagerly so the hot loop is uniform).
    f: Vec<f64>,
}

/// The solver.
pub struct SaSolver {
    pub opts: SaSolverOpts,
}

impl SaSolver {
    pub fn new(opts: SaSolverOpts) -> Self {
        assert!(opts.predictor_steps >= 1);
        SaSolver { opts }
    }

    /// Run the full Algorithm 1 over `grid`, evolving `x` (n×dim) in place
    /// from x_{t₀} to x_{t_M}.
    ///
    /// This is the monolithic seed-era loop, retained as the reference
    /// implementation for the stepper equivalence contract; production
    /// traffic goes through [`SaStepper`] (asserted bit-identical in the
    /// equivalence suite).
    pub fn solve(
        &self,
        model: &dyn ModelEval,
        grid: &Grid,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        debug_assert_eq!(x.len(), n * dim);
        let m = grid.m();
        let keep = self.opts.predictor_steps.max(self.opts.corrector_steps).max(1);
        let mut buffer: VecDeque<Entry> = VecDeque::with_capacity(keep + 1);

        // Warm-up eval at t₀ (line 1 of Algorithm 1).
        let mut f0 = vec![0.0; n * dim];
        model.eval_batch(x, &grid.ctx(0), &mut f0);
        to_interp_space(self.opts.prediction, x, &mut f0, grid, 0);
        buffer.push_front(Entry { idx: 0, f: f0 });

        let mut xi = vec![0.0; n * dim];
        let mut xi_dirty = false;
        let mut x_pred = vec![0.0; n * dim];
        let mut f_new = vec![0.0; n * dim];

        for i in 0..m {
            let ends = step_ends(grid, i, i + 1);
            // One ξ per step, shared by predictor and corrector (Alg. 1).
            // Noise generation is transcendental-bound (bench_perf); skip
            // it entirely on steps that inject none (τ = 0 there, i.e.
            // every ODE configuration and the out-of-band part of the
            // paper's interval τ). `xi` stays zeroed from initialization.
            let injects = self.opts.tau.int_tau2(ends.lam_s, ends.lam_t) > 0.0;
            if injects {
                step_noise(noise, i, dim, n, &mut xi);
            } else if xi_dirty {
                xi.fill(0.0);
            }
            let xi_was_filled = injects;

            // --- Predictor (Eq. 14): s_eff most recent evals.
            let s_eff = buffer.len().min(self.opts.predictor_steps);
            let nodes: Vec<f64> = buffer.iter().take(s_eff).map(|e| grid.lams[e.idx]).collect();
            let pc = coefficients(&nodes, &ends, &self.opts.tau, self.opts.prediction);
            let fs = buffer.iter().take(s_eff).map(|e| e.f.as_slice());
            apply_update(&pc, x, fs, &xi, &mut x_pred);

            // --- Evaluate the model at the prediction (line 6/11).
            model.eval_batch(&x_pred, &grid.ctx(i + 1), &mut f_new);
            to_interp_space(self.opts.prediction, &x_pred, &mut f_new, grid, i + 1);

            // --- Corrector (Eq. 17): prediction eval + ŝ_eff former evals.
            if self.opts.corrector_steps > 0 {
                let sc_eff = buffer.len().min(self.opts.corrector_steps);
                let mut cnodes = Vec::with_capacity(sc_eff + 1);
                cnodes.push(grid.lams[i + 1]);
                cnodes.extend(buffer.iter().take(sc_eff).map(|e| grid.lams[e.idx]));
                let cc = coefficients(&cnodes, &ends, &self.opts.tau, self.opts.prediction);
                let fs = std::iter::once(f_new.as_slice())
                    .chain(buffer.iter().take(sc_eff).map(|e| e.f.as_slice()));
                let mut x_next = std::mem::take(&mut x_pred);
                apply_update(&cc, x, fs, &xi, &mut x_next);
                x.copy_from_slice(&x_next);
                x_pred = x_next;
            } else {
                x.copy_from_slice(&x_pred);
            }

            xi_dirty = xi_was_filled;

            // Recycle the evicted entry's allocation for the next step's
            // f_new (no steady-state allocation in the solve loop).
            let recycled = if buffer.len() >= keep {
                buffer.pop_back().map(|e| e.f)
            } else {
                None
            };
            buffer.push_front(Entry {
                idx: i + 1,
                f: std::mem::replace(&mut f_new, recycled.unwrap_or_else(|| vec![0.0; n * dim])),
            });
            while buffer.len() > keep {
                buffer.pop_back();
            }
        }
    }
}

/// Convert a fresh data-prediction eval into the interpolation space:
/// identity for data prediction, ε̂ = (x − α x₀̂)/σ for noise prediction.
/// Shared by the monolithic reference loop and [`SaStepper`].
fn to_interp_space(
    prediction: Prediction,
    x_at_eval: &[f64],
    f: &mut [f64],
    grid: &Grid,
    idx: usize,
) {
    if prediction == Prediction::Noise {
        let alpha = grid.alphas[idx];
        let sigma = grid.sigmas[idx];
        for k in 0..f.len() {
            f[k] = (x_at_eval[k] - alpha * f[k]) / sigma;
        }
    }
}

/// Everything step `i` needs that depends only on the grid and the solver
/// options — precomputed at `init`/`restore` so the step hot path does no
/// coefficient work and no allocation.
struct StepPlan {
    /// Whether this step injects noise (τ² integrates to > 0 over it).
    injects: bool,
    /// Predictor coefficients (Eq. 14) for the history depth this step has.
    pc: StepCoeffs,
    /// Corrector coefficients (Eq. 17); `None` when the corrector is off.
    cc: Option<StepCoeffs>,
}

/// Precompute the per-step coefficient plan. The history depth at entry to
/// step `i` is `min(i + 1, keep)` by construction (the warm-up commits one
/// entry, every step commits one more, capped at `keep`), so the
/// interpolation nodes — λ of the buffered evals, newest first — are
/// `grid.lams[i], grid.lams[i − 1], …` and the whole table is a pure
/// function of (grid, opts).
fn build_plan(opts: &SaSolverOpts, grid: &Grid, keep: usize) -> Vec<StepPlan> {
    let m = grid.m();
    let mut plans = Vec::with_capacity(m);
    let mut nodes: Vec<f64> = Vec::with_capacity(keep + 1);
    for i in 0..m {
        let ends = step_ends(grid, i, i + 1);
        let injects = opts.tau.int_tau2(ends.lam_s, ends.lam_t) > 0.0;
        let hist_len = (i + 1).min(keep);
        let s_eff = hist_len.min(opts.predictor_steps);
        nodes.clear();
        nodes.extend((0..s_eff).map(|j| grid.lams[i - j]));
        let pc = coefficients(&nodes, &ends, &opts.tau, opts.prediction);
        let cc = if opts.corrector_steps > 0 {
            let sc_eff = hist_len.min(opts.corrector_steps);
            nodes.clear();
            nodes.push(grid.lams[i + 1]);
            nodes.extend((0..sc_eff).map(|j| grid.lams[i - j]));
            Some(coefficients(&nodes, &ends, &opts.tau, opts.prediction))
        } else {
            None
        };
        plans.push(StepPlan { injects, pc, cc });
    }
    plans
}

/// SA-Solver as an incremental [`Stepper`]: the history buffer becomes a
/// contiguous [`HistoryRing`] arena, the per-step coefficients are
/// precomputed into a `StepPlan` table at `init`/`restore`, and each
/// `step(i)` call is exactly one iteration of Algorithm 1's loop — with
/// the predictor/corrector coefficient application fused into a single
/// [`crate::linalg::lincomb_into`] pass and **zero heap allocations**.
pub struct SaStepper {
    opts: SaSolverOpts,
    /// History depth max(s, ŝ, 1).
    keep: usize,
    /// Per-step coefficient table, indexed by grid step.
    plan: Vec<StepPlan>,
    /// History arena; the free slot doubles as the f_new eval target.
    hist: HistoryRing,
    /// Reused per-step entry-offset list for the fused kernel.
    offsets: Vec<usize>,
    xi: Vec<f64>,
    xi_dirty: bool,
    x_pred: Vec<f64>,
}

impl SaStepper {
    /// A stepper for `opts`; sized and planned at [`Stepper::init`] (or
    /// [`Stepper::restore`]).
    pub fn new(opts: SaSolverOpts) -> Self {
        assert!(opts.predictor_steps >= 1);
        let keep = opts.predictor_steps.max(opts.corrector_steps).max(1);
        SaStepper {
            opts,
            keep,
            plan: Vec::new(),
            hist: HistoryRing::new(keep, 0),
            offsets: Vec::new(),
            xi: Vec::new(),
            xi_dirty: false,
            x_pred: Vec::new(),
        }
    }
}

impl Stepper for SaStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        debug_assert_eq!(x.len(), n * dim);
        self.plan = build_plan(&self.opts, grid, self.keep);
        self.hist = HistoryRing::new(self.keep, n * dim);
        self.offsets = Vec::with_capacity(self.keep + 1);
        // Warm-up eval at t₀ (line 1 of Algorithm 1) straight into the
        // ring's free slot.
        model.eval_batch(x, &grid.ctx(0), self.hist.free_mut());
        to_interp_space(self.opts.prediction, x, self.hist.free_mut(), grid, 0);
        self.hist.commit(0);
        self.xi = vec![0.0; n * dim];
        self.xi_dirty = false;
        self.x_pred = vec![0.0; n * dim];
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        debug_assert_eq!(x.len(), n * dim);
        let plan = &self.plan[i];
        // One ξ per step, shared by predictor and corrector (Alg. 1); skip
        // generation entirely on steps that inject none (see solve()).
        if plan.injects {
            step_noise(noise, i, dim, n, &mut self.xi);
        } else if self.xi_dirty {
            self.xi.fill(0.0);
        }

        // --- Predictor (Eq. 14): s_eff most recent evals, combined in one
        // fused pass (noise term included — exactly apply_update's order).
        let s_eff = plan.pc.b.len();
        debug_assert!(self.hist.len() >= s_eff);
        // The plan assumed nodes λ_i, λ_{i−1}, …; the ring must agree, or
        // precomputed coefficients would silently apply to wrong nodes.
        debug_assert!(
            self.hist.indices().take(s_eff).enumerate().all(|(j, idx)| idx == i - j),
            "history ring indices diverged from the coefficient plan at step {i}"
        );
        self.offsets.clear();
        self.offsets.extend(self.hist.offsets().take(s_eff));
        crate::linalg::lincomb_into(
            plan.pc.c0,
            x,
            Some((plan.pc.sigma_tilde, &self.xi)),
            &plan.pc.b,
            self.hist.data(),
            &self.offsets,
            &mut self.x_pred,
        );

        // --- Evaluate the model at the prediction (line 6/11), straight
        // into the ring's free slot (the would-be f_new buffer).
        model.eval_batch(&self.x_pred, &grid.ctx(i + 1), self.hist.free_mut());
        to_interp_space(self.opts.prediction, &self.x_pred, self.hist.free_mut(), grid, i + 1);

        // --- Corrector (Eq. 17): prediction eval + ŝ_eff former evals.
        if let Some(cc) = &plan.cc {
            let sc_eff = cc.b.len() - 1;
            debug_assert!(self.hist.len() >= sc_eff);
            self.offsets.clear();
            self.offsets.push(self.hist.free_offset());
            self.offsets.extend(self.hist.offsets().take(sc_eff));
            crate::linalg::lincomb_into(
                cc.c0,
                x,
                Some((cc.sigma_tilde, &self.xi)),
                &cc.b,
                self.hist.data(),
                &self.offsets,
                &mut self.x_pred,
            );
        }
        x.copy_from_slice(&self.x_pred);

        self.xi_dirty = plan.injects;
        self.hist.commit(i + 1);
    }

    fn retain_lanes(&mut self, keep: &[bool], dim: usize) {
        self.hist.retain_lanes(keep, dim);
        // ξ rows carry cross-step state only in the "stays zero" sense;
        // compacting survivor rows preserves both the zero and the filled
        // case bitwise.
        retain_rows(&mut self.xi, keep, dim);
        retain_rows(&mut self.x_pred, keep, dim);
    }

    /// The carried state is the history ring (values + grid indices) and
    /// the `xi_dirty` flag. ξ itself is NOT serialized: its contents are
    /// only ever read on steps that inject no noise, and on those the
    /// uninterrupted run guarantees it is all zeros (either never filled or
    /// re-zeroed by the dirty check) — so restoring a zeroed ξ with the
    /// saved flag is bit-identical. `x_pred` and the ring's free slot are
    /// pure scratch, fully rewritten every step; the coefficient table is
    /// a pure function of (grid, opts) and is rebuilt on restore.
    fn snapshot(&self, lanes: usize, dim: usize) -> StepperState {
        StepperState {
            lanes,
            dim,
            scalars: Value::obj(vec![
                ("xi_dirty", Value::Bool(self.xi_dirty)),
                (
                    "buf_idx",
                    Value::Array(self.hist.indices().map(|idx| Value::Num(idx as f64)).collect()),
                ),
            ]),
            mats: (0..self.hist.len())
                .map(|j| (format!("buf{j}"), self.hist.entry(j).to_vec()))
                .collect(),
        }
    }

    fn restore(&mut self, state: &StepperState, grid: &Grid, dim: usize) -> Result<()> {
        let idxs: Vec<usize> = state
            .scalars
            .get("buf_idx")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("sa snapshot missing 'buf_idx'"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::config("sa 'buf_idx' entry not an index")))
            .collect::<Result<_>>()?;
        if idxs.len() != state.mats.len() {
            return Err(Error::config(format!(
                "sa snapshot has {} buffer indices but {} matrices",
                idxs.len(),
                state.mats.len()
            )));
        }
        if idxs.len() > self.keep {
            return Err(Error::config(format!(
                "sa snapshot has {} history entries but this config keeps {}",
                idxs.len(),
                self.keep
            )));
        }
        // The precomputed coefficient plan assumes the ring holds exactly
        // the newest min(front + 1, keep) evals at indices front, front−1,
        // …; reject any snapshot that breaks that shape (corruption or a
        // foreign writer) instead of silently applying coefficients to the
        // wrong interpolation nodes.
        check_contiguous_history(&idxs, self.keep, "sa")?;
        self.plan = build_plan(&self.opts, grid, self.keep);
        let len = state.lanes * dim;
        self.hist = HistoryRing::new(self.keep, len);
        for (j, idx) in idxs.iter().enumerate() {
            // Front-to-back order, exactly as snapshotted.
            self.hist.restore_entry(*idx, state.mat(&format!("buf{j}"))?);
        }
        self.offsets = Vec::with_capacity(self.keep + 1);
        self.xi_dirty = state.scalars.opt_bool("xi_dirty", false);
        self.xi = vec![0.0; len];
        self.x_pred = vec![0.0; len];
        Ok(())
    }
}

/// Validate a restored history-index sequence against the shape the
/// precomputed coefficient plans assume: the newest `min(front + 1, keep)`
/// evaluations at contiguous descending grid indices `front, front − 1, …`.
/// Shared by the SA and UniPC steppers' `restore` so an inconsistent
/// snapshot is a typed error, never silently-wrong coefficients.
pub(crate) fn check_contiguous_history(idxs: &[usize], keep: usize, what: &str) -> Result<()> {
    let Some(&front) = idxs.first() else {
        return Err(Error::config(format!("{what} snapshot has an empty history buffer")));
    };
    let want_len = (front + 1).min(keep);
    let contiguous = idxs.iter().enumerate().all(|(j, &idx)| front >= j && idx == front - j);
    if !contiguous || idxs.len() != want_len {
        return Err(Error::config(format!(
            "{what} snapshot history indices {idxs:?} are not the contiguous run the \
             coefficient plan assumes ({want_len} entries descending from {front})"
        )));
    }
    Ok(())
}

/// Schedule endpoints for the step grid[i] → grid[j].
pub fn step_ends(grid: &Grid, i: usize, j: usize) -> StepEnds {
    StepEnds {
        lam_s: grid.lams[i],
        lam_t: grid.lams[j],
        alpha_s: grid.alphas[i],
        alpha_t: grid.alphas[j],
        sigma_s: grid.sigmas[i],
        sigma_t: grid.sigmas[j],
    }
}

/// Fused update: out = c0·x + Σ_j b_j F_j + σ̃·ξ, in a SINGLE pass over
/// the state (one read of each operand, one write) — the Rust analog of
/// the Pallas `sa_update` kernel; multi-pass composition costs (2 + s)
/// extra state-sized memory sweeps (bench_perf, §Perf).
fn apply_update<'a>(
    c: &StepCoeffs,
    x: &[f64],
    fs: impl Iterator<Item = &'a [f64]>,
    xi: &[f64],
    out: &mut [f64],
) {
    let fs: Vec<&[f64]> = fs.collect();
    debug_assert_eq!(fs.len(), c.b.len());
    match fs.len() {
        1 => fused_pass::<1>(c, x, &fs, xi, out),
        2 => fused_pass::<2>(c, x, &fs, xi, out),
        3 => fused_pass::<3>(c, x, &fs, xi, out),
        4 => fused_pass::<4>(c, x, &fs, xi, out),
        _ => fused_pass_dyn(c, x, &fs, xi, out),
    }
}

/// Monomorphized fused pass for the common small orders (lets the
/// compiler unroll the buffer loop).
fn fused_pass<const S: usize>(
    c: &StepCoeffs,
    x: &[f64],
    fs: &[&[f64]],
    xi: &[f64],
    out: &mut [f64],
) {
    let mut b = [0.0f64; S];
    b.copy_from_slice(&c.b[..S]);
    for k in 0..out.len() {
        let mut acc = c.c0 * x[k] + c.sigma_tilde * xi[k];
        for j in 0..S {
            acc += b[j] * fs[j][k];
        }
        out[k] = acc;
    }
}

fn fused_pass_dyn(c: &StepCoeffs, x: &[f64], fs: &[&[f64]], xi: &[f64], out: &mut [f64]) {
    for k in 0..out.len() {
        let mut acc = c.c0 * x[k] + c.sigma_tilde * xi[k];
        for (bj, f) in c.b.iter().zip(fs) {
            acc += bj * f[k];
        }
        out[k] = acc;
    }
}

/// Convenience wrapper: build a solver from a config and run it.
pub fn solve_with_config(
    model: &dyn ModelEval,
    grid: &Grid,
    cfg: &SamplerConfig,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    SaSolver::new(SaSolverOpts::from_config(cfg)).solve(model, grid, x, n, noise)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::{EvalCtx, GmmAnalytic};
    use crate::rng::normal::{PhiloxNormal, ZeroNormal};
    use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
    use crate::util::{close, std_dev};

    /// A model that always predicts x₀̂ = 0 (pure contraction).
    struct ZeroModel {
        dim: usize,
    }
    impl ModelEval for ZeroModel {
        fn dim(&self) -> usize {
            self.dim
        }
        fn eval_batch(&self, _xs: &[f64], _ctx: &EvalCtx, out: &mut [f64]) {
            out.fill(0.0);
        }
    }

    fn grid(m: usize) -> Grid {
        let sch = NoiseSchedule::vp_linear();
        Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m))
    }

    #[test]
    fn zero_model_contracts_exactly() {
        // With x₀̂ ≡ 0 and τ = 0, every step multiplies the state by
        // σ_{i+1}/σ_i exactly (data parameterization), independent of order.
        for s in [1, 2, 3] {
            let g = grid(6);
            let model = ZeroModel { dim: 3 };
            let opts = SaSolverOpts {
                predictor_steps: s,
                corrector_steps: 0,
                prediction: Prediction::Data,
                tau: TauFn::Constant(0.0),
            };
            let mut x = vec![1.0; 6];
            SaSolver::new(opts).solve(&model, &g, &mut x, 2, &mut ZeroNormal);
            let want = g.sigmas[6] / g.sigmas[0];
            for v in &x {
                assert!(close(*v, want, 1e-12, 0.0), "s={s}: {v} vs {want}");
            }
        }
    }

    #[test]
    fn injected_noise_variance_matches_analytic() {
        // One step, x₀̂ ≡ 0, x = 0: x₁ = σ̃ ξ; check sample std ≈ σ̃.
        let g = grid(1);
        let model = ZeroModel { dim: 1 };
        let tau = 1.0;
        let opts = SaSolverOpts {
            predictor_steps: 1,
            corrector_steps: 0,
            prediction: Prediction::Data,
            tau: TauFn::Constant(tau),
        };
        let n = 4000;
        let mut x = vec![0.0; n];
        let mut noise = PhiloxNormal::new(3);
        SaSolver::new(opts).solve(&model, &g, &mut x, n, &mut noise);
        let h = g.lams[1] - g.lams[0];
        let want = g.sigmas[1] * (1.0 - (-2.0 * tau * tau * h).exp()).sqrt();
        let got = std_dev(&x);
        assert!(close(got, want, 0.05, 0.0), "std {got} vs σ̃ {want}");
    }

    #[test]
    fn corrector_changes_result_and_stays_finite() {
        let g = grid(8);
        let gmm = Gmm::structured(3, 2, 1.5, 1);
        let model = GmmAnalytic::new(gmm);
        let base = SaSolverOpts {
            predictor_steps: 2,
            corrector_steps: 0,
            prediction: Prediction::Data,
            tau: TauFn::Constant(0.5),
        };
        let with_corr = SaSolverOpts { corrector_steps: 2, ..base.clone() };
        let mut xa = vec![0.3; 12];
        let mut xb = vec![0.3; 12];
        let mut na = PhiloxNormal::new(5);
        let mut nb = PhiloxNormal::new(5);
        SaSolver::new(base).solve(&model, &g, &mut xa, 4, &mut na);
        SaSolver::new(with_corr).solve(&model, &g, &mut xb, 4, &mut nb);
        assert_ne!(xa, xb);
        assert!(xb.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn higher_order_more_accurate_on_ode() {
        // τ=0 on an exact (single-Gaussian) model: the ODE solution's
        // terminal mean/std are analytic; order-3 must beat order-1 with
        // coarse steps. For a single Gaussian prior N(0, v), the PF-ODE is
        // linear; starting at x_T, the exact map is
        // x_0 = x_T · σ-ratio solved... instead compare against a very fine
        // high-order reference run.
        let gmm = Gmm::new(vec![1.0], vec![vec![0.5, -0.2]], vec![vec![0.8, 1.3]]);
        let model = GmmAnalytic::new(gmm);
        let fine = grid(256);
        let opts3 = SaSolverOpts {
            predictor_steps: 3,
            corrector_steps: 3,
            prediction: Prediction::Data,
            tau: TauFn::Constant(0.0),
        };
        let x0: Vec<f64> = vec![1.2, -0.7, 0.4, 0.9]; // 2 samples × dim 2
        let mut x_ref = x0.clone();
        SaSolver::new(opts3.clone()).solve(&model, &fine, &mut x_ref, 2, &mut ZeroNormal);

        let coarse = grid(8);
        let mut errs = Vec::new();
        for s in [1usize, 3] {
            let opts = SaSolverOpts {
                predictor_steps: s,
                corrector_steps: 0,
                prediction: Prediction::Data,
                tau: TauFn::Constant(0.0),
            };
            let mut x = x0.clone();
            SaSolver::new(opts).solve(&model, &coarse, &mut x, 2, &mut ZeroNormal);
            let err: f64 = x
                .iter()
                .zip(&x_ref)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            errs.push(err);
        }
        assert!(
            errs[1] < errs[0] * 0.5,
            "order-3 err {} not ≪ order-1 err {}",
            errs[1],
            errs[0]
        );
    }

    #[test]
    fn noise_prediction_runs_and_differs() {
        let g = grid(10);
        let gmm = Gmm::structured(2, 2, 1.5, 2);
        let model = GmmAnalytic::new(gmm);
        let mk = |pred| SaSolverOpts {
            predictor_steps: 2,
            corrector_steps: 1,
            prediction: pred,
            tau: TauFn::Constant(0.4),
        };
        let mut xd = vec![0.5; 8];
        let mut xn = vec![0.5; 8];
        let mut sd = PhiloxNormal::new(7);
        let mut sn = PhiloxNormal::new(7);
        SaSolver::new(mk(Prediction::Data)).solve(&model, &g, &mut xd, 4, &mut sd);
        SaSolver::new(mk(Prediction::Noise)).solve(&model, &g, &mut xn, 4, &mut sn);
        assert!(xd.iter().all(|v| v.is_finite()));
        assert!(xn.iter().all(|v| v.is_finite()));
        assert_ne!(xd, xn, "parameterizations are different numerical schemes");
    }

    #[test]
    fn restore_history_shape_check() {
        // Valid shapes: contiguous descending run of min(front + 1, keep).
        assert!(check_contiguous_history(&[3, 2, 1], 3, "sa").is_ok());
        assert!(check_contiguous_history(&[0], 3, "sa").is_ok());
        assert!(check_contiguous_history(&[1], 1, "sa").is_ok());
        // Corrupt shapes are typed errors, not silently-wrong coefficients.
        assert!(check_contiguous_history(&[], 3, "sa").is_err(), "empty");
        assert!(check_contiguous_history(&[3, 1], 3, "sa").is_err(), "gap");
        assert!(check_contiguous_history(&[3, 2], 3, "sa").is_err(), "too short");
        assert!(check_contiguous_history(&[1, 0], 1, "sa").is_err(), "too long");
        assert!(check_contiguous_history(&[2, 3], 3, "sa").is_err(), "ascending");
    }

    #[test]
    fn warmup_respects_available_history() {
        // With M=2 and s=3 the solver must silently run s_eff = 1, 2 — no
        // panic, finite output.
        let g = grid(2);
        let model = ZeroModel { dim: 2 };
        let opts = SaSolverOpts {
            predictor_steps: 3,
            corrector_steps: 3,
            prediction: Prediction::Data,
            tau: TauFn::Constant(1.0),
        };
        let mut x = vec![1.0; 4];
        let mut noise = PhiloxNormal::new(1);
        SaSolver::new(opts).solve(&model, &g, &mut x, 2, &mut noise);
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
