//! Snapshot/restore for the stepper core: a versioned, self-describing
//! serialization of every solver's between-step state.
//!
//! SA-Solver's recurrence — and the whole predictor/corrector family — is a
//! small explicit state machine: a history buffer of past model evaluations,
//! a handful of shared scalars, and a position on the grid. [`StepperState`]
//! captures exactly that, which turns every in-flight solve into a
//! *preemptible, migratable* unit: the coordinator can checkpoint a batch at
//! any step boundary, a restarted process can resume it, and the remaining
//! steps are bit-identical to the uninterrupted run (the contract asserted
//! by `integration_snapshot`).
//!
//! Wire shape (schema_version 1, the `registry.rs` provenance pattern):
//! ```json
//! {"schema_version": 1, "lanes": 3, "dim": 2,
//!  "scalars": {"xi_dirty": false, "buf_idx": [2, 1, 0]},
//!  "mats": [{"name": "buf0", "hex": "3ff0000000000000..."}]}
//! ```
//!
//! All floating-point payloads are encoded as IEEE-754 bit patterns (16 hex
//! chars per f64) rather than decimal text: the bit-identity contract covers
//! every value a solver can produce, including `-0.0`, which a decimal
//! round-trip through the integer fast path of the JSON writer would
//! silently rewrite to `+0.0`.

use crate::jsonlite::Value;
use crate::util::error::{Error, Result};

/// Newest snapshot schema this build reads and writes (stepper states,
/// batch-run checkpoints and server checkpoint files all share it). Newer
/// files are rejected with a typed error, never a panic.
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 1;

/// Reject a value whose `schema_version` is missing or newer than this
/// build supports. `what` names the container for the error message.
pub fn check_schema_version(v: &Value, what: &str) -> Result<u64> {
    let version = v
        .get("schema_version")
        .and_then(Value::as_u64)
        .ok_or_else(|| Error::config(format!("{what} missing 'schema_version'")))?;
    if version > SNAPSHOT_SCHEMA_VERSION {
        return Err(Error::config(format!(
            "{what} schema_version {version} is newer than supported {SNAPSHOT_SCHEMA_VERSION}"
        )));
    }
    Ok(version)
}

/// Encode f64s as concatenated big-endian IEEE-754 bit patterns (16 lowercase
/// hex chars each) — exact for every value, including -0.0 and subnormals.
pub fn f64s_to_hex(xs: &[f64]) -> String {
    let mut out = String::with_capacity(xs.len() * 16);
    for x in xs {
        out.push_str(&format!("{:016x}", x.to_bits()));
    }
    out
}

/// Inverse of [`f64s_to_hex`].
pub fn hex_to_f64s(s: &str) -> Result<Vec<f64>> {
    if s.len() % 16 != 0 {
        return Err(Error::config(format!(
            "f64 hex payload length {} is not a multiple of 16",
            s.len()
        )));
    }
    let mut out = Vec::with_capacity(s.len() / 16);
    for chunk in s.as_bytes().chunks(16) {
        let txt = std::str::from_utf8(chunk)
            .map_err(|_| Error::config("f64 hex payload is not ascii"))?;
        let bits = u64::from_str_radix(txt, 16)
            .map_err(|_| Error::config(format!("invalid f64 hex chunk '{txt}'")))?;
        out.push(f64::from_bits(bits));
    }
    Ok(out)
}

/// One f64 as its 16-char hex bit pattern.
pub fn f64_to_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Inverse of [`f64_to_hex`].
pub fn hex_to_f64(s: &str) -> Result<f64> {
    let v = hex_to_f64s(s)?;
    if v.len() != 1 {
        return Err(Error::config(format!("expected one f64, got {}", v.len())));
    }
    Ok(v[0])
}

/// A u64 (noise-stream key or cursor) as 16 hex chars — JSON numbers are
/// f64 in this crate's jsonlite, which cannot hold all u64s exactly.
pub fn u64_to_hex(x: u64) -> String {
    format!("{x:016x}")
}

/// Inverse of [`u64_to_hex`].
pub fn hex_to_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|_| Error::config(format!("invalid u64 hex '{s}'")))
}

/// Required field `key` of `v`: an array of hex-encoded u64 strings (the
/// shape every checkpoint container uses for id and noise-key lists).
pub fn hex_u64_array(v: &Value, key: &str) -> Result<Vec<u64>> {
    v.get(key)
        .and_then(Value::as_array)
        .ok_or_else(|| Error::config(format!("missing '{key}' array")))?
        .iter()
        .map(|s| {
            hex_to_u64(
                s.as_str().ok_or_else(|| Error::config(format!("'{key}' entry not a string")))?,
            )
        })
        .collect()
}

/// The between-step state of one stepper over `lanes` lanes: shared
/// (lane-independent) scalars plus named per-lane `lanes × dim` matrices.
/// Memoryless schemes (DDIM, DDPM, Euler–Maruyama, DPM-Solver-2, Heun,
/// EDM-SDE) have an empty state — their scratch buffers are fully rewritten
/// each step. The split between scalars and matrices is what lets the
/// coordinator re-shard a restored batch across a different thread count:
/// matrices are split/merged by lane rows, scalars must agree across shards.
#[derive(Debug, Clone, PartialEq)]
pub struct StepperState {
    /// Number of lanes this state covers.
    pub lanes: usize,
    /// Data dimension per lane.
    pub dim: usize,
    /// Solver-specific shared fields (a JSON object; empty when stateless).
    pub scalars: Value,
    /// Named per-lane matrices, row-major `lanes × dim`, in a fixed
    /// solver-defined order.
    pub mats: Vec<(String, Vec<f64>)>,
}

impl StepperState {
    /// The empty state of a memoryless stepper.
    pub fn stateless(lanes: usize, dim: usize) -> StepperState {
        StepperState { lanes, dim, scalars: Value::obj(vec![]), mats: Vec::new() }
    }

    /// Look up a matrix by name.
    pub fn mat(&self, name: &str) -> Result<&[f64]> {
        self.mats
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m.as_slice())
            .ok_or_else(|| Error::config(format!("stepper state missing matrix '{name}'")))
    }

    /// Serialize to the versioned wire form (hex-encoded f64 payloads).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("schema_version", Value::Num(SNAPSHOT_SCHEMA_VERSION as f64)),
            ("lanes", Value::Num(self.lanes as f64)),
            ("dim", Value::Num(self.dim as f64)),
            ("scalars", self.scalars.clone()),
            (
                "mats",
                Value::Array(
                    self.mats
                        .iter()
                        .map(|(name, m)| {
                            Value::obj(vec![
                                ("name", Value::Str(name.clone())),
                                ("hex", Value::Str(f64s_to_hex(m))),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the wire form; rejects newer schema versions and shape
    /// mismatches with typed errors.
    pub fn from_json(v: &Value) -> Result<StepperState> {
        check_schema_version(v, "stepper state")?;
        let lanes = v.req_usize("lanes")?;
        let dim = v.req_usize("dim")?;
        let scalars = v.get("scalars").cloned().unwrap_or_else(|| Value::obj(vec![]));
        let mats = v
            .get("mats")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("stepper state missing 'mats' array"))?
            .iter()
            .map(|m| {
                let name = m.req_str("name")?.to_string();
                let xs = hex_to_f64s(m.req_str("hex")?)?;
                if xs.len() != lanes * dim {
                    return Err(Error::config(format!(
                        "stepper state matrix '{name}' has {} values, expected {}×{}",
                        xs.len(),
                        lanes,
                        dim
                    )));
                }
                Ok((name, xs))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(StepperState { lanes, dim, scalars, mats })
    }

    /// Merge per-shard states (ascending disjoint lane sets, in order) into
    /// one combined state: matrices concatenate by rows, scalars must be
    /// identical across shards — shards of one batch step in lockstep, so a
    /// disagreement means per-shard state drifted (a bug worth failing on).
    pub fn merge(parts: &[StepperState]) -> Result<StepperState> {
        let first = parts
            .first()
            .ok_or_else(|| Error::config("cannot merge zero stepper states"))?;
        let mut merged = first.clone();
        for p in &parts[1..] {
            if p.scalars != first.scalars || p.dim != first.dim {
                return Err(Error::config(
                    "shard stepper states disagree on shared scalars — cannot merge",
                ));
            }
            if p.mats.len() != first.mats.len() {
                return Err(Error::config("shard stepper states disagree on matrix set"));
            }
            for ((name, acc), (pname, pm)) in merged.mats.iter_mut().zip(&p.mats) {
                if name != pname {
                    return Err(Error::config(format!(
                        "shard stepper states disagree on matrix order: '{name}' vs '{pname}'"
                    )));
                }
                acc.extend_from_slice(pm);
            }
            merged.lanes += p.lanes;
        }
        Ok(merged)
    }

    /// Split a combined state back into per-shard states of `counts` lanes
    /// each (the restore-side shard layout — free to differ from the layout
    /// the snapshot was taken under).
    pub fn split(&self, counts: &[usize]) -> Result<Vec<StepperState>> {
        if counts.iter().sum::<usize>() != self.lanes {
            return Err(Error::config(format!(
                "shard lane counts {:?} do not sum to {} lanes",
                counts, self.lanes
            )));
        }
        let mut out = Vec::with_capacity(counts.len());
        let mut row = 0usize;
        for &c in counts {
            let mats = self
                .mats
                .iter()
                .map(|(name, m)| (name.clone(), m[row * self.dim..(row + c) * self.dim].to_vec()))
                .collect();
            out.push(StepperState {
                lanes: c,
                dim: self.dim,
                scalars: self.scalars.clone(),
                mats,
            });
            row += c;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite::{parse, to_string};

    #[test]
    fn hex_codec_is_bit_exact() {
        let xs = vec![
            0.0,
            -0.0,
            1.5,
            -1.0 / 3.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            4.9e-324, // smallest subnormal
            std::f64::consts::PI,
        ];
        let back = hex_to_f64s(&f64s_to_hex(&xs)).unwrap();
        assert_eq!(xs.len(), back.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} changed bits");
        }
        // -0.0 specifically: plain JSON numbers would lose the sign.
        assert_eq!(hex_to_f64(&f64_to_hex(-0.0)).unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(hex_to_u64(&u64_to_hex(u64::MAX)).unwrap(), u64::MAX);
        assert!(hex_to_f64s("123").is_err());
        assert!(hex_to_f64s("zzzzzzzzzzzzzzzz").is_err());
    }

    #[test]
    fn hex_u64_array_parses_and_rejects() {
        let v = parse(r#"{"ids": ["0000000000000001", "ffffffffffffffff"]}"#).unwrap();
        assert_eq!(hex_u64_array(&v, "ids").unwrap(), vec![1, u64::MAX]);
        assert!(hex_u64_array(&v, "missing").is_err());
        let bad = parse(r#"{"ids": [7]}"#).unwrap();
        assert!(hex_u64_array(&bad, "ids").is_err(), "non-string entry must be rejected");
    }

    fn state() -> StepperState {
        StepperState {
            lanes: 3,
            dim: 2,
            scalars: Value::obj(vec![("flag", Value::Bool(true))]),
            mats: vec![
                ("a".into(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                ("b".into(), vec![-0.0, 0.5, 1.5, 2.5, 3.5, 4.5]),
            ],
        }
    }

    #[test]
    fn json_roundtrip_bitwise() {
        let st = state();
        let parsed = StepperState::from_json(&parse(&to_string(&st.to_json())).unwrap()).unwrap();
        assert_eq!(st, parsed);
        assert_eq!(parsed.mat("b").unwrap()[0].to_bits(), (-0.0f64).to_bits());
        assert!(parsed.mat("missing").is_err());
    }

    #[test]
    fn newer_schema_rejected_with_typed_error() {
        let mut j = state().to_json();
        if let Value::Object(fields) = &mut j {
            for (k, v) in fields.iter_mut() {
                if k == "schema_version" {
                    *v = Value::Num((SNAPSHOT_SCHEMA_VERSION + 1) as f64);
                }
            }
        }
        let err = StepperState::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        // Missing version is also a typed error, not a default.
        let v = parse(r#"{"lanes": 1, "dim": 1, "mats": []}"#).unwrap();
        assert!(StepperState::from_json(&v).is_err());
    }

    #[test]
    fn merge_then_split_roundtrips() {
        let st = state();
        let parts = st.split(&[1, 2]).unwrap();
        assert_eq!(parts[0].lanes, 1);
        assert_eq!(parts[0].mat("a").unwrap(), &[1.0, 2.0]);
        assert_eq!(parts[1].mat("b").unwrap(), &[1.5, 2.5, 3.5, 4.5]);
        let merged = StepperState::merge(&parts).unwrap();
        assert_eq!(merged, st);
        // A different split layout also merges back (the re-shard case).
        let merged2 = StepperState::merge(&st.split(&[2, 1]).unwrap()).unwrap();
        assert_eq!(merged2, st);
        assert!(st.split(&[1, 1]).is_err(), "counts must cover all lanes");
    }

    #[test]
    fn merge_rejects_scalar_drift() {
        let a = state();
        let mut b = state();
        b.scalars = Value::obj(vec![("flag", Value::Bool(false))]);
        assert!(StepperState::merge(&[a, b]).is_err());
        assert!(StepperState::merge(&[]).is_err());
    }

    #[test]
    fn stateless_is_empty() {
        let st = StepperState::stateless(4, 2);
        assert!(st.mats.is_empty());
        let back = StepperState::from_json(&st.to_json()).unwrap();
        assert_eq!(st, back);
    }
}
