//! The exponentially weighted Adams coefficient engine — Eqs. (15)/(18) of
//! the paper, for both reparameterizations:
//!
//! **Data prediction** (Prop. 4.2): over a step [λ_s, λ_t] (λ_t > λ_s),
//!
//!   x_t = c₀ x_s + Σ_j b_j x₀̂_j + σ̃ ξ
//!   c₀  = (σ_t/σ_s) e^{−W}                     W = ∫_{λ_s}^{λ_t} τ²(λ) dλ
//!   b_j = α_t ∫ e^{−W(λ)} e^{λ−λ_t} (1+τ²) l_j(λ) dλ,  W(λ)=∫_λ^{λ_t} τ²
//!   σ̃  = σ_t √(1 − e^{−2W})
//!
//! **Noise prediction** (Prop. A.1, with the sign fixed — see the note in
//! `noise_param_sign`): x_t = (α_t/α_s) x_s + Σ_j b̃_j ε̂_j + σ̃' ξ with
//!
//!   b̃_j = −α_t ∫ e^{−λ} (1+τ²) l_j(λ) dλ
//!   σ̃'² = α_t² ∫ 2 e^{−2λ} τ²(λ) dλ
//!
//! For piecewise-constant τ the integrals are *exact*: each Lagrange basis
//! is expanded into monomials of u = λ − p₁ (piece end) and the integrals
//! reduce to the stable moments I_k(a,h) = ∫_{−h}^0 uᵏ e^{au} du
//! (`lagrange::exp_moments`). A Gauss–Legendre path covers general τ.

use crate::config::Prediction;
use crate::lagrange::{exp_moments, lagrange_basis_coeffs, poly_eval};
use crate::quad::GaussLegendre;
use crate::tau::TauFn;

/// Coefficients of one exponential-integrator step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepCoeffs {
    /// Multiplier on the carried state x_s.
    pub c0: f64,
    /// Multiplier per interpolation node, same order as the `nodes` input.
    pub b: Vec<f64>,
    /// Std-dev of the injected Gaussian noise.
    pub sigma_tilde: f64,
}

/// Scalar schedule values at the two endpoints of a step.
#[derive(Debug, Clone, Copy)]
pub struct StepEnds {
    pub lam_s: f64,
    pub lam_t: f64,
    pub alpha_s: f64,
    pub alpha_t: f64,
    pub sigma_s: f64,
    pub sigma_t: f64,
}

/// Quadrature nodes used by the general-τ path (cheap vs. any model eval).
const QUAD_POINTS: usize = 32;

/// Compute the step coefficients for interpolation nodes `nodes` (λ values
/// of the buffered model evaluations; may include λ_t itself for the
/// corrector) over the step `ends`, stochasticity `tau`, in the given
/// parameterization.
pub fn coefficients(
    nodes: &[f64],
    ends: &StepEnds,
    tau: &TauFn,
    pred: Prediction,
) -> StepCoeffs {
    assert!(!nodes.is_empty());
    assert!(ends.lam_t > ends.lam_s, "step must increase λ");
    let w_total = tau.int_tau2(ends.lam_s, ends.lam_t);
    match pred {
        Prediction::Data => {
            let c0 = ends.sigma_t / ends.sigma_s * (-w_total).exp();
            let sigma_tilde =
                ends.sigma_t * crate::util::one_minus_exp_neg(2.0 * w_total).max(0.0).sqrt();
            let b = match tau.const_pieces(ends.lam_s, ends.lam_t) {
                Some(pieces) => data_b_exact(nodes, ends, tau, &pieces),
                None => data_b_quadrature(nodes, ends, tau),
            };
            StepCoeffs { c0, b, sigma_tilde }
        }
        Prediction::Noise => {
            let c0 = ends.alpha_t / ends.alpha_s;
            let (b, var) = match tau.const_pieces(ends.lam_s, ends.lam_t) {
                Some(pieces) => noise_b_exact(nodes, ends, &pieces),
                None => noise_b_quadrature(nodes, ends, tau),
            };
            StepCoeffs { c0, b, sigma_tilde: var.max(0.0).sqrt() }
        }
    }
}

/// Exact data-prediction b's over piecewise-constant τ.
fn data_b_exact(
    nodes: &[f64],
    ends: &StepEnds,
    tau: &TauFn,
    pieces: &[(f64, f64, f64)],
) -> Vec<f64> {
    let s = nodes.len();
    let mut b = vec![0.0; s];
    for &(p0, p1, tp) in pieces {
        let hp = p1 - p0;
        if hp <= 0.0 {
            continue;
        }
        let a = 1.0 + tp * tp;
        // e^{−W(p1)} damping from the piece end to λ_t, times e^{p1−λ_t}.
        let damp = (-tau.int_tau2(p1, ends.lam_t)).exp() * (p1 - ends.lam_t).exp();
        let scale = ends.alpha_t * damp * a;
        let shifted: Vec<f64> = nodes.iter().map(|x| x - p1).collect();
        let cs = lagrange_basis_coeffs(&shifted);
        let ms = exp_moments(a, hp, s - 1);
        for j in 0..s {
            let contribution: f64 = cs[j].iter().zip(&ms).map(|(c, m)| c * m).sum();
            b[j] += scale * contribution;
        }
    }
    b
}

/// Quadrature data-prediction b's for general τ.
fn data_b_quadrature(nodes: &[f64], ends: &StepEnds, tau: &TauFn) -> Vec<f64> {
    let gl = GaussLegendre::new(QUAD_POINTS);
    let shifted: Vec<f64> = nodes.iter().map(|x| x - ends.lam_t).collect();
    let cs = lagrange_basis_coeffs(&shifted);
    cs.iter()
        .map(|cj| {
            ends.alpha_t
                * gl.integrate(ends.lam_s, ends.lam_t, |lam| {
                    let tv = tau.value(lam);
                    (-tau.int_tau2(lam, ends.lam_t)).exp()
                        * (lam - ends.lam_t).exp()
                        * (1.0 + tv * tv)
                        * poly_eval(cj, lam - ends.lam_t)
                })
        })
        .collect()
}

/// Exact noise-prediction (b̃, noise variance) over piecewise-constant τ.
fn noise_b_exact(nodes: &[f64], ends: &StepEnds, pieces: &[(f64, f64, f64)]) -> (Vec<f64>, f64) {
    let s = nodes.len();
    let mut b = vec![0.0; s];
    let mut var = 0.0;
    for &(p0, p1, tp) in pieces {
        let hp = p1 - p0;
        if hp <= 0.0 {
            continue;
        }
        let a2 = 1.0 + tp * tp;
        // α_t e^{−p1} = σ_t e^{λ_t − p1}; λ_t ≥ p1 keeps the factor ≥ 1 but
        // bounded by e^{h}, so no overflow for sane step sizes.
        let scale = -ends.sigma_t * (ends.lam_t - p1).exp() * a2;
        let shifted: Vec<f64> = nodes.iter().map(|x| x - p1).collect();
        let cs = lagrange_basis_coeffs(&shifted);
        // ∫_{p0}^{p1} e^{−λ} u^k dλ = e^{−p1} ∫_{−hp}^0 e^{−u} u^k du.
        let ms = exp_moments(-1.0, hp, s - 1);
        for j in 0..s {
            let contribution: f64 = cs[j].iter().zip(&ms).map(|(c, m)| c * m).sum();
            b[j] += scale * contribution;
        }
        // α_t² ∫ 2 e^{−2λ} τ² dλ = τ² σ_t² (e^{2(λ_t−p0)} − e^{2(λ_t−p1)}).
        var += tp
            * tp
            * ends.sigma_t
            * ends.sigma_t
            * ((2.0 * (ends.lam_t - p0)).exp() - (2.0 * (ends.lam_t - p1)).exp());
    }
    (b, var)
}

/// Quadrature noise-prediction path for general τ.
fn noise_b_quadrature(nodes: &[f64], ends: &StepEnds, tau: &TauFn) -> (Vec<f64>, f64) {
    let gl = GaussLegendre::new(QUAD_POINTS);
    let shifted: Vec<f64> = nodes.iter().map(|x| x - ends.lam_t).collect();
    let cs = lagrange_basis_coeffs(&shifted);
    let b = cs
        .iter()
        .map(|cj| {
            -ends.sigma_t
                * gl.integrate(ends.lam_s, ends.lam_t, |lam| {
                    let tv = tau.value(lam);
                    (ends.lam_t - lam).exp() * (1.0 + tv * tv) * poly_eval(cj, lam - ends.lam_t)
                })
        })
        .collect();
    let var = ends.sigma_t
        * ends.sigma_t
        * gl.integrate(ends.lam_s, ends.lam_t, |lam| {
            let tv = tau.value(lam);
            2.0 * (2.0 * (ends.lam_t - lam)).exp() * tv * tv
        });
    (b, var)
}

/// Documentation anchor for the Prop. A.1 sign convention (see module docs):
/// integrating d(x/α) = −(σ/α) (1+τ²) ε dλ gives the minus sign on b̃; the
/// paper's appendix drops it between Eq. (41) and Eq. (42). With the minus,
/// the 1-step τ=0 case reduces to DPM-Solver-1: b̃ = −σ_t (e^h − 1).
pub const fn noise_param_sign() -> f64 {
    -1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    fn ends_vp(lam_s: f64, lam_t: f64) -> StepEnds {
        // Consistent VP-style endpoints: α = sigmoid-ish from λ.
        let alpha = |l: f64| (1.0 / (1.0 + (-2.0 * l).exp())).sqrt();
        let sigma = |l: f64| (1.0 - alpha(l) * alpha(l)).sqrt();
        StepEnds {
            lam_s,
            lam_t,
            alpha_s: alpha(lam_s),
            alpha_t: alpha(lam_t),
            sigma_s: sigma(lam_s),
            sigma_t: sigma(lam_t),
        }
    }

    #[test]
    fn one_step_data_matches_closed_form() {
        // s = 1 (l ≡ 1): b = α_t (1 − e^{−(1+τ²)h}) — the Corollary 5.3 form.
        let ends = ends_vp(-1.0, -0.3);
        let h = ends.lam_t - ends.lam_s;
        for tau_v in [0.0, 0.7, 1.4] {
            let tau = TauFn::Constant(tau_v);
            let c = coefficients(&[ends.lam_s], &ends, &tau, Prediction::Data);
            let a = 1.0 + tau_v * tau_v;
            let want_b = ends.alpha_t * (1.0 - (-a * h).exp());
            assert!(close(c.b[0], want_b, 1e-12, 0.0), "τ={tau_v}: {} vs {want_b}", c.b[0]);
            let want_c0 = ends.sigma_t / ends.sigma_s * (-tau_v * tau_v * h).exp();
            assert!(close(c.c0, want_c0, 1e-12, 0.0));
            let want_sig = ends.sigma_t * (1.0 - (-2.0 * tau_v * tau_v * h).exp()).sqrt();
            assert!(close(c.sigma_tilde, want_sig, 1e-12, 1e-15));
        }
    }

    #[test]
    fn one_step_noise_matches_dpm_solver1() {
        // τ=0, s=1: b̃ = −σ_t (e^h − 1), c0 = α_t/α_s, σ̃ = 0.
        let ends = ends_vp(-1.2, -0.4);
        let h = ends.lam_t - ends.lam_s;
        let c = coefficients(&[ends.lam_s], &ends, &TauFn::Constant(0.0), Prediction::Noise);
        assert!(close(c.b[0], -ends.sigma_t * (h.exp() - 1.0), 1e-12, 0.0));
        assert!(close(c.c0, ends.alpha_t / ends.alpha_s, 1e-14, 0.0));
        assert!(c.sigma_tilde.abs() < 1e-14);
    }

    #[test]
    fn exact_matches_quadrature_data() {
        // Force the quadrature path by comparing against hand-driven
        // quadrature on the same constant τ.
        let ends = ends_vp(-2.0, -1.1);
        for tau_v in [0.0, 0.9] {
            let tau = TauFn::Constant(tau_v);
            let nodes = [ends.lam_s, ends.lam_s - 0.5, ends.lam_s - 1.1];
            let exact = coefficients(&nodes, &ends, &tau, Prediction::Data);
            let quad_b = data_b_quadrature(&nodes, &ends, &tau);
            for (e, q) in exact.b.iter().zip(&quad_b) {
                assert!(close(*e, *q, 1e-9, 1e-12), "τ={tau_v}: {e} vs {q}");
            }
        }
    }

    #[test]
    fn exact_matches_quadrature_noise() {
        let ends = ends_vp(-2.0, -1.3);
        let tau = TauFn::Constant(1.2);
        let nodes = [ends.lam_s, ends.lam_s - 0.7];
        let exact = coefficients(&nodes, &ends, &tau, Prediction::Noise);
        let (quad_b, quad_var) = noise_b_quadrature(&nodes, &ends, &tau);
        for (e, q) in exact.b.iter().zip(&quad_b) {
            assert!(close(*e, *q, 1e-9, 1e-12), "{e} vs {q}");
        }
        assert!(close(exact.sigma_tilde, quad_var.sqrt(), 1e-9, 1e-12));
    }

    #[test]
    fn interval_tau_pieces_consistent() {
        // A band boundary inside the step must agree with quadrature.
        let ends = ends_vp(-0.5, 1.5);
        let tau = TauFn::interval_from_sigma(1.0, 0.05, 1.0); // active λ ∈ [0, ln 20]
        let nodes = [ends.lam_s, ends.lam_s - 0.8];
        let exact = coefficients(&nodes, &ends, &tau, Prediction::Data);
        // Compare against fine piece-split quadrature.
        let gl = GaussLegendre::new(64);
        let shifted: Vec<f64> = nodes.iter().map(|x| x - ends.lam_t).collect();
        let cs = lagrange_basis_coeffs(&shifted);
        for j in 0..nodes.len() {
            let f = |lam: f64| {
                let tv = tau.value(lam);
                (-tau.int_tau2(lam, ends.lam_t)).exp()
                    * (lam - ends.lam_t).exp()
                    * (1.0 + tv * tv)
                    * poly_eval(&cs[j], lam - ends.lam_t)
            };
            // Split at the band boundary λ=0 for quadrature accuracy.
            let pieces = gl.integrate(ends.lam_s, 0.0, f) + gl.integrate(0.0, ends.lam_t, f);
            let want = ends.alpha_t * pieces;
            assert!(
                close(exact.b[j], want, 1e-8, 1e-10),
                "j={j}: {} vs {want}",
                exact.b[j]
            );
        }
    }

    #[test]
    fn partition_of_unity_limit() {
        // Σ_j b_j must equal the s=1 coefficient (interpolating the constant
        // function 1 reproduces the total mass) — any node set.
        let ends = ends_vp(-1.5, -0.6);
        let tau = TauFn::Constant(0.8);
        let one = coefficients(&[ends.lam_s], &ends, &tau, Prediction::Data);
        for nodes in [
            vec![ends.lam_s, ends.lam_s - 0.4],
            vec![ends.lam_s, ends.lam_s - 0.4, ends.lam_s - 0.9],
            vec![ends.lam_t, ends.lam_s, ends.lam_s - 0.4], // corrector-style
        ] {
            let c = coefficients(&nodes, &ends, &tau, Prediction::Data);
            let total: f64 = c.b.iter().sum();
            assert!(
                close(total, one.b[0], 1e-10, 1e-13),
                "nodes={nodes:?}: Σb={total} vs {}",
                one.b[0]
            );
        }
    }

    #[test]
    fn noise_variance_dominates_data_variance() {
        // Corollary A.2: noise-param injected variance ≥ data-param variance.
        let ends = ends_vp(-1.0, 0.2);
        for tau_v in [0.3, 1.0, 1.6] {
            let tau = TauFn::Constant(tau_v);
            let d = coefficients(&[ends.lam_s], &ends, &tau, Prediction::Data);
            let n = coefficients(&[ends.lam_s], &ends, &tau, Prediction::Noise);
            assert!(
                n.sigma_tilde >= d.sigma_tilde - 1e-12,
                "τ={tau_v}: noise {} < data {}",
                n.sigma_tilde,
                d.sigma_tilde
            );
        }
    }

    #[test]
    fn appendix_d_2step_expansion() {
        // Appendix D: for the 2-step predictor with constant τ,
        // b_i + b_{i-1} = α_{t+1}(1 − e^{−(1+τ²)h}) and b_{i-1} ≈
        // α_{t+1}/(λ_i−λ_{i-1}) · ½(1+τ²)h² + O(h³).
        let h = 0.05;
        let ends = ends_vp(-1.0, -1.0 + h);
        let prev_gap: f64 = 0.04;
        let tau_v: f64 = 0.8;
        let tau = TauFn::Constant(tau_v);
        let nodes = [ends.lam_s, ends.lam_s - prev_gap];
        let c = coefficients(&nodes, &ends, &tau, Prediction::Data);
        let a = 1.0 + tau_v * tau_v;
        let sum_want = ends.alpha_t * (1.0 - (-a * h).exp());
        assert!(close(c.b[0] + c.b[1], sum_want, 1e-12, 0.0));
        let b1_leading = ends.alpha_t / prev_gap * 0.5 * a * h * h;
        // b_{i-1} is negative (extrapolation) with magnitude ≈ leading term.
        assert!(
            close(-c.b[1], b1_leading, 0.05, 1e-9),
            "-b1={} leading={b1_leading}",
            -c.b[1]
        );
    }
}
