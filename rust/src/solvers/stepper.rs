//! The incremental `Stepper` core: every solver in the zoo exposed as a
//! per-step recurrence instead of a monolithic grid loop.
//!
//! SA-Solver's Algorithm 1 — and the predictor/corrector family generally —
//! is a recurrence over a small history buffer: `init` performs the warm-up
//! evaluation (if the scheme has one), `step(i)` advances the state from
//! grid point `i` to `i + 1`, and `finish` runs any trailing work. Holding
//! that state in a struct instead of on a call stack is what turns a solve
//! into a *schedulable primitive*: the coordinator can interleave steps of
//! several in-flight batches, admit new requests at step boundaries, drop a
//! cancelled request's lanes mid-run, and report per-step progress — the
//! same structural move that unlocked continuous batching for LLM serving.
//!
//! Contract (asserted for every [`SolverKind`] in the equivalence suite):
//! driving a stepper one step at a time is bit-identical to the monolithic
//! seed-era `solve()` loop ([`crate::solvers::run_reference`]), for any
//! split of the step sequence across separate driving loops, and all
//! per-lane state is keyed by the lane's noise stream so lanes can be
//! removed at a step boundary without perturbing the survivors.

use crate::config::{SamplerConfig, SolverKind};
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::schedule::NoiseSchedule;
use crate::solvers::snapshot::StepperState;
use crate::solvers::{ddim, ddpm, dpm, edm, euler, sa, unipc, Grid};
use crate::util::error::{Error, Result};

/// One solver as an incremental per-step recurrence. All methods take the
/// state `x` (row-major `n × dim`, evolved in place) plus the shared grid;
/// the stepper owns only its history/buffer state between calls.
pub trait Stepper: Send {
    /// Warm-up before the first step (multistep schemes evaluate the model
    /// at grid point 0 here). Must be called exactly once, before `step`.
    fn init(
        &mut self,
        _model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        _n: usize,
        _noise: &mut dyn NormalSource,
    ) {
    }

    /// Advance `x` from grid point `i` to `i + 1`.
    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    );

    /// Drop lanes at a step boundary: keep lane `l` iff `keep[l]`. Called
    /// by the scheduler when a co-batched request is cancelled; per-lane
    /// history rows for surviving lanes must be preserved bitwise (the
    /// caller remaps the noise source so surviving lanes keep their global
    /// streams).
    fn retain_lanes(&mut self, _keep: &[bool], _dim: usize) {}

    /// Trailing work after the last step. No solver in the zoo needs one
    /// today; part of the API so a scheme with a final transform can add it
    /// without changing the driver.
    fn finish(&mut self, _x: &mut [f64]) {}

    /// Serialize the between-step state at a step boundary. The default is
    /// the empty state — correct for every memoryless scheme whose scratch
    /// buffers are fully rewritten each step (DDIM, DDPM, Euler–Maruyama,
    /// DPM-Solver-2, Heun, EDM-SDE). History-buffer solvers (SA, UniPC,
    /// DPM-Solver++(2M)) override both this and [`Stepper::restore`].
    ///
    /// Contract (asserted in `integration_snapshot` for every
    /// [`SolverKind`]): `restore(snapshot())` on a fresh stepper from the
    /// same config resumes bit-identically to the uninterrupted run, at any
    /// boundary, across serialize/deserialize round-trips, and under a
    /// different lane-shard layout (states merge/split by lane rows).
    fn snapshot(&self, lanes: usize, dim: usize) -> StepperState {
        StepperState::stateless(lanes, dim)
    }

    /// Restore a state captured by [`Stepper::snapshot`] into a freshly
    /// constructed stepper (replaces `init`; call before the next `step`).
    fn restore(&mut self, state: &StepperState, _dim: usize) -> Result<()> {
        if !state.mats.is_empty() {
            return Err(Error::config(
                "this stepper is memoryless but the snapshot carries per-lane state \
                 (solver/config mismatch?)",
            ));
        }
        Ok(())
    }
}

/// Build the stepper for a config. `sch` is captured by value (it is
/// `Copy`) by the schemes that evaluate the schedule off-grid.
pub fn make_stepper(cfg: &SamplerConfig, sch: &NoiseSchedule) -> Box<dyn Stepper> {
    match cfg.solver {
        SolverKind::Sa => Box::new(sa::SaStepper::new(sa::SaSolverOpts::from_config(cfg))),
        SolverKind::Ddim => Box::new(ddim::DdimStepper::new(cfg.eta)),
        SolverKind::Ddpm => Box::new(ddpm::DdpmStepper::new()),
        SolverKind::EulerMaruyama => Box::new(euler::EulerStepper::new(*sch, cfg.tau)),
        SolverKind::DpmSolver2 => Box::new(dpm::Dpm2Stepper::new(*sch)),
        SolverKind::DpmSolverPp2m => Box::new(dpm::Pp2mStepper::new()),
        SolverKind::UniPc => {
            Box::new(unipc::UniPcStepper::new(cfg.predictor_steps, cfg.corrector_steps))
        }
        SolverKind::Heun => Box::new(edm::HeunStepper::new()),
        SolverKind::EdmSde => Box::new(edm::EdmSdeStepper::new(edm::ChurnParams {
            churn: cfg.churn,
            s_noise: cfg.s_noise,
            s_tmin: cfg.s_tmin,
            s_tmax: cfg.s_tmax,
        })),
    }
}

/// Drive a stepper over the whole grid: `init`, every `step`, `finish`.
/// This is the thin generic loop [`crate::solvers::run_with_noise`] is
/// built on; schedulers inline it so they can interleave work between
/// steps.
pub fn drive(
    stepper: &mut dyn Stepper,
    model: &dyn ModelEval,
    grid: &Grid,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    stepper.init(model, grid, x, n, noise);
    for i in 0..grid.m() {
        stepper.step(model, grid, i, x, n, noise);
    }
    stepper.finish(x);
}

/// Compact a row-major `n × dim` buffer in place, keeping row `l` iff
/// `keep[l]`. Shared by every stepper's `retain_lanes`.
pub fn retain_rows(v: &mut Vec<f64>, keep: &[bool], dim: usize) {
    debug_assert_eq!(v.len(), keep.len() * dim, "row buffer / keep mask mismatch");
    let mut w = 0usize;
    for (l, &k) in keep.iter().enumerate() {
        if k {
            if w != l {
                v.copy_within(l * dim..(l + 1) * dim, w * dim);
            }
            w += 1;
        }
    }
    v.truncate(w * dim);
}

/// Grow-or-shrink a scratch buffer to `len` (contents are overwritten by
/// the next step; only the length matters after a lane-count change).
pub(crate) fn ensure_len(v: &mut Vec<f64>, len: usize) {
    v.resize(len, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::GmmAnalytic;
    use crate::rng::normal::PhiloxNormal;
    use crate::schedule::timesteps;
    use crate::solvers::{prior_sample, run_reference};

    #[test]
    fn retain_rows_compacts() {
        let mut v = vec![0.0, 0.1, 1.0, 1.1, 2.0, 2.1, 3.0, 3.1];
        retain_rows(&mut v, &[true, false, false, true], 2);
        assert_eq!(v, vec![0.0, 0.1, 3.0, 3.1]);
        let mut all = vec![1.0, 2.0];
        retain_rows(&mut all, &[true], 2);
        assert_eq!(all, vec![1.0, 2.0]);
        let mut none = vec![1.0, 2.0];
        retain_rows(&mut none, &[false], 2);
        assert!(none.is_empty());
    }

    #[test]
    fn driven_stepper_matches_reference_for_every_solver() {
        // The core contract at unit scope (the integration suite covers
        // splits and threads): drive() == the monolithic seed-era loop,
        // bitwise, for all nine solvers.
        let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 3));
        let sch = NoiseSchedule::vp_linear();
        for kind in SolverKind::all() {
            let mut cfg = SamplerConfig::for_solver(*kind);
            cfg.nfe = 12;
            let reference = run_reference(&model, &sch, &cfg, 5, 42);

            let m = cfg.steps_for_nfe();
            let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
            let mut noise = PhiloxNormal::new(42);
            let mut x = prior_sample(&grid, model.gmm.dim, 5, &mut noise);
            let mut stepper = make_stepper(&cfg, &sch);
            drive(&mut *stepper, &model, &grid, &mut x, 5, &mut noise);
            assert_eq!(x, reference.samples, "{kind:?}: stepper diverged from reference");
        }
    }
}
