//! The incremental `Stepper` core: every solver in the zoo exposed as a
//! per-step recurrence instead of a monolithic grid loop.
//!
//! SA-Solver's Algorithm 1 — and the predictor/corrector family generally —
//! is a recurrence over a small history buffer: `init` performs the warm-up
//! evaluation (if the scheme has one), `step(i)` advances the state from
//! grid point `i` to `i + 1`, and `finish` runs any trailing work. Holding
//! that state in a struct instead of on a call stack is what turns a solve
//! into a *schedulable primitive*: the coordinator can interleave steps of
//! several in-flight batches, admit new requests at step boundaries, drop a
//! cancelled request's lanes mid-run, and report per-step progress — the
//! same structural move that unlocked continuous batching for LLM serving.
//!
//! The step path is **allocation-free after `init`**: steppers hold their
//! temporaries in a [`crate::linalg::Scratch`] arena and their model-eval
//! history in a [`HistoryRing`] (one contiguous arena addressed by slot
//! offsets), both sized once at `init`; per-step coefficients are
//! precomputed from the grid at `init`/`restore`. A counting-allocator
//! test asserts zero heap allocations per [`Stepper::step`] call for
//! every [`SolverKind`].
//!
//! Every per-step update runs through the fused [`crate::linalg`]
//! kernels, which transparently dispatch to the widest **kernel tier**
//! the host supports (scalar reference / portable wide / AVX2 — see
//! docs/KERNELS.md). All tiers are bit-identical for these kernels, so
//! the contract below is tier-independent; [`make_stepper`] resolves the
//! dispatch eagerly so its one-time environment probe never lands inside
//! the zero-allocation step loop.
//!
//! Contract (asserted for every [`SolverKind`] in the equivalence suite):
//! driving a stepper one step at a time is bit-identical to the monolithic
//! seed-era `solve()` loop ([`crate::solvers::run_reference`]), for any
//! split of the step sequence across separate driving loops, and all
//! per-lane state is keyed by the lane's noise stream so lanes can be
//! removed at a step boundary without perturbing the survivors.

use crate::config::{SamplerConfig, SolverKind};
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::schedule::NoiseSchedule;
use crate::solvers::snapshot::StepperState;
use crate::solvers::{ddim, ddpm, dpm, edm, euler, sa, unipc, Grid};
use crate::util::error::{Error, Result};
use std::collections::VecDeque;

/// One solver as an incremental per-step recurrence. All methods take the
/// state `x` (row-major `n × dim`, evolved in place) plus the shared grid;
/// the stepper owns only its history/buffer state between calls.
///
/// The full `init` / `step` × M / `finish` round-trip (what
/// [`drive`] does):
///
/// ```
/// use sadiff::config::SamplerConfig;
/// use sadiff::gmm::Gmm;
/// use sadiff::models::{GmmAnalytic, ModelEval};
/// use sadiff::rng::normal::PhiloxNormal;
/// use sadiff::schedule::{timesteps, NoiseSchedule};
/// use sadiff::solvers::stepper::{make_stepper, Stepper};
/// use sadiff::solvers::{prior_sample, Grid};
///
/// let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 3));
/// let sch = NoiseSchedule::vp_linear();
/// let cfg = SamplerConfig { nfe: 8, ..SamplerConfig::sa_default() };
/// let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, cfg.steps_for_nfe()));
/// let n = 2;
/// let mut noise = PhiloxNormal::new(7);
/// let mut x = prior_sample(&grid, model.dim(), n, &mut noise);
///
/// let mut st = make_stepper(&cfg, &sch);
/// st.init(&model, &grid, &mut x, n, &mut noise);
/// for i in 0..grid.m() {
///     st.step(&model, &grid, i, &mut x, n, &mut noise); // a step boundary
/// }
/// st.finish(&mut x);
/// assert!(x.iter().all(|v| v.is_finite()));
/// ```
pub trait Stepper: Send {
    /// Warm-up before the first step: multistep schemes evaluate the model
    /// at grid point 0 here, and every scheme sizes its scratch arena /
    /// history ring and precomputes its per-step coefficients from the
    /// grid. Must be called exactly once, before `step` (unless the
    /// stepper is rebuilt through [`Stepper::restore`] instead).
    fn init(
        &mut self,
        _model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        _n: usize,
        _noise: &mut dyn NormalSource,
    ) {
    }

    /// Advance `x` from grid point `i` to `i + 1`. Performs no heap
    /// allocation (asserted by the counting-allocator test).
    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    );

    /// Drop lanes at a step boundary: keep lane `l` iff `keep[l]`. Called
    /// by the scheduler when a co-batched request is cancelled; per-lane
    /// history rows for surviving lanes must be preserved bitwise (the
    /// caller remaps the noise source so surviving lanes keep their global
    /// streams).
    fn retain_lanes(&mut self, _keep: &[bool], _dim: usize) {}

    /// Trailing work after the last step. No solver in the zoo needs one
    /// today; part of the API so a scheme with a final transform can add it
    /// without changing the driver.
    fn finish(&mut self, _x: &mut [f64]) {}

    /// Serialize the between-step state at a step boundary. The default is
    /// the empty state — correct for every memoryless scheme whose scratch
    /// buffers are fully rewritten each step (DDIM, DDPM, Euler–Maruyama,
    /// DPM-Solver-2, Heun, EDM-SDE). History-buffer solvers (SA, UniPC,
    /// DPM-Solver++(2M)) override both this and [`Stepper::restore`].
    ///
    /// Contract (asserted in `integration_snapshot` for every
    /// [`SolverKind`]): `restore(snapshot())` on a fresh stepper from the
    /// same config resumes bit-identically to the uninterrupted run, at any
    /// boundary, across serialize/deserialize round-trips, and under a
    /// different lane-shard layout (states merge/split by lane rows).
    fn snapshot(&self, lanes: usize, dim: usize) -> StepperState {
        StepperState::stateless(lanes, dim)
    }

    /// Restore a state captured by [`Stepper::snapshot`] into a freshly
    /// constructed stepper (replaces `init`; call before the next `step`).
    /// The grid is the one the resumed solve runs on — identical to the
    /// snapshotting process's grid because it is derived from the same
    /// config — and is what lets history-buffer steppers rebuild their
    /// precomputed per-step coefficient tables.
    fn restore(&mut self, state: &StepperState, _grid: &Grid, _dim: usize) -> Result<()> {
        if !state.mats.is_empty() {
            return Err(Error::config(
                "this stepper is memoryless but the snapshot carries per-lane state \
                 (solver/config mismatch?)",
            ));
        }
        Ok(())
    }
}

/// Build the stepper for a config. `sch` is captured by value (it is
/// `Copy`) by the schemes that evaluate the schedule off-grid.
pub fn make_stepper(cfg: &SamplerConfig, sch: &NoiseSchedule) -> Box<dyn Stepper> {
    // Resolve the kernel-tier dispatch now: its first call reads the
    // environment (which may allocate), and every construction path goes
    // through here — so by the time `step` runs, the per-step kernels hit
    // a cached, allocation-free lookup (integration_alloc asserts this).
    crate::linalg::simd::dispatch();
    match cfg.solver {
        SolverKind::Sa => Box::new(sa::SaStepper::new(sa::SaSolverOpts::from_config(cfg))),
        SolverKind::Ddim => Box::new(ddim::DdimStepper::new(cfg.eta)),
        SolverKind::Ddpm => Box::new(ddpm::DdpmStepper::new()),
        SolverKind::EulerMaruyama => Box::new(euler::EulerStepper::new(*sch, cfg.tau)),
        SolverKind::DpmSolver2 => Box::new(dpm::Dpm2Stepper::new(*sch)),
        SolverKind::DpmSolverPp2m => Box::new(dpm::Pp2mStepper::new()),
        SolverKind::UniPc => {
            Box::new(unipc::UniPcStepper::new(cfg.predictor_steps, cfg.corrector_steps))
        }
        SolverKind::Heun => Box::new(edm::HeunStepper::new()),
        SolverKind::EdmSde => Box::new(edm::EdmSdeStepper::new(edm::ChurnParams {
            churn: cfg.churn,
            s_noise: cfg.s_noise,
            s_tmin: cfg.s_tmin,
            s_tmax: cfg.s_tmax,
        })),
    }
}

/// Drive a stepper over the whole grid: `init`, every `step`, `finish`.
/// This is the thin generic loop [`crate::solvers::run_with_noise`] is
/// built on; schedulers inline it so they can interleave work between
/// steps.
pub fn drive(
    stepper: &mut dyn Stepper,
    model: &dyn ModelEval,
    grid: &Grid,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    stepper.init(model, grid, x, n, noise);
    for i in 0..grid.m() {
        stepper.step(model, grid, i, x, n, noise);
    }
    stepper.finish(x);
}

/// Compact a row-major `n × dim` buffer in place, keeping row `l` iff
/// `keep[l]`. Shared by every stepper's `retain_lanes`.
pub fn retain_rows(v: &mut Vec<f64>, keep: &[bool], dim: usize) {
    debug_assert_eq!(v.len(), keep.len() * dim, "row buffer / keep mask mismatch");
    let mut w = 0usize;
    for (l, &k) in keep.iter().enumerate() {
        if k {
            if w != l {
                v.copy_within(l * dim..(l + 1) * dim, w * dim);
            }
            w += 1;
        }
    }
    v.truncate(w * dim);
}

/// The model-evaluation history of a multistep scheme as one contiguous
/// arena: `keep + 1` equally-sized slots — up to `keep` committed history
/// entries plus one *free* slot the next evaluation writes into — so
/// committing a new entry is a slot-index rotation, never a copy or an
/// allocation, and the fused combination kernels
/// ([`crate::linalg::lincomb_into`]) address entries by element offset
/// into [`HistoryRing::data`].
///
/// Entries are ordered newest-first, exactly like the `VecDeque` of the
/// seed-era loops, and carry the grid index they were evaluated at.
#[derive(Debug)]
pub struct HistoryRing {
    buf: Vec<f64>,
    chunk: usize,
    keep: usize,
    /// (grid index, slot) per committed entry, newest first.
    ring: VecDeque<(usize, usize)>,
    /// Slot the next evaluation writes into (never in `ring`).
    free: usize,
}

impl HistoryRing {
    /// An empty ring holding up to `keep ≥ 1` entries of `chunk` elements.
    pub fn new(keep: usize, chunk: usize) -> HistoryRing {
        debug_assert!(keep >= 1);
        HistoryRing {
            buf: vec![0.0; (keep + 1) * chunk],
            chunk,
            keep,
            ring: VecDeque::with_capacity(keep + 1),
            free: 0,
        }
    }

    /// Committed entry count (≤ `keep`).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// True before the first [`HistoryRing::commit`].
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// The whole arena, for offset-addressed kernels.
    pub fn data(&self) -> &[f64] {
        &self.buf
    }

    /// The free slot, mutably — the target of the next model evaluation.
    pub fn free_mut(&mut self) -> &mut [f64] {
        let c = self.chunk;
        &mut self.buf[self.free * c..(self.free + 1) * c]
    }

    /// Element offset of the free slot in [`HistoryRing::data`].
    pub fn free_offset(&self) -> usize {
        self.free * self.chunk
    }

    /// Element offsets of the committed entries, newest first.
    pub fn offsets(&self) -> impl Iterator<Item = usize> + '_ {
        let c = self.chunk;
        self.ring.iter().map(move |&(_, slot)| slot * c)
    }

    /// Grid indices of the committed entries, newest first.
    pub fn indices(&self) -> impl Iterator<Item = usize> + '_ {
        self.ring.iter().map(|&(idx, _)| idx)
    }

    /// The `j`-th newest committed entry.
    pub fn entry(&self, j: usize) -> &[f64] {
        let (_, slot) = self.ring[j];
        &self.buf[slot * self.chunk..(slot + 1) * self.chunk]
    }

    /// Commit the free slot as the newest entry, evaluated at grid index
    /// `idx`; if the ring already held `keep` entries, the oldest is
    /// evicted and its slot becomes the new free slot. Allocation-free.
    pub fn commit(&mut self, idx: usize) {
        self.ring.push_front((idx, self.free));
        if self.ring.len() > self.keep {
            let (_, old) = self.ring.pop_back().expect("ring is non-empty after push");
            self.free = old;
        } else {
            // Slots 0..ring.len() are in use; the next virgin slot is free
            // (the arena holds keep + 1 slots, so this index is in bounds).
            self.free = self.ring.len();
        }
    }

    /// Restore-path append: add `data` as the entry *behind* all current
    /// ones (snapshots list entries newest-first, so restoring them in
    /// order rebuilds the exact ring). Panics if `data` is not slot-sized
    /// or the ring is full.
    pub fn restore_entry(&mut self, idx: usize, data: &[f64]) {
        assert!(self.ring.len() < self.keep, "history ring overflow on restore");
        assert_eq!(data.len(), self.chunk, "history entry size mismatch on restore");
        let slot = self.ring.len();
        self.buf[slot * self.chunk..(slot + 1) * self.chunk].copy_from_slice(data);
        self.ring.push_back((idx, slot));
        self.free = self.ring.len().min(self.keep);
    }

    /// Compact every slot (committed and free) to the surviving lanes:
    /// keep row `l` iff `keep_mask[l]`, preserving surviving rows bitwise.
    /// The slot size shrinks to `survivors × dim`.
    pub fn retain_lanes(&mut self, keep_mask: &[bool], dim: usize) {
        let old_chunk = self.chunk;
        debug_assert_eq!(old_chunk, keep_mask.len() * dim, "ring chunk / keep mask mismatch");
        let survivors = keep_mask.iter().filter(|k| **k).count();
        let new_chunk = survivors * dim;
        if new_chunk == old_chunk {
            return;
        }
        let slots = self.keep + 1;
        let mut w = 0usize;
        for s in 0..slots {
            let base = s * old_chunk;
            for (l, &k) in keep_mask.iter().enumerate() {
                if k {
                    self.buf.copy_within(base + l * dim..base + (l + 1) * dim, w);
                    w += dim;
                }
            }
        }
        self.buf.truncate(slots * new_chunk);
        self.chunk = new_chunk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::GmmAnalytic;
    use crate::rng::normal::PhiloxNormal;
    use crate::schedule::timesteps;
    use crate::solvers::{prior_sample, run_reference};

    #[test]
    fn retain_rows_compacts() {
        let mut v = vec![0.0, 0.1, 1.0, 1.1, 2.0, 2.1, 3.0, 3.1];
        retain_rows(&mut v, &[true, false, false, true], 2);
        assert_eq!(v, vec![0.0, 0.1, 3.0, 3.1]);
        let mut all = vec![1.0, 2.0];
        retain_rows(&mut all, &[true], 2);
        assert_eq!(all, vec![1.0, 2.0]);
        let mut none = vec![1.0, 2.0];
        retain_rows(&mut none, &[false], 2);
        assert!(none.is_empty());
    }

    #[test]
    fn history_ring_rotates_like_a_deque() {
        let mut ring = HistoryRing::new(2, 2);
        ring.free_mut().copy_from_slice(&[0.0, 0.5]);
        ring.commit(0);
        assert_eq!(ring.len(), 1);
        ring.free_mut().copy_from_slice(&[1.0, 1.5]);
        ring.commit(1);
        ring.free_mut().copy_from_slice(&[2.0, 2.5]);
        ring.commit(2);
        // Newest first, capped at keep = 2.
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.indices().collect::<Vec<_>>(), vec![2, 1]);
        assert_eq!(ring.entry(0), &[2.0, 2.5]);
        assert_eq!(ring.entry(1), &[1.0, 1.5]);
        // Offsets address the same entries through the arena.
        let offs: Vec<usize> = ring.offsets().collect();
        assert_eq!(&ring.data()[offs[0]..offs[0] + 2], &[2.0, 2.5]);
        // The evicted entry's slot was recycled as the free slot.
        assert_eq!(ring.free_offset() % 2, 0);
        assert!(ring.free_offset() / 2 <= 2);
    }

    #[test]
    fn history_ring_restore_rebuilds_order() {
        let mut a = HistoryRing::new(3, 2);
        for i in 0..3 {
            let v = i as f64;
            a.free_mut().copy_from_slice(&[v, v + 0.5]);
            a.commit(i);
        }
        let entries: Vec<(usize, Vec<f64>)> =
            (0..a.len()).map(|j| (a.indices().nth(j).unwrap(), a.entry(j).to_vec())).collect();
        let mut b = HistoryRing::new(3, 2);
        for (idx, data) in &entries {
            b.restore_entry(*idx, data);
        }
        assert_eq!(a.indices().collect::<Vec<_>>(), b.indices().collect::<Vec<_>>());
        for j in 0..a.len() {
            assert_eq!(a.entry(j), b.entry(j), "entry {j}");
        }
        // The restored ring keeps committing correctly.
        b.free_mut().fill(9.0);
        b.commit(3);
        assert_eq!(b.indices().next(), Some(3));
        assert_eq!(b.entry(0), &[9.0, 9.0]);
    }

    #[test]
    fn history_ring_retain_lanes_compacts_every_slot() {
        // chunk = 3 lanes × dim 2; drop the middle lane and check every
        // committed entry keeps its surviving rows bitwise.
        let mut ring = HistoryRing::new(2, 6);
        ring.free_mut().copy_from_slice(&[0.0, 0.1, 1.0, 1.1, 2.0, 2.1]);
        ring.commit(0);
        ring.free_mut().copy_from_slice(&[10.0, 10.1, 11.0, 11.1, 12.0, 12.1]);
        ring.commit(1);
        ring.retain_lanes(&[true, false, true], 2);
        assert_eq!(ring.entry(0), &[10.0, 10.1, 12.0, 12.1]);
        assert_eq!(ring.entry(1), &[0.0, 0.1, 2.0, 2.1]);
        // The ring still rotates correctly at the new width.
        ring.free_mut().copy_from_slice(&[20.0, 20.1, 22.0, 22.1]);
        ring.commit(2);
        assert_eq!(ring.entry(0), &[20.0, 20.1, 22.0, 22.1]);
        assert_eq!(ring.entry(1), &[10.0, 10.1, 12.0, 12.1]);
    }

    #[test]
    fn driven_stepper_matches_reference_for_every_solver() {
        // The core contract at unit scope (the integration suite covers
        // splits and threads): drive() == the monolithic seed-era loop,
        // bitwise, for all nine solvers.
        let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 3));
        let sch = NoiseSchedule::vp_linear();
        for kind in SolverKind::all() {
            let mut cfg = SamplerConfig::for_solver(*kind);
            cfg.nfe = 12;
            let reference = run_reference(&model, &sch, &cfg, 5, 42);

            let m = cfg.steps_for_nfe();
            let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
            let mut noise = PhiloxNormal::new(42);
            let mut x = prior_sample(&grid, model.gmm.dim, 5, &mut noise);
            let mut stepper = make_stepper(&cfg, &sch);
            drive(&mut *stepper, &model, &grid, &mut x, 5, &mut noise);
            assert_eq!(x, reference.samples, "{kind:?}: stepper diverged from reference");
        }
    }
}
