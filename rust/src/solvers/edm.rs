//! EDM samplers (Karras et al. 2022), generalized off the EDM schedule by
//! working in the scaled space x̄ = x/α with σ^{EDM} = σ/α = e^{−λ}:
//!
//! * `solve_heun` — deterministic 2nd-order Heun on dx̄/dσ = (x̄ − x₀̂)/σ,
//!   trailing step plain Euler (2 NFE/step except the last).
//! * `solve_sde` — the stochastic "churn" sampler: per step, σ is inflated
//!   by γ with fresh noise before the Heun step. This is the paper's
//!   EDM(SDE) baseline; its 4 hyperparameters are exposed for the small
//!   grid search mirrored from the paper's protocol (§E.2).

use crate::linalg::Scratch;
use crate::models::{EvalCtx, ModelEval};
use crate::rng::normal::NormalSource;
use crate::solvers::stepper::Stepper;
use crate::solvers::{step_noise, Grid};

/// EDM stochastic-sampler hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct ChurnParams {
    pub churn: f64,
    pub s_noise: f64,
    pub s_tmin: f64,
    pub s_tmax: f64,
}

/// Deterministic Heun.
///
/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`HeunStepper`]).
pub fn solve_heun(model: &dyn ModelEval, grid: &Grid, x: &mut [f64], n: usize) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0 = vec![0.0; n * dim];
    let mut x0b = vec![0.0; n * dim];
    let mut xb = vec![0.0; n * dim]; // scaled-space trial point
    for i in 0..m {
        let (sig_i, sig_j) = (edm_sigma(grid, i), edm_sigma(grid, i + 1));
        let (a_i, a_j) = (grid.alphas[i], grid.alphas[i + 1]);
        let dsig = sig_j - sig_i;
        model.eval_batch(x, &grid.ctx(i), &mut x0);
        if i + 1 == m || sig_j == 0.0 {
            // Trailing Euler step.
            for k in 0..n * dim {
                let xbar = x[k] / a_i;
                let d = (xbar - x0[k]) / sig_i;
                x[k] = a_j * (xbar + dsig * d);
            }
        } else {
            for k in 0..n * dim {
                let xbar = x[k] / a_i;
                let d = (xbar - x0[k]) / sig_i;
                xb[k] = xbar + dsig * d;
            }
            // Evaluate at the trial point (unscaled: x = α_j x̄).
            let mut trial = vec![0.0; n * dim];
            for k in 0..n * dim {
                trial[k] = a_j * xb[k];
            }
            let ctx_j = EvalCtx { t: grid.ts[i + 1], alpha: a_j, sigma: grid.sigmas[i + 1] };
            model.eval_batch(&trial, &ctx_j, &mut x0b);
            for k in 0..n * dim {
                let xbar = x[k] / a_i;
                let d = (xbar - x0[k]) / sig_i;
                let d2 = (xb[k] - x0b[k]) / sig_j;
                x[k] = a_j * (xbar + dsig * 0.5 * (d + d2));
            }
        }
    }
}

/// Stochastic churn sampler.
///
/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`EdmSdeStepper`]).
pub fn solve_sde(
    model: &dyn ModelEval,
    grid: &Grid,
    p: ChurnParams,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0 = vec![0.0; n * dim];
    let mut x0b = vec![0.0; n * dim];
    let mut xi = vec![0.0; n * dim];
    let gamma_max = (2.0f64).sqrt() - 1.0;
    for i in 0..m {
        let (sig_i, sig_j) = (edm_sigma(grid, i), edm_sigma(grid, i + 1));
        let (a_i, a_j) = (grid.alphas[i], grid.alphas[i + 1]);
        // Churn: inflate σ_i → σ̂ with fresh noise if inside the band.
        let gamma = if sig_i >= p.s_tmin && sig_i <= p.s_tmax {
            (p.churn / m as f64).min(gamma_max)
        } else {
            0.0
        };
        let sig_hat = sig_i * (1.0 + gamma);
        step_noise(noise, i, dim, n, &mut xi);
        let extra = (sig_hat * sig_hat - sig_i * sig_i).max(0.0).sqrt() * p.s_noise;
        // Work in scaled space at σ̂ (the model is queried at the σ̂ level;
        // on non-EDM schedules we approximate the (α, σ) pair at σ̂ by the
        // λ-inversion of the *grid* — exact on VE/EDM where α ≡ 1).
        let mut xhat = vec![0.0; n * dim];
        for k in 0..n * dim {
            xhat[k] = x[k] / a_i + extra * xi[k];
        }
        let ctx_hat = EvalCtx {
            t: grid.ts[i],
            alpha: a_i,
            sigma: sig_hat * a_i,
        };
        let unscaled: Vec<f64> = xhat.iter().map(|v| v * a_i).collect();
        model.eval_batch(&unscaled, &ctx_hat, &mut x0);
        let dsig = sig_j - sig_hat;
        if i + 1 == m || sig_j == 0.0 {
            for k in 0..n * dim {
                let d = (xhat[k] - x0[k]) / sig_hat;
                x[k] = a_j * (xhat[k] + dsig * d);
            }
        } else {
            let mut xb = vec![0.0; n * dim];
            for k in 0..n * dim {
                let d = (xhat[k] - x0[k]) / sig_hat;
                xb[k] = xhat[k] + dsig * d;
            }
            let trial: Vec<f64> = xb.iter().map(|v| v * a_j).collect();
            let ctx_j = EvalCtx { t: grid.ts[i + 1], alpha: a_j, sigma: grid.sigmas[i + 1] };
            model.eval_batch(&trial, &ctx_j, &mut x0b);
            for k in 0..n * dim {
                let d = (xhat[k] - x0[k]) / sig_hat;
                let d2 = (xb[k] - x0b[k]) / sig_j;
                x[k] = a_j * (xhat[k] + dsig * 0.5 * (d + d2));
            }
        }
    }
}

/// σ^{EDM} at grid point i.
fn edm_sigma(grid: &Grid, i: usize) -> f64 {
    grid.sigmas[i] / grid.alphas[i]
}

/// Deterministic Heun as an incremental [`Stepper`] (memoryless; the
/// trailing-Euler special case keys off `i + 1 == grid.m()`). A four-slot
/// [`Scratch`] arena sized at `init` keeps the step path allocation-free.
#[derive(Default)]
pub struct HeunStepper {
    scr: Scratch,
}

impl HeunStepper {
    /// A fresh stepper; sized at [`Stepper::init`].
    pub fn new() -> Self {
        HeunStepper::default()
    }
}

impl Stepper for HeunStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        self.scr = Scratch::new(4, n * model.dim());
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let m = grid.m();
        let [x0, x0b, xb, trial] = self.scr.split(n * dim);
        let (sig_i, sig_j) = (edm_sigma(grid, i), edm_sigma(grid, i + 1));
        let (a_i, a_j) = (grid.alphas[i], grid.alphas[i + 1]);
        let dsig = sig_j - sig_i;
        model.eval_batch(x, &grid.ctx(i), x0);
        if i + 1 == m || sig_j == 0.0 {
            // Trailing Euler step.
            for k in 0..n * dim {
                let xbar = x[k] / a_i;
                let d = (xbar - x0[k]) / sig_i;
                x[k] = a_j * (xbar + dsig * d);
            }
        } else {
            for k in 0..n * dim {
                let xbar = x[k] / a_i;
                let d = (xbar - x0[k]) / sig_i;
                xb[k] = xbar + dsig * d;
            }
            for k in 0..n * dim {
                trial[k] = a_j * xb[k];
            }
            let ctx_j = EvalCtx { t: grid.ts[i + 1], alpha: a_j, sigma: grid.sigmas[i + 1] };
            model.eval_batch(trial, &ctx_j, x0b);
            for k in 0..n * dim {
                let xbar = x[k] / a_i;
                let d = (xbar - x0[k]) / sig_i;
                let d2 = (xb[k] - x0b[k]) / sig_j;
                x[k] = a_j * (xbar + dsig * 0.5 * (d + d2));
            }
        }
    }
}

/// The stochastic churn sampler as an incremental [`Stepper`]. The churn
/// band test and γ depend only on the grid (passed every step), so the
/// stepper itself is memoryless; a six-slot [`Scratch`] arena sized at
/// `init` keeps the step path allocation-free.
pub struct EdmSdeStepper {
    p: ChurnParams,
    scr: Scratch,
}

impl EdmSdeStepper {
    /// A stepper with churn hyperparameters `p`; sized at
    /// [`Stepper::init`].
    pub fn new(p: ChurnParams) -> Self {
        EdmSdeStepper { p, scr: Scratch::default() }
    }
}

impl Stepper for EdmSdeStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        self.scr = Scratch::new(6, n * model.dim());
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let m = grid.m();
        let p = self.p;
        let [x0, x0b, xi, xhat, xb, trial] = self.scr.split(n * dim);
        let gamma_max = (2.0f64).sqrt() - 1.0;
        let (sig_i, sig_j) = (edm_sigma(grid, i), edm_sigma(grid, i + 1));
        let (a_i, a_j) = (grid.alphas[i], grid.alphas[i + 1]);
        let gamma = if sig_i >= p.s_tmin && sig_i <= p.s_tmax {
            (p.churn / m as f64).min(gamma_max)
        } else {
            0.0
        };
        let sig_hat = sig_i * (1.0 + gamma);
        step_noise(noise, i, dim, n, xi);
        let extra = (sig_hat * sig_hat - sig_i * sig_i).max(0.0).sqrt() * p.s_noise;
        for k in 0..n * dim {
            xhat[k] = x[k] / a_i + extra * xi[k];
        }
        let ctx_hat = EvalCtx { t: grid.ts[i], alpha: a_i, sigma: sig_hat * a_i };
        // `trial` doubles as the unscaled churned state for the first eval.
        for k in 0..n * dim {
            trial[k] = xhat[k] * a_i;
        }
        model.eval_batch(trial, &ctx_hat, x0);
        let dsig = sig_j - sig_hat;
        if i + 1 == m || sig_j == 0.0 {
            for k in 0..n * dim {
                let d = (xhat[k] - x0[k]) / sig_hat;
                x[k] = a_j * (xhat[k] + dsig * d);
            }
        } else {
            for k in 0..n * dim {
                let d = (xhat[k] - x0[k]) / sig_hat;
                xb[k] = xhat[k] + dsig * d;
            }
            for k in 0..n * dim {
                trial[k] = xb[k] * a_j;
            }
            let ctx_j = EvalCtx { t: grid.ts[i + 1], alpha: a_j, sigma: grid.sigmas[i + 1] };
            model.eval_batch(trial, &ctx_j, x0b);
            for k in 0..n * dim {
                let d = (xhat[k] - x0[k]) / sig_hat;
                let d2 = (xb[k] - x0b[k]) / sig_j;
                x[k] = a_j * (xhat[k] + dsig * 0.5 * (d + d2));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::{CountingModel, GmmAnalytic};
    use crate::rng::normal::PhiloxNormal;
    use crate::schedule::{timesteps, NoiseSchedule, StepSelector};

    fn setup(m: usize) -> (GmmAnalytic, Grid) {
        let sch = NoiseSchedule::ve();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::EdmRho { rho: 7.0 }, m));
        (GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 12)), grid)
    }

    #[test]
    fn heun_nfe_accounting() {
        let (model, grid) = setup(6);
        let counting = CountingModel::new(&model);
        let mut x = vec![10.0, -5.0];
        solve_heun(&counting, &grid, &mut x, 1);
        // 2 per step except the trailing Euler step: 2*6 - 1 = 11.
        assert_eq!(counting.count(), 11);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn heun_deterministic_and_converges() {
        let (model, grid) = setup(40);
        let mut a = vec![20.0, -12.0];
        let mut b = a.clone();
        solve_heun(&model, &grid, &mut a, 1);
        solve_heun(&model, &grid, &mut b, 1);
        assert_eq!(a, b);
        // Ends within data range.
        assert!(crate::linalg::norm2(&a) < 8.0, "a={a:?}");
    }

    #[test]
    fn churn_zero_equals_heun() {
        let (model, grid) = setup(8);
        let p = ChurnParams { churn: 0.0, s_noise: 1.0, s_tmin: 0.0, s_tmax: f64::INFINITY };
        let mut a = vec![15.0, 3.0];
        let mut b = a.clone();
        solve_heun(&model, &grid, &mut a, 1);
        solve_sde(&model, &grid, p, &mut b, 1, &mut PhiloxNormal::new(3));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn churn_injects_noise() {
        let (model, grid) = setup(8);
        let p = ChurnParams { churn: 10.0, s_noise: 1.0, s_tmin: 0.0, s_tmax: f64::INFINITY };
        let mut a = vec![15.0, 3.0];
        let mut b = a.clone();
        solve_sde(&model, &grid, p, &mut a, 1, &mut PhiloxNormal::new(3));
        solve_sde(&model, &grid, p, &mut b, 1, &mut PhiloxNormal::new(4));
        assert_ne!(a, b);
    }
}
