//! DPM-Solver baselines:
//!
//! * `solve_dpm2` — DPM-Solver-2 (Lu et al. 2022): singlestep midpoint
//!   scheme in λ on the noise-prediction ODE; 2 NFE per step.
//! * `solve_pp2m` — DPM-Solver++(2M) (Lu et al. 2023): 2-step multistep on
//!   the data-prediction ODE; 1 NFE per step. Per the paper's §5.3 it is
//!   exactly the 2-step SA-Predictor at τ ≡ 0 — `integration_equivalence`
//!   checks our SA implementation against this independent one.

use crate::jsonlite::Value;
use crate::linalg::Scratch;
use crate::models::{EvalCtx, ModelEval};
use crate::rng::normal::NormalSource;
use crate::schedule::NoiseSchedule;
use crate::solvers::snapshot::{f64_to_hex, hex_to_f64, StepperState};
use crate::solvers::stepper::{retain_rows, Stepper};
use crate::solvers::Grid;
use crate::util::error::{Error, Result};

/// DPM-Solver-2 (singlestep, midpoint in λ, noise prediction).
///
/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`Dpm2Stepper`]).
pub fn solve_dpm2(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    grid: &Grid,
    x: &mut [f64],
    n: usize,
) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0 = vec![0.0; n * dim];
    let mut u = vec![0.0; n * dim];
    let mut x0_mid = vec![0.0; n * dim];
    for i in 0..m {
        let (lam_s, lam_t) = (grid.lams[i], grid.lams[i + 1]);
        let h = lam_t - lam_s;
        let lam_mid = 0.5 * (lam_s + lam_t);
        let t_mid = sch.t_of_lambda(lam_mid);
        let (a_mid, s_mid) = (sch.alpha(t_mid), sch.sigma(t_mid));
        let (a_s, s_s) = (grid.alphas[i], grid.sigmas[i]);
        let (a_t, s_t) = (grid.alphas[i + 1], grid.sigmas[i + 1]);

        model.eval_batch(x, &grid.ctx(i), &mut x0);
        // u = (α_mid/α_s) x − σ_mid (e^{h/2} − 1) ε̂(x, t_i)
        let c_mid = s_mid * ((0.5 * h).exp() - 1.0);
        for k in 0..n * dim {
            let eps = (x[k] - a_s * x0[k]) / s_s;
            u[k] = a_mid / a_s * x[k] - c_mid * eps;
        }
        let mid_ctx = EvalCtx { t: t_mid, alpha: a_mid, sigma: s_mid };
        model.eval_batch(&u, &mid_ctx, &mut x0_mid);
        // x ← (α_t/α_s) x − σ_t (e^{h} − 1) ε̂(u, t_mid)
        let c_t = s_t * (h.exp() - 1.0);
        for k in 0..n * dim {
            let eps_mid = (u[k] - a_mid * x0_mid[k]) / s_mid;
            x[k] = a_t / a_s * x[k] - c_t * eps_mid;
        }
    }
}

/// DPM-Solver++(2M): multistep data-prediction scheme.
///
/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`Pp2mStepper`]).
pub fn solve_pp2m(model: &dyn ModelEval, grid: &Grid, x: &mut [f64], n: usize) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0_prev: Option<Vec<f64>> = None;
    let mut h_prev = 0.0f64;
    let mut x0 = vec![0.0; n * dim];
    for i in 0..m {
        model.eval_batch(x, &grid.ctx(i), &mut x0);
        let h = grid.lams[i + 1] - grid.lams[i];
        let (s_s, s_t) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let a_t = grid.alphas[i + 1];
        let ratio = s_t / s_s;
        let phi = 1.0 - (-h).exp();
        match &x0_prev {
            None => {
                // First step: DPM-Solver++(1) == deterministic DDIM.
                for k in 0..n * dim {
                    x[k] = ratio * x[k] + a_t * phi * x0[k];
                }
            }
            Some(prev) => {
                let r = h_prev / h;
                let c_cur = 1.0 + 1.0 / (2.0 * r);
                let c_prev = -1.0 / (2.0 * r);
                for k in 0..n * dim {
                    let d = c_cur * x0[k] + c_prev * prev[k];
                    x[k] = ratio * x[k] + a_t * phi * d;
                }
            }
        }
        h_prev = h;
        x0_prev = Some(std::mem::replace(&mut x0, vec![0.0; n * dim]));
    }
}

/// DPM-Solver-2 as an incremental [`Stepper`] (memoryless; 2 NFE/step).
/// Holds the schedule by value for the λ-midpoint inversion; a three-slot
/// [`Scratch`] arena sized at `init` keeps the step path allocation-free.
pub struct Dpm2Stepper {
    sch: NoiseSchedule,
    scr: Scratch,
}

impl Dpm2Stepper {
    /// A stepper over `sch`; sized at [`Stepper::init`].
    pub fn new(sch: NoiseSchedule) -> Self {
        Dpm2Stepper { sch, scr: Scratch::default() }
    }
}

impl Stepper for Dpm2Stepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        self.scr = Scratch::new(3, n * model.dim());
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let [x0, u, x0_mid] = self.scr.split(n * dim);
        let (lam_s, lam_t) = (grid.lams[i], grid.lams[i + 1]);
        let h = lam_t - lam_s;
        let lam_mid = 0.5 * (lam_s + lam_t);
        let t_mid = self.sch.t_of_lambda(lam_mid);
        let (a_mid, s_mid) = (self.sch.alpha(t_mid), self.sch.sigma(t_mid));
        let (a_s, s_s) = (grid.alphas[i], grid.sigmas[i]);
        let (a_t, s_t) = (grid.alphas[i + 1], grid.sigmas[i + 1]);

        model.eval_batch(x, &grid.ctx(i), x0);
        let c_mid = s_mid * ((0.5 * h).exp() - 1.0);
        for k in 0..n * dim {
            let eps = (x[k] - a_s * x0[k]) / s_s;
            u[k] = a_mid / a_s * x[k] - c_mid * eps;
        }
        let mid_ctx = EvalCtx { t: t_mid, alpha: a_mid, sigma: s_mid };
        model.eval_batch(u, &mid_ctx, x0_mid);
        let c_t = s_t * (h.exp() - 1.0);
        for k in 0..n * dim {
            let eps_mid = (u[k] - a_mid * x0_mid[k]) / s_mid;
            x[k] = a_t / a_s * x[k] - c_t * eps_mid;
        }
    }
}

/// DPM-Solver++(2M) as an incremental [`Stepper`]: the one-entry x₀̂
/// history and the previous step size are the carried state. Both the
/// history buffer and the eval scratch are pre-allocated at `init` and
/// rotated by `mem::swap`, so the step path never allocates.
#[derive(Default)]
pub struct Pp2mStepper {
    /// Whether `x0_prev` holds a committed history entry yet.
    has_prev: bool,
    h_prev: f64,
    x0_prev: Vec<f64>,
    x0: Vec<f64>,
}

impl Pp2mStepper {
    /// A fresh stepper; sized at [`Stepper::init`].
    pub fn new() -> Self {
        Pp2mStepper::default()
    }
}

impl Stepper for Pp2mStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let len = n * model.dim();
        self.has_prev = false;
        self.h_prev = 0.0;
        self.x0_prev = vec![0.0; len];
        self.x0 = vec![0.0; len];
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        debug_assert_eq!(self.x0.len(), n * dim);
        model.eval_batch(x, &grid.ctx(i), &mut self.x0);
        let h = grid.lams[i + 1] - grid.lams[i];
        let (s_s, s_t) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let a_t = grid.alphas[i + 1];
        let ratio = s_t / s_s;
        let phi = 1.0 - (-h).exp();
        if !self.has_prev {
            // First step: DPM-Solver++(1) == deterministic DDIM, a single
            // fused scale-and-accumulate.
            crate::linalg::scale_add(x, ratio, a_t * phi, &self.x0);
        } else {
            let prev = &self.x0_prev;
            let r = self.h_prev / h;
            let c_cur = 1.0 + 1.0 / (2.0 * r);
            let c_prev = -1.0 / (2.0 * r);
            for k in 0..n * dim {
                let d = c_cur * self.x0[k] + c_prev * prev[k];
                x[k] = ratio * x[k] + a_t * phi * d;
            }
        }
        self.h_prev = h;
        // Rotate the fresh eval into the history slot; the old history
        // buffer becomes the next step's eval scratch (fully overwritten).
        std::mem::swap(&mut self.x0_prev, &mut self.x0);
        self.has_prev = true;
    }

    fn retain_lanes(&mut self, keep: &[bool], dim: usize) {
        retain_rows(&mut self.x0_prev, keep, dim);
        // x0 is pure scratch between steps (its content moved into
        // x0_prev); only its length must track the surviving lanes.
        retain_rows(&mut self.x0, keep, dim);
    }

    /// Carried state: the one-entry x₀̂ history plus the previous step size
    /// h (an f64 whose exact bits feed the next step's coefficients — it is
    /// serialized as a hex bit pattern like every float payload).
    fn snapshot(&self, lanes: usize, dim: usize) -> StepperState {
        StepperState {
            lanes,
            dim,
            scalars: Value::obj(vec![
                ("h_prev", Value::Str(f64_to_hex(self.h_prev))),
                ("has_prev", Value::Bool(self.has_prev)),
            ]),
            mats: if self.has_prev {
                vec![("x0_prev".to_string(), self.x0_prev.clone())]
            } else {
                Vec::new()
            },
        }
    }

    fn restore(&mut self, state: &StepperState, _grid: &Grid, dim: usize) -> Result<()> {
        self.h_prev = hex_to_f64(
            state
                .scalars
                .get("h_prev")
                .and_then(Value::as_str)
                .ok_or_else(|| Error::config("dpm++2m snapshot missing 'h_prev'"))?,
        )?;
        let len = state.lanes * dim;
        self.has_prev = state.scalars.opt_bool("has_prev", false);
        self.x0_prev = if self.has_prev {
            state.mat("x0_prev")?.to_vec()
        } else {
            vec![0.0; len]
        };
        self.x0 = vec![0.0; len];
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::{CountingModel, GmmAnalytic};
    use crate::schedule::{timesteps, StepSelector};

    fn setup(m: usize) -> (GmmAnalytic, NoiseSchedule, Grid) {
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
        (GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 8)), sch, grid)
    }

    #[test]
    fn dpm2_two_evals_per_step() {
        let (model, sch, grid) = setup(5);
        let counting = CountingModel::new(&model);
        let mut x = vec![0.2, 0.4];
        solve_dpm2(&counting, &sch, &grid, &mut x, 1);
        assert_eq!(counting.count(), 10);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pp2m_one_eval_per_step() {
        let (model, _sch, grid) = setup(7);
        let counting = CountingModel::new(&model);
        let mut x = vec![0.2, 0.4];
        solve_pp2m(&counting, &grid, &mut x, 1);
        assert_eq!(counting.count(), 7);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn both_deterministic() {
        let (model, sch, grid) = setup(6);
        let mut a = vec![0.3, -0.1];
        let mut b = a.clone();
        solve_dpm2(&model, &sch, &grid, &mut a, 1);
        solve_dpm2(&model, &sch, &grid, &mut b, 1);
        assert_eq!(a, b);
        let mut c = vec![0.3, -0.1];
        let mut d = c.clone();
        solve_pp2m(&model, &grid, &mut c, 1);
        solve_pp2m(&model, &grid, &mut d, 1);
        assert_eq!(c, d);
    }

    #[test]
    fn dpm2_more_accurate_than_one_step_per_eval() {
        // On a linear (single-Gaussian) model, compare both solvers at the
        // same NFE against a fine reference; dpm2 should be closer than a
        // 1-step-only scheme run at matching NFE via pp2m-first-step-style.
        let gmm = Gmm::new(vec![1.0], vec![vec![0.7]], vec![vec![1.1]]);
        let model = GmmAnalytic::new(gmm);
        let sch = NoiseSchedule::vp_linear();
        let fine = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 512));
        let mut x_ref = vec![1.0];
        solve_pp2m(&model, &fine, &mut x_ref, 1);

        let mut errs = Vec::new();
        for m in [5usize, 10, 20] {
            let coarse = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
            let mut x2 = vec![1.0];
            solve_dpm2(&model, &sch, &coarse, &mut x2, 1);
            errs.push((x2[0] - x_ref[0]).abs());
        }
        // Second-order scheme: error drops superlinearly with the grid.
        assert!(errs[2] < errs[0] * 0.25, "errs={errs:?}");
        assert!(errs[2] < 0.02, "errs={errs:?}");
    }
}
