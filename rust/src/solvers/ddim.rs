//! DDIM-η (Song et al. 2021), generalized to arbitrary (α, σ) schedules.
//!
//! Step i → i+1 (λ increases by h):
//!   σ̂  = η σ_{i+1} √(1 − e^{−2h})
//!   x  = α_{i+1} x₀̂ + √(σ_{i+1}² − σ̂²) ε̂ + σ̂ ξ,  ε̂ = (x_i − α_i x₀̂)/σ_i
//!
//! η = 0 is the classic deterministic DDIM; this σ̂ parameterization is the
//! schedule-agnostic form under which DDIM-η coincides with the 1-step
//! SA-Predictor at τ_η² = −ln(1 − η²(1 − e^{−2h}))/(2h) (Corollary 5.3) —
//! covered by `integration_equivalence`.

use crate::linalg::Scratch;
use crate::models::ModelEval;
use crate::rng::normal::NormalSource;
use crate::solvers::stepper::Stepper;
use crate::solvers::{step_noise, Grid};

/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`DdimStepper`]).
pub fn solve(
    model: &dyn ModelEval,
    grid: &Grid,
    eta: f64,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) {
    let dim = model.dim();
    let m = grid.m();
    let mut x0 = vec![0.0; n * dim];
    let mut xi = vec![0.0; n * dim];
    for i in 0..m {
        model.eval_batch(x, &grid.ctx(i), &mut x0);
        step_noise(noise, i, dim, n, &mut xi);
        let h = grid.lams[i + 1] - grid.lams[i];
        let (a_s, a_t) = (grid.alphas[i], grid.alphas[i + 1]);
        let (s_s, s_t) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let sig_hat = eta * s_t * crate::util::one_minus_exp_neg(2.0 * h).max(0.0).sqrt();
        let det = (s_t * s_t - sig_hat * sig_hat).max(0.0).sqrt();
        for k in 0..n * dim {
            let eps = (x[k] - a_s * x0[k]) / s_s;
            x[k] = a_t * x0[k] + det * eps + sig_hat * xi[k];
        }
    }
}

/// DDIM-η as an incremental [`Stepper`]: memoryless scheme, the only state
/// is a two-slot [`Scratch`] arena for x₀̂ and ξ, sized at `init` so the
/// step path never allocates.
pub struct DdimStepper {
    eta: f64,
    scr: Scratch,
}

impl DdimStepper {
    /// A stepper with stochasticity `eta` (0 = deterministic DDIM).
    pub fn new(eta: f64) -> Self {
        DdimStepper { eta, scr: Scratch::default() }
    }
}

impl Stepper for DdimStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        _grid: &Grid,
        _x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        self.scr = Scratch::new(2, n * model.dim());
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let [x0, xi] = self.scr.split(n * dim);
        model.eval_batch(x, &grid.ctx(i), x0);
        step_noise(noise, i, dim, n, xi);
        let h = grid.lams[i + 1] - grid.lams[i];
        let (a_s, a_t) = (grid.alphas[i], grid.alphas[i + 1]);
        let (s_s, s_t) = (grid.sigmas[i], grid.sigmas[i + 1]);
        let sig_hat = self.eta * s_t * crate::util::one_minus_exp_neg(2.0 * h).max(0.0).sqrt();
        let det = (s_t * s_t - sig_hat * sig_hat).max(0.0).sqrt();
        for k in 0..n * dim {
            let eps = (x[k] - a_s * x0[k]) / s_s;
            x[k] = a_t * x0[k] + det * eps + sig_hat * xi[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::GmmAnalytic;
    use crate::rng::normal::{PhiloxNormal, ZeroNormal};
    use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
    use crate::util::close;

    fn setup(m: usize) -> (GmmAnalytic, Grid) {
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
        (GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 4)), grid)
    }

    #[test]
    fn eta_zero_deterministic() {
        let (model, grid) = setup(10);
        let mut a = vec![0.4, -0.2, 0.9, 0.1];
        let mut b = a.clone();
        solve(&model, &grid, 0.0, &mut a, 2, &mut PhiloxNormal::new(1));
        solve(&model, &grid, 0.0, &mut b, 2, &mut PhiloxNormal::new(999));
        assert_eq!(a, b, "η=0 must ignore the noise source");
    }

    #[test]
    fn eta_one_adds_noise() {
        let (model, grid) = setup(10);
        let mut a = vec![0.4, -0.2];
        let mut b = a.clone();
        solve(&model, &grid, 1.0, &mut a, 1, &mut PhiloxNormal::new(1));
        solve(&model, &grid, 1.0, &mut b, 1, &mut PhiloxNormal::new(2));
        assert_ne!(a, b);
    }

    #[test]
    fn converges_to_posterior_mode_region() {
        // Deterministic DDIM from a point should land in the data support:
        // final x should be near where the GMM has mass (|x| bounded by
        // spread + a few std).
        let (model, grid) = setup(100);
        let mut x = vec![1.0, -1.0];
        solve(&model, &grid, 0.0, &mut x, 1, &mut ZeroNormal);
        let p = model.gmm.log_density(&x, 1.0, 0.05);
        assert!(p.is_finite());
        assert!(crate::linalg::norm2(&x) < 6.0, "x={x:?}");
    }

    #[test]
    fn single_gaussian_exact_limit() {
        // For a zero-mean single Gaussian the DDIM map is linear; with many
        // steps the terminal scale must approach the data std from the
        // prior std (flow map preserves quantiles of a 1-D Gaussian).
        let gmm = Gmm::new(vec![1.0], vec![vec![0.0]], vec![vec![2.0]]);
        let model = GmmAnalytic::new(gmm);
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 400));
        // Start at x_T = σ_T·z for z = 1 ⇒ terminal ≈ sqrt(v_data + σ_min²)·z
        let z = 1.0;
        let mut x = vec![grid.sigmas[0] * z];
        solve(&model, &grid, 0.0, &mut x, 1, &mut ZeroNormal);
        // Marginal-preserving flow maps N(0, σ_T²) to N(0, α² v + σ²) at
        // t_min; with α≈1, σ≈0 that is std ≈ sqrt(2).
        let want = (model.gmm.vars[0][0]
            * grid.alphas[grid.m()].powi(2)
            + grid.sigmas[grid.m()].powi(2))
        .sqrt()
            * z;
        assert!(close(x[0], want, 0.02, 0.0), "x={} want {want}", x[0]);
    }
}
