//! Adaptive-step stochastic solver — "Gotta Go Fast" (Jolicoeur-Martineau
//! et al. 2021, the paper's [25] and the strongest *adaptive* stochastic
//! baseline it discusses): stochastic Improved Euler (Heun–Maruyama) with
//! embedded first-order error control.
//!
//! Each trial step from t with size dt < 0 on the reverse SDE (τ from
//! config):
//!   k₁ = drift(x, t)
//!   x_E  = x + dt·k₁ + √(−dt)·τ g(t) ξ            (Euler–Maruyama)
//!   k₂ = drift(x_E, t+dt)
//!   x_H  = x + dt·(k₁+k₂)/2 + √(−dt)·τ g(t) ξ     (Improved Euler, shared ξ)
//! Error estimate E = ‖(x_H − x_E)/(δ + r·max(|x_H|,|x_E|))‖_rms; accept if
//! E ≤ 1, step-size update dt ← ν·dt·E^{−1/2} (clamped), as in the paper's
//! Algorithm 1 (their θ=0.9, r/δ tolerances).
//!
//! NFE is whatever the controller spends — the paper's point (and ours,
//! Fig. 2) is that hundreds of evaluations are needed for high quality,
//! which is why SA-Solver's fixed-budget multistep design wins at small
//! NFE.

use crate::models::{EvalCtx, ModelEval};
use crate::rng::normal::NormalSource;
use crate::schedule::NoiseSchedule;

/// Controller parameters (defaults from Jolicoeur-Martineau et al.).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveParams {
    /// Relative tolerance r.
    pub rtol: f64,
    /// Absolute tolerance δ.
    pub atol: f64,
    /// Safety factor ν on the step-size update.
    pub safety: f64,
    /// Stochasticity scale τ of the reverse SDE.
    pub tau: f64,
    /// Hard cap on model evaluations (2 per trial step).
    pub max_nfe: usize,
}

impl Default for AdaptiveParams {
    fn default() -> Self {
        AdaptiveParams { rtol: 0.05, atol: 0.01, safety: 0.9, tau: 1.0, max_nfe: 2000 }
    }
}

/// Solve from sch.t_max down to sch.t_min with adaptive steps; returns the
/// number of model evaluations spent.
pub fn solve(
    model: &dyn ModelEval,
    sch: &NoiseSchedule,
    p: AdaptiveParams,
    x: &mut [f64],
    n: usize,
    noise: &mut dyn NormalSource,
) -> usize {
    let dim = model.dim();
    let mut t = sch.t_max;
    let mut dt = -(sch.t_max - sch.t_min) / 64.0; // initial guess
    let min_dt = -(sch.t_max - sch.t_min) / 4096.0;
    let mut nfe = 0usize;
    let mut step_idx = 0usize;

    let mut x0hat = vec![0.0; n * dim];
    let mut k1 = vec![0.0; n * dim];
    let mut k2 = vec![0.0; n * dim];
    let mut x_e = vec![0.0; n * dim];
    let mut x_h = vec![0.0; n * dim];
    let mut xi = vec![0.0; n * dim];

    while t > sch.t_min + 1e-12 && nfe + 2 <= p.max_nfe {
        // Clamp the step to not overshoot.
        if t + dt < sch.t_min {
            dt = sch.t_min - t;
        }
        let (alpha, sigma) = (sch.alpha(t), sch.sigma(t));
        let g2 = sch.g2(t);
        let f = sch.dlog_alpha_dt(t);
        let ctx = EvalCtx { t, alpha, sigma };
        model.eval_batch(x, &ctx, &mut x0hat);
        nfe += 1;
        let half = 0.5 * (1.0 + p.tau * p.tau) * g2;
        for k in 0..n * dim {
            let score = (alpha * x0hat[k] - x[k]) / (sigma * sigma);
            k1[k] = f * x[k] - half * score;
        }
        crate::solvers::step_noise(noise, step_idx, dim, n, &mut xi);
        step_idx += 1;
        let noise_scale = p.tau * g2.sqrt() * (-dt).max(0.0).sqrt();
        for k in 0..n * dim {
            x_e[k] = x[k] + dt * k1[k] + noise_scale * xi[k];
        }
        // Second stage at t+dt on the Euler proposal.
        let t2 = t + dt;
        let (alpha2, sigma2) = (sch.alpha(t2), sch.sigma(t2));
        let ctx2 = EvalCtx { t: t2, alpha: alpha2, sigma: sigma2 };
        model.eval_batch(&x_e, &ctx2, &mut x0hat);
        nfe += 1;
        let g2_2 = sch.g2(t2.max(sch.t_min));
        let f2 = sch.dlog_alpha_dt(t2);
        let half2 = 0.5 * (1.0 + p.tau * p.tau) * g2_2;
        for k in 0..n * dim {
            let score2 = (alpha2 * x0hat[k] - x_e[k]) / (sigma2 * sigma2);
            k2[k] = f2 * x_e[k] - half2 * score2;
        }
        for k in 0..n * dim {
            x_h[k] = x[k] + dt * 0.5 * (k1[k] + k2[k]) + noise_scale * xi[k];
        }
        // Mixed-norm error estimate.
        let mut acc = 0.0;
        for k in 0..n * dim {
            let scale = p.atol + p.rtol * x_h[k].abs().max(x_e[k].abs());
            let e = (x_h[k] - x_e[k]) / scale;
            acc += e * e;
        }
        let err = (acc / (n * dim) as f64).sqrt();
        // Accept on tolerance, or once the step has shrunk to the floor
        // (prevents stalling; matches the reference implementation).
        let at_floor = dt >= min_dt - 1e-15;
        if err <= 1.0 || at_floor {
            x.copy_from_slice(&x_h);
            t += dt;
        }
        // Step-size controller: |dt| ← ν |dt| clamp(E^{−1/2}, 0.2, 5),
        // bounded to [range/4096, range/8] in magnitude (dt stays < 0).
        let factor = (err.max(1e-12)).powf(-0.5).clamp(0.2, 5.0);
        let mag = (p.safety * factor * dt.abs())
            .clamp(min_dt.abs(), (sch.t_max - sch.t_min) / 8.0);
        dt = -mag;
    }
    nfe
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::{CountingModel, GmmAnalytic};
    use crate::rng::normal::PhiloxNormal;

    #[test]
    fn reaches_t_min_within_budget() {
        let sch = NoiseSchedule::vp_linear();
        let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 3));
        let counting = CountingModel::new(&model);
        let mut noise = PhiloxNormal::new(1);
        let mut x = vec![0.5, -0.5, 1.0, 0.0];
        let nfe = solve(&counting, &sch, AdaptiveParams::default(), &mut x, 2, &mut noise);
        assert_eq!(nfe, counting.count());
        assert!(nfe >= 4, "suspiciously few evals: {nfe}");
        assert!(nfe <= AdaptiveParams::default().max_nfe);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tighter_tolerance_spends_more_nfe() {
        let sch = NoiseSchedule::vp_linear();
        let model = GmmAnalytic::new(Gmm::structured(2, 2, 1.5, 3));
        let run = |rtol: f64| {
            let counting = CountingModel::new(&model);
            let mut noise = PhiloxNormal::new(2);
            let mut x = vec![0.5, -0.5];
            solve(
                &counting,
                &sch,
                AdaptiveParams { rtol, atol: rtol / 5.0, ..Default::default() },
                &mut x,
                1,
                &mut noise,
            )
        };
        let loose = run(0.2);
        let tight = run(0.01);
        assert!(
            tight > loose,
            "tighter tolerance should cost more NFE: {tight} !> {loose}"
        );
    }

    #[test]
    fn samples_land_in_data_region() {
        let sch = NoiseSchedule::vp_linear();
        let gmm = Gmm::structured(2, 2, 1.5, 3);
        let model = GmmAnalytic::new(gmm);
        let mut noise = PhiloxNormal::new(5);
        let n = 64;
        // Start from the prior.
        let mut x = vec![0.0; n * 2];
        for lane in 0..n {
            let mut row = [0.0; 2];
            use crate::rng::normal::NormalSource;
            noise.fill(lane as u64, crate::solvers::PRIOR_STEP, &mut row);
            x[lane * 2] = row[0] * sch.sigma(sch.t_max);
            x[lane * 2 + 1] = row[1] * sch.sigma(sch.t_max);
        }
        solve(&model, &sch, AdaptiveParams::default(), &mut x, n, &mut noise);
        let max = x.iter().fold(0.0f64, |a, v| a.max(v.abs()));
        assert!(max < 8.0, "samples far outside data region: {max}");
    }
}
