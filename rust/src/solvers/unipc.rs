//! UniPC-p (Zhao et al. 2023) — p-step Adams-Bashforth predictor +
//! p-step Adams-Moulton corrector with the exponential integrator, on the
//! data-prediction ODE.
//!
//! Per the paper's §B.5.3, UniPC-p equals SA-Solver(p, p) at τ ≡ 0. This
//! module is a deliberately *independent* implementation: the coefficient
//! integrals ∫ e^{λ−λ_t} l_j(λ) dλ are evaluated with adaptive Simpson
//! quadrature rather than the closed-form moment recursion used by
//! `solvers::coeffs`, so the equivalence tests cross-validate both paths.

use crate::jsonlite::Value;
use crate::lagrange::{lagrange_basis_coeffs, poly_eval};
use crate::models::ModelEval;
use crate::quad::adaptive_simpson;
use crate::rng::normal::NormalSource;
use crate::solvers::snapshot::StepperState;
use crate::solvers::stepper::{retain_rows, HistoryRing, Stepper};
use crate::solvers::Grid;
use crate::util::error::{Error, Result};
use std::collections::VecDeque;

/// ODE Adams coefficients via quadrature: b_j = α_t ∫ e^{λ−λ_t} l_j dλ.
fn ode_coeffs(nodes: &[f64], lam_s: f64, lam_t: f64, alpha_t: f64) -> Vec<f64> {
    let shifted: Vec<f64> = nodes.iter().map(|x| x - lam_t).collect();
    let cs = lagrange_basis_coeffs(&shifted);
    cs.iter()
        .map(|cj| {
            let f = |lam: f64| (lam - lam_t).exp() * poly_eval(cj, lam - lam_t);
            alpha_t * adaptive_simpson(&f, lam_s, lam_t, 1e-13)
        })
        .collect()
}

/// Run UniPC-p with predictor order `p` and corrector order `pc`
/// (`pc = 0` disables the corrector).
///
/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`UniPcStepper`]).
pub fn solve(
    model: &dyn ModelEval,
    grid: &Grid,
    p: usize,
    pc: usize,
    x: &mut [f64],
    n: usize,
) {
    let dim = model.dim();
    let m = grid.m();
    let p = p.max(1);
    let keep = p.max(pc).max(1);
    let mut buffer: VecDeque<(usize, Vec<f64>)> = VecDeque::new();

    let mut f0 = vec![0.0; n * dim];
    model.eval_batch(x, &grid.ctx(0), &mut f0);
    buffer.push_front((0, f0));

    let mut x_pred = vec![0.0; n * dim];
    let mut f_new = vec![0.0; n * dim];
    for i in 0..m {
        let (lam_s, lam_t) = (grid.lams[i], grid.lams[i + 1]);
        let ratio = grid.sigmas[i + 1] / grid.sigmas[i];
        let a_t = grid.alphas[i + 1];

        // Predictor: AB over the p_eff most recent evals.
        let p_eff = buffer.len().min(p);
        let nodes: Vec<f64> = buffer.iter().take(p_eff).map(|(j, _)| grid.lams[*j]).collect();
        let b = ode_coeffs(&nodes, lam_s, lam_t, a_t);
        for k in 0..n * dim {
            x_pred[k] = ratio * x[k];
        }
        for (bj, (_, f)) in b.iter().zip(buffer.iter().take(p_eff)) {
            for k in 0..n * dim {
                x_pred[k] += bj * f[k];
            }
        }

        model.eval_batch(&x_pred, &grid.ctx(i + 1), &mut f_new);

        if pc > 0 {
            // Corrector: AM over {λ_{i+1}} ∪ pc_eff former evals.
            let pc_eff = buffer.len().min(pc);
            let mut cnodes = vec![lam_t];
            cnodes.extend(buffer.iter().take(pc_eff).map(|(j, _)| grid.lams[*j]));
            let bc = ode_coeffs(&cnodes, lam_s, lam_t, a_t);
            for k in 0..n * dim {
                x[k] = ratio * x[k] + bc[0] * f_new[k];
            }
            for (bj, (_, f)) in bc[1..].iter().zip(buffer.iter().take(pc_eff)) {
                for k in 0..n * dim {
                    x[k] += bj * f[k];
                }
            }
        } else {
            x.copy_from_slice(&x_pred);
        }

        buffer.push_front((i + 1, std::mem::replace(&mut f_new, vec![0.0; n * dim])));
        while buffer.len() > keep {
            buffer.pop_back();
        }
    }
}

/// Precomputed per-step UniPC coefficients: the AB predictor weights and
/// (when the corrector is on) the AM corrector weights. The history depth
/// at entry to step `i` is `min(i + 1, keep)` by construction, so the
/// node sets — λ of the buffered evals, newest first — are a pure
/// function of the grid; quadrature runs once at `init`/`restore`, never
/// on the step hot path.
struct UniPlan {
    b: Vec<f64>,
    bc: Option<Vec<f64>>,
}

fn build_plan(p: usize, pc: usize, keep: usize, grid: &Grid) -> Vec<UniPlan> {
    let m = grid.m();
    let mut plans = Vec::with_capacity(m);
    let mut nodes: Vec<f64> = Vec::with_capacity(keep + 1);
    for i in 0..m {
        let (lam_s, lam_t) = (grid.lams[i], grid.lams[i + 1]);
        let a_t = grid.alphas[i + 1];
        let hist_len = (i + 1).min(keep);
        let p_eff = hist_len.min(p);
        nodes.clear();
        nodes.extend((0..p_eff).map(|j| grid.lams[i - j]));
        let b = ode_coeffs(&nodes, lam_s, lam_t, a_t);
        let bc = if pc > 0 {
            let pc_eff = hist_len.min(pc);
            nodes.clear();
            nodes.push(lam_t);
            nodes.extend((0..pc_eff).map(|j| grid.lams[i - j]));
            Some(ode_coeffs(&nodes, lam_s, lam_t, a_t))
        } else {
            None
        };
        plans.push(UniPlan { b, bc });
    }
    plans
}

/// UniPC-p as an incremental [`Stepper`]: the AB/AM history buffer is a
/// contiguous [`HistoryRing`] arena (the carried state), the quadrature
/// coefficients are precomputed into a `UniPlan` table at
/// `init`/`restore`, and each step applies them through the fused
/// [`crate::linalg::lincomb_into`] / [`crate::linalg::lincomb_inplace`]
/// kernels with zero heap allocations.
pub struct UniPcStepper {
    p: usize,
    pc: usize,
    keep: usize,
    plan: Vec<UniPlan>,
    hist: HistoryRing,
    offsets: Vec<usize>,
    x_pred: Vec<f64>,
}

impl UniPcStepper {
    /// A stepper with predictor order `p` and corrector order `pc`
    /// (`pc = 0` disables the corrector).
    pub fn new(p: usize, pc: usize) -> Self {
        let p = p.max(1);
        let keep = p.max(pc).max(1);
        UniPcStepper {
            p,
            pc,
            keep,
            plan: Vec::new(),
            hist: HistoryRing::new(keep, 0),
            offsets: Vec::new(),
            x_pred: Vec::new(),
        }
    }
}

impl Stepper for UniPcStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        self.plan = build_plan(self.p, self.pc, self.keep, grid);
        self.hist = HistoryRing::new(self.keep, n * dim);
        self.offsets = Vec::with_capacity(self.keep + 1);
        model.eval_batch(x, &grid.ctx(0), self.hist.free_mut());
        self.hist.commit(0);
        self.x_pred = vec![0.0; n * dim];
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        debug_assert_eq!(x.len(), n * dim);
        let plan = &self.plan[i];
        let ratio = grid.sigmas[i + 1] / grid.sigmas[i];

        // Predictor: AB over the p_eff most recent evals, one fused pass.
        let p_eff = plan.b.len();
        debug_assert!(self.hist.len() >= p_eff);
        // The plan assumed nodes λ_i, λ_{i−1}, …; the ring must agree, or
        // precomputed coefficients would silently apply to wrong nodes.
        debug_assert!(
            self.hist.indices().take(p_eff).enumerate().all(|(j, idx)| idx == i - j),
            "history ring indices diverged from the coefficient plan at step {i}"
        );
        self.offsets.clear();
        self.offsets.extend(self.hist.offsets().take(p_eff));
        crate::linalg::lincomb_into(
            ratio,
            x,
            None,
            &plan.b,
            self.hist.data(),
            &self.offsets,
            &mut self.x_pred,
        );

        model.eval_batch(&self.x_pred, &grid.ctx(i + 1), self.hist.free_mut());

        if let Some(bc) = &plan.bc {
            // Corrector: AM over {λ_{i+1}} ∪ pc_eff former evals, applied
            // in place on the carried state.
            let pc_eff = bc.len() - 1;
            debug_assert!(self.hist.len() >= pc_eff);
            self.offsets.clear();
            self.offsets.push(self.hist.free_offset());
            self.offsets.extend(self.hist.offsets().take(pc_eff));
            crate::linalg::lincomb_inplace(ratio, x, bc, self.hist.data(), &self.offsets);
        } else {
            x.copy_from_slice(&self.x_pred);
        }

        self.hist.commit(i + 1);
    }

    fn retain_lanes(&mut self, keep: &[bool], dim: usize) {
        self.hist.retain_lanes(keep, dim);
        retain_rows(&mut self.x_pred, keep, dim);
    }

    /// Carried state: the AB/AM history ring (values + grid indices).
    /// Coefficients are a pure function of the grid (rebuilt on restore);
    /// `x_pred` and the ring's free slot are scratch, fully rewritten
    /// every step.
    fn snapshot(&self, lanes: usize, dim: usize) -> StepperState {
        StepperState {
            lanes,
            dim,
            scalars: Value::obj(vec![(
                "buf_idx",
                Value::Array(self.hist.indices().map(|idx| Value::Num(idx as f64)).collect()),
            )]),
            mats: (0..self.hist.len())
                .map(|j| (format!("buf{j}"), self.hist.entry(j).to_vec()))
                .collect(),
        }
    }

    fn restore(&mut self, state: &StepperState, grid: &Grid, dim: usize) -> Result<()> {
        let idxs: Vec<usize> = state
            .scalars
            .get("buf_idx")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("unipc snapshot missing 'buf_idx'"))?
            .iter()
            .map(|v| {
                v.as_usize().ok_or_else(|| Error::config("unipc 'buf_idx' entry not an index"))
            })
            .collect::<Result<_>>()?;
        if idxs.len() != state.mats.len() {
            return Err(Error::config(format!(
                "unipc snapshot has {} buffer indices but {} matrices",
                idxs.len(),
                state.mats.len()
            )));
        }
        if idxs.len() > self.keep {
            return Err(Error::config(format!(
                "unipc snapshot has {} history entries but this config keeps {}",
                idxs.len(),
                self.keep
            )));
        }
        // The precomputed plan assumes the ring shape min(front + 1, keep)
        // at indices front, front−1, … — reject inconsistent snapshots
        // (see the same check in the SA stepper).
        crate::solvers::sa::check_contiguous_history(&idxs, self.keep, "unipc")?;
        self.plan = build_plan(self.p, self.pc, self.keep, grid);
        let len = state.lanes * dim;
        self.hist = HistoryRing::new(self.keep, len);
        for (j, idx) in idxs.iter().enumerate() {
            // Front-to-back order, exactly as snapshotted.
            self.hist.restore_entry(*idx, state.mat(&format!("buf{j}"))?);
        }
        self.offsets = Vec::with_capacity(self.keep + 1);
        self.x_pred = vec![0.0; len];
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::{CountingModel, GmmAnalytic};
    use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
    use crate::util::close;

    fn setup(m: usize) -> (GmmAnalytic, Grid) {
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
        (GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 17)), grid)
    }

    #[test]
    fn nfe_is_m_plus_one() {
        let (model, grid) = setup(9);
        let counting = CountingModel::new(&model);
        let mut x = vec![0.1, 0.2];
        solve(&counting, &grid, 3, 3, &mut x, 1);
        assert_eq!(counting.count(), 10);
    }

    #[test]
    fn corrector_improves_accuracy() {
        let gmm = Gmm::new(vec![1.0], vec![vec![0.4]], vec![vec![0.9]]);
        let model = GmmAnalytic::new(gmm);
        let sch = NoiseSchedule::vp_linear();
        let fine = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 512));
        let mut x_ref = vec![0.8];
        solve(&model, &fine, 3, 3, &mut x_ref, 1);
        let coarse = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 6));
        let mut errs = Vec::new();
        for pc in [0usize, 2] {
            let mut x = vec![0.8];
            solve(&model, &coarse, 2, pc, &mut x, 1);
            errs.push((x[0] - x_ref[0]).abs());
        }
        assert!(
            errs[1] < errs[0],
            "corrector err {} !< predictor-only err {}",
            errs[1],
            errs[0]
        );
    }

    #[test]
    fn one_step_matches_ddim_form() {
        // p=1, single step: x₁ = (σ₁/σ₀) x₀ + α₁(1−e^{−h}) x₀̂ — check the
        // coefficient against the closed form.
        let (_, grid) = setup(1);
        let b = ode_coeffs(&[grid.lams[0]], grid.lams[0], grid.lams[1], grid.alphas[1]);
        let h = grid.lams[1] - grid.lams[0];
        let want = grid.alphas[1] * (1.0 - (-h).exp());
        assert!(close(b[0], want, 1e-10, 0.0), "{} vs {want}", b[0]);
    }
}
