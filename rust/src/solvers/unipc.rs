//! UniPC-p (Zhao et al. 2023) — p-step Adams-Bashforth predictor +
//! p-step Adams-Moulton corrector with the exponential integrator, on the
//! data-prediction ODE.
//!
//! Per the paper's §B.5.3, UniPC-p equals SA-Solver(p, p) at τ ≡ 0. This
//! module is a deliberately *independent* implementation: the coefficient
//! integrals ∫ e^{λ−λ_t} l_j(λ) dλ are evaluated with adaptive Simpson
//! quadrature rather than the closed-form moment recursion used by
//! `solvers::coeffs`, so the equivalence tests cross-validate both paths.

use crate::jsonlite::Value;
use crate::lagrange::{lagrange_basis_coeffs, poly_eval};
use crate::models::ModelEval;
use crate::quad::adaptive_simpson;
use crate::rng::normal::NormalSource;
use crate::solvers::snapshot::StepperState;
use crate::solvers::stepper::{ensure_len, retain_rows, Stepper};
use crate::solvers::Grid;
use crate::util::error::{Error, Result};
use std::collections::VecDeque;

/// ODE Adams coefficients via quadrature: b_j = α_t ∫ e^{λ−λ_t} l_j dλ.
fn ode_coeffs(nodes: &[f64], lam_s: f64, lam_t: f64, alpha_t: f64) -> Vec<f64> {
    let shifted: Vec<f64> = nodes.iter().map(|x| x - lam_t).collect();
    let cs = lagrange_basis_coeffs(&shifted);
    cs.iter()
        .map(|cj| {
            let f = |lam: f64| (lam - lam_t).exp() * poly_eval(cj, lam - lam_t);
            alpha_t * adaptive_simpson(&f, lam_s, lam_t, 1e-13)
        })
        .collect()
}

/// Run UniPC-p with predictor order `p` and corrector order `pc`
/// (`pc = 0` disables the corrector).
///
/// Monolithic seed-era loop, retained as the reference implementation for
/// the stepper equivalence contract (production goes through
/// [`UniPcStepper`]).
pub fn solve(
    model: &dyn ModelEval,
    grid: &Grid,
    p: usize,
    pc: usize,
    x: &mut [f64],
    n: usize,
) {
    let dim = model.dim();
    let m = grid.m();
    let p = p.max(1);
    let keep = p.max(pc).max(1);
    let mut buffer: VecDeque<(usize, Vec<f64>)> = VecDeque::new();

    let mut f0 = vec![0.0; n * dim];
    model.eval_batch(x, &grid.ctx(0), &mut f0);
    buffer.push_front((0, f0));

    let mut x_pred = vec![0.0; n * dim];
    let mut f_new = vec![0.0; n * dim];
    for i in 0..m {
        let (lam_s, lam_t) = (grid.lams[i], grid.lams[i + 1]);
        let ratio = grid.sigmas[i + 1] / grid.sigmas[i];
        let a_t = grid.alphas[i + 1];

        // Predictor: AB over the p_eff most recent evals.
        let p_eff = buffer.len().min(p);
        let nodes: Vec<f64> = buffer.iter().take(p_eff).map(|(j, _)| grid.lams[*j]).collect();
        let b = ode_coeffs(&nodes, lam_s, lam_t, a_t);
        for k in 0..n * dim {
            x_pred[k] = ratio * x[k];
        }
        for (bj, (_, f)) in b.iter().zip(buffer.iter().take(p_eff)) {
            for k in 0..n * dim {
                x_pred[k] += bj * f[k];
            }
        }

        model.eval_batch(&x_pred, &grid.ctx(i + 1), &mut f_new);

        if pc > 0 {
            // Corrector: AM over {λ_{i+1}} ∪ pc_eff former evals.
            let pc_eff = buffer.len().min(pc);
            let mut cnodes = vec![lam_t];
            cnodes.extend(buffer.iter().take(pc_eff).map(|(j, _)| grid.lams[*j]));
            let bc = ode_coeffs(&cnodes, lam_s, lam_t, a_t);
            for k in 0..n * dim {
                x[k] = ratio * x[k] + bc[0] * f_new[k];
            }
            for (bj, (_, f)) in bc[1..].iter().zip(buffer.iter().take(pc_eff)) {
                for k in 0..n * dim {
                    x[k] += bj * f[k];
                }
            }
        } else {
            x.copy_from_slice(&x_pred);
        }

        buffer.push_front((i + 1, std::mem::replace(&mut f_new, vec![0.0; n * dim])));
        while buffer.len() > keep {
            buffer.pop_back();
        }
    }
}

/// UniPC-p as an incremental [`Stepper`]: the AB/AM history buffer is the
/// carried state; coefficients are recomputed per step from the grid.
pub struct UniPcStepper {
    p: usize,
    pc: usize,
    keep: usize,
    buffer: VecDeque<(usize, Vec<f64>)>,
    x_pred: Vec<f64>,
    f_new: Vec<f64>,
}

impl UniPcStepper {
    pub fn new(p: usize, pc: usize) -> Self {
        let p = p.max(1);
        let keep = p.max(pc).max(1);
        UniPcStepper { p, pc, keep, buffer: VecDeque::new(), x_pred: Vec::new(), f_new: Vec::new() }
    }
}

impl Stepper for UniPcStepper {
    fn init(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        let mut f0 = vec![0.0; n * dim];
        model.eval_batch(x, &grid.ctx(0), &mut f0);
        self.buffer.push_front((0, f0));
        self.x_pred = vec![0.0; n * dim];
        self.f_new = vec![0.0; n * dim];
    }

    fn step(
        &mut self,
        model: &dyn ModelEval,
        grid: &Grid,
        i: usize,
        x: &mut [f64],
        n: usize,
        _noise: &mut dyn NormalSource,
    ) {
        let dim = model.dim();
        ensure_len(&mut self.x_pred, n * dim);
        ensure_len(&mut self.f_new, n * dim);
        let (lam_s, lam_t) = (grid.lams[i], grid.lams[i + 1]);
        let ratio = grid.sigmas[i + 1] / grid.sigmas[i];
        let a_t = grid.alphas[i + 1];

        // Predictor: AB over the p_eff most recent evals.
        let p_eff = self.buffer.len().min(self.p);
        let nodes: Vec<f64> = self.buffer.iter().take(p_eff).map(|(j, _)| grid.lams[*j]).collect();
        let b = ode_coeffs(&nodes, lam_s, lam_t, a_t);
        for k in 0..n * dim {
            self.x_pred[k] = ratio * x[k];
        }
        for (bj, (_, f)) in b.iter().zip(self.buffer.iter().take(p_eff)) {
            for k in 0..n * dim {
                self.x_pred[k] += bj * f[k];
            }
        }

        model.eval_batch(&self.x_pred, &grid.ctx(i + 1), &mut self.f_new);

        if self.pc > 0 {
            // Corrector: AM over {λ_{i+1}} ∪ pc_eff former evals.
            let pc_eff = self.buffer.len().min(self.pc);
            let mut cnodes = vec![lam_t];
            cnodes.extend(self.buffer.iter().take(pc_eff).map(|(j, _)| grid.lams[*j]));
            let bc = ode_coeffs(&cnodes, lam_s, lam_t, a_t);
            for k in 0..n * dim {
                x[k] = ratio * x[k] + bc[0] * self.f_new[k];
            }
            for (bj, (_, f)) in bc[1..].iter().zip(self.buffer.iter().take(pc_eff)) {
                for k in 0..n * dim {
                    x[k] += bj * f[k];
                }
            }
        } else {
            x.copy_from_slice(&self.x_pred);
        }

        // Recycle the evicted entry's allocation for the next step's
        // f_new scratch (it is fully overwritten by the next eval), as
        // SaStepper does — no steady-state allocation per step.
        let recycled = if self.buffer.len() >= self.keep {
            self.buffer.pop_back().map(|(_, f)| f)
        } else {
            None
        };
        let next = recycled.unwrap_or_else(|| vec![0.0; n * dim]);
        let f = std::mem::replace(&mut self.f_new, next);
        self.buffer.push_front((i + 1, f));
        while self.buffer.len() > self.keep {
            self.buffer.pop_back();
        }
    }

    fn retain_lanes(&mut self, keep: &[bool], dim: usize) {
        for (_, f) in self.buffer.iter_mut() {
            retain_rows(f, keep, dim);
        }
        retain_rows(&mut self.x_pred, keep, dim);
        retain_rows(&mut self.f_new, keep, dim);
    }

    /// Carried state: the AB/AM history buffer (values + grid indices).
    /// Coefficients are recomputed per step from the grid; `x_pred`/`f_new`
    /// are scratch, fully rewritten every step.
    fn snapshot(&self, lanes: usize, dim: usize) -> StepperState {
        StepperState {
            lanes,
            dim,
            scalars: Value::obj(vec![(
                "buf_idx",
                Value::Array(self.buffer.iter().map(|(j, _)| Value::Num(*j as f64)).collect()),
            )]),
            mats: self
                .buffer
                .iter()
                .enumerate()
                .map(|(j, (_, f))| (format!("buf{j}"), f.clone()))
                .collect(),
        }
    }

    fn restore(&mut self, state: &StepperState, dim: usize) -> Result<()> {
        let idxs: Vec<usize> = state
            .scalars
            .get("buf_idx")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::config("unipc snapshot missing 'buf_idx'"))?
            .iter()
            .map(|v| {
                v.as_usize().ok_or_else(|| Error::config("unipc 'buf_idx' entry not an index"))
            })
            .collect::<Result<_>>()?;
        if idxs.len() != state.mats.len() {
            return Err(Error::config(format!(
                "unipc snapshot has {} buffer indices but {} matrices",
                idxs.len(),
                state.mats.len()
            )));
        }
        self.buffer.clear();
        for (j, idx) in idxs.iter().enumerate() {
            // Front-to-back order, exactly as snapshotted.
            self.buffer.push_back((*idx, state.mat(&format!("buf{j}"))?.to_vec()));
        }
        let len = state.lanes * dim;
        self.x_pred = vec![0.0; len];
        self.f_new = vec![0.0; len];
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Gmm;
    use crate::models::{CountingModel, GmmAnalytic};
    use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
    use crate::util::close;

    fn setup(m: usize) -> (GmmAnalytic, Grid) {
        let sch = NoiseSchedule::vp_linear();
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
        (GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 17)), grid)
    }

    #[test]
    fn nfe_is_m_plus_one() {
        let (model, grid) = setup(9);
        let counting = CountingModel::new(&model);
        let mut x = vec![0.1, 0.2];
        solve(&counting, &grid, 3, 3, &mut x, 1);
        assert_eq!(counting.count(), 10);
    }

    #[test]
    fn corrector_improves_accuracy() {
        let gmm = Gmm::new(vec![1.0], vec![vec![0.4]], vec![vec![0.9]]);
        let model = GmmAnalytic::new(gmm);
        let sch = NoiseSchedule::vp_linear();
        let fine = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 512));
        let mut x_ref = vec![0.8];
        solve(&model, &fine, 3, 3, &mut x_ref, 1);
        let coarse = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 6));
        let mut errs = Vec::new();
        for pc in [0usize, 2] {
            let mut x = vec![0.8];
            solve(&model, &coarse, 2, pc, &mut x, 1);
            errs.push((x[0] - x_ref[0]).abs());
        }
        assert!(
            errs[1] < errs[0],
            "corrector err {} !< predictor-only err {}",
            errs[1],
            errs[0]
        );
    }

    #[test]
    fn one_step_matches_ddim_form() {
        // p=1, single step: x₁ = (σ₁/σ₀) x₀ + α₁(1−e^{−h}) x₀̂ — check the
        // coefficient against the closed form.
        let (_, grid) = setup(1);
        let b = ode_coeffs(&[grid.lams[0]], grid.lams[0], grid.lams[1], grid.alphas[1]);
        let h = grid.lams[1] - grid.lams[0];
        let want = grid.alphas[1] * (1.0 - (-h).exp());
        assert!(close(b[0], want, 1e-10, 0.0), "{} vs {want}", b[0]);
    }
}
