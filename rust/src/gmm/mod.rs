//! Gaussian-mixture substrate: the *exact-score* stand-in for pretrained
//! denoisers (see DESIGN.md §2).
//!
//! For x₀ ~ Σ_k w_k N(μ_k, diag(s_k)) and the forward marginal
//! x_t | x₀ ~ N(α x₀, σ² I), the time-t marginal is again a GMM
//! (means α μ_k, vars α² s_k + σ²) and the data-prediction target
//! x_θ*(x, t) = E[x₀ | x_t = x] is in closed form — a responsibility-weighted
//! sum of per-component posterior means. This gives every solver an exact,
//! smooth, Lipschitz model so ordering effects are measured without
//! model-error confounds.

use crate::rng::Xoshiro256pp;

/// Diagonal-covariance Gaussian mixture over R^dim.
#[derive(Debug, Clone)]
pub struct Gmm {
    pub dim: usize,
    /// Mixture weights (normalized at construction).
    pub weights: Vec<f64>,
    /// Component means, `k × dim`.
    pub means: Vec<Vec<f64>>,
    /// Component per-dimension variances, `k × dim`.
    pub vars: Vec<Vec<f64>>,
}

impl Gmm {
    /// Construct (weights are normalized; all variances must be positive).
    pub fn new(weights: Vec<f64>, means: Vec<Vec<f64>>, vars: Vec<Vec<f64>>) -> Self {
        assert_eq!(weights.len(), means.len());
        assert_eq!(weights.len(), vars.len());
        assert!(!weights.is_empty());
        let dim = means[0].len();
        for (m, v) in means.iter().zip(&vars) {
            assert_eq!(m.len(), dim);
            assert_eq!(v.len(), dim);
            assert!(v.iter().all(|x| *x > 0.0), "variances must be positive");
        }
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0);
        Gmm {
            dim,
            weights: weights.iter().map(|w| w / total).collect(),
            means,
            vars,
        }
    }

    /// Single standard Gaussian.
    pub fn standard(dim: usize) -> Self {
        Gmm::new(vec![1.0], vec![vec![0.0; dim]], vec![vec![1.0; dim]])
    }

    /// A reproducible "structured" mixture: K components on a scaled
    /// hypersphere shell with anisotropic variances. Used by the workload
    /// analogs; the seed fixes the geometry.
    pub fn structured(dim: usize, k: usize, spread: f64, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        let mut means = Vec::with_capacity(k);
        let mut vars = Vec::with_capacity(k);
        let mut weights = Vec::with_capacity(k);
        for _ in 0..k {
            let raw: Vec<f64> = rng.normals(dim);
            let norm = crate::linalg::norm2(&raw).max(1e-9);
            means.push(raw.iter().map(|x| spread * x / norm).collect());
            vars.push((0..dim).map(|_| rng.uniform_in(0.05, 0.35)).collect());
            weights.push(rng.uniform_in(0.5, 1.5));
        }
        Gmm::new(weights, means, vars)
    }

    /// Number of components.
    pub fn k(&self) -> usize {
        self.weights.len()
    }

    /// Draw `n` samples from the prior (x₀); returns row-major `n × dim`.
    pub fn sample(&self, rng: &mut Xoshiro256pp, n: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(n * self.dim);
        for _ in 0..n {
            let k = rng.choose_weighted(&self.weights);
            for d in 0..self.dim {
                out.push(self.means[k][d] + self.vars[k][d].sqrt() * rng.normal());
            }
        }
        out
    }

    /// Draw `n` samples from the *time-t marginal* given (α, σ) — exact
    /// reference distribution for solver-output comparison.
    pub fn sample_marginal(
        &self,
        rng: &mut Xoshiro256pp,
        n: usize,
        alpha: f64,
        sigma: f64,
    ) -> Vec<f64> {
        let mut out = Vec::with_capacity(n * self.dim);
        for _ in 0..n {
            let k = rng.choose_weighted(&self.weights);
            for d in 0..self.dim {
                let var = alpha * alpha * self.vars[k][d] + sigma * sigma;
                out.push(alpha * self.means[k][d] + var.sqrt() * rng.normal());
            }
        }
        out
    }

    /// Log-responsibilities log γ_k(x) under the time-t marginal, written
    /// into `log_resp` (length k). Returns the marginal log-density.
    fn log_responsibilities(&self, x: &[f64], alpha: f64, sigma: f64, log_resp: &mut [f64]) -> f64 {
        let s2 = sigma * sigma;
        for k in 0..self.k() {
            let mut lp = self.weights[k].ln();
            for d in 0..self.dim {
                let var = alpha * alpha * self.vars[k][d] + s2;
                let diff = x[d] - alpha * self.means[k][d];
                lp += -0.5 * (diff * diff / var + var.ln() + (2.0 * std::f64::consts::PI).ln());
            }
            log_resp[k] = lp;
        }
        // log-sum-exp
        let m = log_resp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = m + log_resp.iter().map(|l| (l - m).exp()).sum::<f64>().ln();
        for l in log_resp.iter_mut() {
            *l -= lse;
        }
        lse
    }

    /// Exact posterior mean E[x₀ | x_t = x] (the data-prediction target).
    /// `x` has length dim; result written into `out`.
    pub fn posterior_mean(&self, x: &[f64], alpha: f64, sigma: f64, out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim);
        let s2 = sigma * sigma;
        let mut log_resp = vec![0.0; self.k()];
        self.log_responsibilities(x, alpha, sigma, &mut log_resp);
        out.fill(0.0);
        for k in 0..self.k() {
            let g = log_resp[k].exp();
            if g < 1e-300 {
                continue;
            }
            for d in 0..self.dim {
                let var = alpha * alpha * self.vars[k][d] + s2;
                // Posterior mean of component k (linear-Gaussian conditioning).
                let mk = self.means[k][d]
                    + alpha * self.vars[k][d] / var * (x[d] - alpha * self.means[k][d]);
                out[d] += g * mk;
            }
        }
    }

    /// Batched posterior mean: `xs` is row-major n×dim, result n×dim.
    pub fn posterior_mean_batch(&self, xs: &[f64], alpha: f64, sigma: f64) -> Vec<f64> {
        let n = xs.len() / self.dim;
        let mut out = vec![0.0; xs.len()];
        for i in 0..n {
            let row = &xs[i * self.dim..(i + 1) * self.dim];
            let orow = &mut out[i * self.dim..(i + 1) * self.dim];
            self.posterior_mean(row, alpha, sigma, orow);
        }
        out
    }

    /// Exact score ∇_x log p_t(x) = (α E[x₀|x] − x)/σ².
    pub fn score(&self, x: &[f64], alpha: f64, sigma: f64, out: &mut [f64]) {
        self.posterior_mean(x, alpha, sigma, out);
        let s2 = sigma * sigma;
        for d in 0..self.dim {
            out[d] = (alpha * out[d] - x[d]) / s2;
        }
    }

    /// Marginal log-density at time t.
    pub fn log_density(&self, x: &[f64], alpha: f64, sigma: f64) -> f64 {
        let mut scratch = vec![0.0; self.k()];
        self.log_responsibilities(x, alpha, sigma, &mut scratch)
    }

    /// Exact mean of the prior.
    pub fn prior_mean(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.dim];
        for k in 0..self.k() {
            for d in 0..self.dim {
                m[d] += self.weights[k] * self.means[k][d];
            }
        }
        m
    }

    /// Exact (diagonal of the) prior covariance plus the mean-spread term:
    /// Var[x_d] = Σ_k w_k (s_kd + μ_kd²) − (Σ_k w_k μ_kd)².
    pub fn prior_var_diag(&self) -> Vec<f64> {
        let m = self.prior_mean();
        let mut v = vec![0.0; self.dim];
        for k in 0..self.k() {
            for d in 0..self.dim {
                v[d] += self.weights[k] * (self.vars[k][d] + self.means[k][d] * self.means[k][d]);
            }
        }
        for d in 0..self.dim {
            v[d] -= m[d] * m[d];
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{close, mean};

    fn two_comp_1d() -> Gmm {
        Gmm::new(
            vec![0.5, 0.5],
            vec![vec![-2.0], vec![2.0]],
            vec![vec![0.25], vec![0.25]],
        )
    }

    #[test]
    fn weights_normalized() {
        let g = Gmm::new(vec![2.0, 6.0], vec![vec![0.0], vec![1.0]], vec![vec![1.0], vec![1.0]]);
        assert!(close(g.weights[0], 0.25, 1e-15, 0.0));
        assert!(close(g.weights[1], 0.75, 1e-15, 0.0));
    }

    #[test]
    fn single_gaussian_posterior_mean_exact() {
        // For one component the posterior mean is the standard Gaussian
        // denoiser: μ + ασ₀²/(α²σ₀²+σ²)(x − αμ).
        let g = Gmm::new(vec![1.0], vec![vec![1.5]], vec![vec![4.0]]);
        let (alpha, sigma) = (0.8, 0.6);
        let x = [2.0];
        let mut out = [0.0];
        g.posterior_mean(&x, alpha, sigma, &mut out);
        let var = alpha * alpha * 4.0 + sigma * sigma;
        let want = 1.5 + alpha * 4.0 / var * (2.0 - alpha * 1.5);
        assert!(close(out[0], want, 1e-12, 0.0), "{} vs {}", out[0], want);
    }

    #[test]
    fn posterior_mean_symmetric_mixture() {
        // Symmetric two-component mixture: E[x0|0] = 0 by symmetry; far in
        // one mode the posterior collapses to that component.
        let g = two_comp_1d();
        let mut out = [0.0];
        g.posterior_mean(&[0.0], 1.0, 0.5, &mut out);
        assert!(out[0].abs() < 1e-12);
        g.posterior_mean(&[2.0], 1.0, 0.1, &mut out);
        assert!(close(out[0], 2.0, 0.02, 0.0), "got {}", out[0]);
    }

    #[test]
    fn score_matches_log_density_gradient() {
        let g = Gmm::structured(3, 4, 2.0, 11);
        let (alpha, sigma) = (0.7, 0.9);
        let x = [0.3, -0.8, 1.2];
        let mut sc = vec![0.0; 3];
        g.score(&x, alpha, sigma, &mut sc);
        for d in 0..3 {
            let mut xp = x.to_vec();
            let mut xm = x.to_vec();
            let eps = 1e-5;
            xp[d] += eps;
            xm[d] -= eps;
            let fd = (g.log_density(&xp, alpha, sigma) - g.log_density(&xm, alpha, sigma))
                / (2.0 * eps);
            assert!(close(sc[d], fd, 1e-4, 1e-6), "d={d}: {} vs fd {}", sc[d], fd);
        }
    }

    #[test]
    fn sampling_moments_match_exact() {
        let g = two_comp_1d();
        let mut rng = Xoshiro256pp::new(1);
        let xs = g.sample(&mut rng, 40_000);
        assert!(close(mean(&xs), 0.0, 0.0, 0.05), "mean {}", mean(&xs));
        let var = xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64;
        let want = g.prior_var_diag()[0];
        assert!(close(var, want, 0.05, 0.0), "var {var} want {want}");
    }

    #[test]
    fn marginal_sampling_interpolates() {
        // At (α=1, σ→0) the marginal is the prior; at (α→0, σ=1) it is N(0,1).
        let g = two_comp_1d();
        let mut rng = Xoshiro256pp::new(2);
        let xs = g.sample_marginal(&mut rng, 30_000, 0.0, 1.0);
        let var = xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64;
        assert!(close(var, 1.0, 0.05, 0.0), "var={var}");
    }

    #[test]
    fn posterior_mean_batch_matches_single() {
        let g = Gmm::structured(4, 3, 1.5, 5);
        let mut rng = Xoshiro256pp::new(3);
        let xs = g.sample_marginal(&mut rng, 8, 0.9, 0.4);
        let batch = g.posterior_mean_batch(&xs, 0.9, 0.4);
        for i in 0..8 {
            let mut single = vec![0.0; 4];
            g.posterior_mean(&xs[i * 4..(i + 1) * 4], 0.9, 0.4, &mut single);
            assert_eq!(&batch[i * 4..(i + 1) * 4], &single[..]);
        }
    }

    #[test]
    fn structured_reproducible() {
        let a = Gmm::structured(8, 5, 2.0, 42);
        let b = Gmm::structured(8, 5, 2.0, 42);
        assert_eq!(a.means, b.means);
        let c = Gmm::structured(8, 5, 2.0, 43);
        assert_ne!(a.means, c.means);
    }
}
