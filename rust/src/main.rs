//! `sadiff` CLI — the Layer-3 entry point.
//!
//! Subcommands:
//!   serve        start the sampling server (`--presets` loads a registry;
//!                `--checkpoint-path`/`--checkpoint-every` enable crash-safe
//!                in-flight checkpointing and resume-on-start; `--register`
//!                joins a router fleet, `--publish-snapshots` exposes live
//!                group checkpoints for router failover)
//!   router       start the multi-worker front-end: owns tickets and client
//!                connections, fans requests over `--worker-addrs` by a
//!                `--placement` policy, heartbeats the fleet, live-migrates
//!                groups on `rebalance` and fails over dead workers
//!   sample       run one sampling job locally and report metrics
//!   client       send a request to a running server (`--resume <id|all>`
//!                queries checkpoint-recovered results; `--stats` prints a
//!                human-readable metrics table; `--trace start|stop|dump`
//!                drives the server's span recorder)
//!   loadgen      drive a server with open-loop (Poisson/bursty/replay) or
//!                closed-loop traffic and report latency percentiles,
//!                goodput vs offered load and shed/deadline-miss counts
//!                (spawns an in-process server unless `--addr` is given)
//!   checkpoint   inspect a serving checkpoint file
//!   trace        inspect a Chrome Trace Event dump written by the server
//!   tune         search solver configs per (workload, NFE budget) and
//!                write a preset registry
//!   `exp <id>`   regenerate a paper table/figure (see `exp list`)
//!   artifacts    list compiled artifacts from the manifest
//!   info         print build/workload/solver inventory

use sadiff::cli::{render_help, Args, FlagSpec};
use sadiff::config::{SamplerConfig, ServerConfig};
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::SampleRequest;
use sadiff::exps::common::f as fmt_f;
use sadiff::exps::{self, Scale, Table};
use sadiff::jsonlite::{self, Value};
use sadiff::tuner::{self, TuneOptions};
use sadiff::util::error::{Error, Result};
use sadiff::workloads;

fn flag_spec() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "help", help: "show help", takes_value: false },
        FlagSpec { name: "config", help: "JSON config file", takes_value: true },
        FlagSpec { name: "addr", help: "server address (serve/client)", takes_value: true },
        FlagSpec { name: "workers", help: "worker threads", takes_value: true },
        FlagSpec { name: "threads", help: "lane-parallel threads (0 = auto)", takes_value: true },
        FlagSpec { name: "max-batch", help: "max requests per batch", takes_value: true },
        FlagSpec {
            name: "max-inflight",
            help: "in-flight lane groups per worker (serve)",
            takes_value: true,
        },
        FlagSpec {
            name: "cancel",
            help: "cancel request id on the server (client)",
            takes_value: true,
        },
        FlagSpec { name: "workload", help: "workload name", takes_value: true },
        FlagSpec { name: "model", help: "gmm | artifact:<name>", takes_value: true },
        FlagSpec { name: "solver", help: "solver name", takes_value: true },
        FlagSpec { name: "nfe", help: "model evaluations", takes_value: true },
        FlagSpec { name: "tau", help: "stochasticity scale", takes_value: true },
        FlagSpec { name: "n", help: "samples", takes_value: true },
        FlagSpec { name: "seed", help: "rng seed", takes_value: true },
        FlagSpec { name: "quick", help: "small quick run", takes_value: false },
        FlagSpec { name: "log", help: "log level", takes_value: true },
        FlagSpec { name: "budgets", help: "NFE budgets to tune, e.g. 5,10,20", takes_value: true },
        FlagSpec {
            name: "out",
            help: "output path (tune registry, trace dump)",
            takes_value: true,
        },
        FlagSpec { name: "refine", help: "tuner refinement rounds", takes_value: true },
        FlagSpec { name: "presets", help: "preset registry path (serve)", takes_value: true },
        FlagSpec { name: "preset", help: "preset name or 'auto' (client)", takes_value: true },
        FlagSpec {
            name: "checkpoint-path",
            help: "serving checkpoint file; resume on start (serve)",
            takes_value: true,
        },
        FlagSpec {
            name: "checkpoint-every",
            help: "steps between checkpoint rewrites (serve)",
            takes_value: true,
        },
        FlagSpec {
            name: "resume",
            help: "fetch a checkpoint-recovered result: id or 'all' (client)",
            takes_value: true,
        },
        FlagSpec {
            name: "trace-path",
            help: "enable tracing; default trace dump path (serve)",
            takes_value: true,
        },
        FlagSpec {
            name: "trace-capacity",
            help: "per-thread trace ring capacity, events (serve)",
            takes_value: true,
        },
        FlagSpec {
            name: "trace",
            help: "span recorder control: start|stop|dump (client)",
            takes_value: true,
        },
        FlagSpec {
            name: "stats",
            help: "print a human-readable server metrics table (client)",
            takes_value: false,
        },
        FlagSpec {
            name: "queue-lane-cap",
            help: "shed when queued lanes exceed this, 0 = queue-cap x max-batch (serve/loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "reply-timeout",
            help: "ms a connection waits for its reply before the ticket is cancelled (serve/loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "max-step-lanes",
            help: "per-step lane admission budget per worker, 0 = unlimited (serve/loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "arrival",
            help: "poisson:<rps> | bursty:<base,burst,on_s,off_s> | replay:<r,..[@bin_s]> | closed:<c> (loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "rates",
            help: "extra poisson sweep rates, e.g. 20,60,120 (loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "duration",
            help: "run length per point, seconds (loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "requests",
            help: "cap on requests per point, 0 = uncapped (loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "deadline",
            help: "per-request deadline in ms, 0 = none (loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "priorities",
            help: "spread request priorities over 0..span-1, 1 = flat (loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "worker-addrs",
            help: "comma-separated worker addresses (router)",
            takes_value: true,
        },
        FlagSpec {
            name: "placement",
            help: "placement policy: least_loaded | round_robin | sticky (router/loadgen)",
            takes_value: true,
        },
        FlagSpec {
            name: "heartbeat",
            help: "worker heartbeat poll interval, ms (router)",
            takes_value: true,
        },
        FlagSpec {
            name: "heartbeat-timeout",
            help: "declare a worker dead after this silence, ms (router)",
            takes_value: true,
        },
        FlagSpec {
            name: "register",
            help: "router address to register this worker with (serve)",
            takes_value: true,
        },
        FlagSpec {
            name: "publish-snapshots",
            help: "publish in-flight group snapshots for router failover without a checkpoint file (serve)",
            takes_value: false,
        },
        FlagSpec {
            name: "router",
            help: "spawn an in-process router over this many workers (loadgen)",
            takes_value: true,
        },
    ]
}

fn main() {
    let spec = flag_spec();
    let args = match Args::from_env(&spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = sadiff::util::log::set_level_by_name(args.get_str("log", "info")) {
        eprintln!("--log: {e}");
        std::process::exit(2);
    }
    if args.has("help") || args.positionals.is_empty() {
        print!(
            "{}",
            render_help("sadiff", "SA-Solver diffusion sampling framework", &spec)
        );
        println!(
            "\nSubcommands: serve | router | sample | client | loadgen | checkpoint <path> | trace <path> | tune | exp <id|list> | artifacts | info"
        );
        return;
    }
    let cmd = args.positionals[0].clone();
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "router" => cmd_router(&args),
        "sample" => cmd_sample(&args),
        "client" => cmd_client(&args),
        "loadgen" => cmd_loadgen(&args),
        "checkpoint" => cmd_checkpoint(&args),
        "trace" => cmd_trace(&args),
        "tune" => cmd_tune(&args),
        "exp" => cmd_exp(&args),
        "artifacts" => cmd_artifacts(),
        "info" => cmd_info(),
        other => Err(Error::config(format!("unknown subcommand '{other}'"))),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn sampler_config(args: &Args) -> Result<SamplerConfig> {
    let mut base = if let Some(path) = args.get("config") {
        let v = sadiff::config::load_json_file(path)?;
        SamplerConfig::from_json(&v)?
    } else {
        SamplerConfig::sa_default()
    };
    if let Some(name) = args.get("solver") {
        let kind = sadiff::config::SolverKind::by_name(name)
            .ok_or_else(|| Error::config(format!("unknown solver '{name}'")))?;
        base = SamplerConfig { solver: kind, ..SamplerConfig::for_solver(kind) };
    }
    base.nfe = args.get_usize("nfe", base.nfe)?;
    base.tau = args.get_f64("tau", base.tau)?;
    base.validate()?;
    Ok(base)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = if let Some(path) = args.get("config") {
        ServerConfig::from_json(&sadiff::config::load_json_file(path)?)?
    } else {
        ServerConfig::default()
    };
    if let Some(addr) = args.get("addr") {
        cfg.addr = addr.to_string();
    }
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    cfg.max_inflight = args.get_usize("max-inflight", cfg.max_inflight)?.max(1);
    cfg.queue_lane_cap = args.get_usize("queue-lane-cap", cfg.queue_lane_cap)?;
    cfg.reply_timeout_ms = args.get_u64("reply-timeout", cfg.reply_timeout_ms)?.max(1);
    cfg.max_step_lanes = args.get_usize("max-step-lanes", cfg.max_step_lanes)?;
    if let Some(path) = args.get("presets") {
        cfg.presets_path = Some(path.to_string());
    }
    if let Some(path) = args.get("checkpoint-path") {
        cfg.checkpoint_path = Some(path.to_string());
    }
    cfg.checkpoint_every =
        args.get_u64("checkpoint-every", cfg.checkpoint_every)?.max(1);
    if let Some(path) = args.get("trace-path") {
        cfg.trace_path = Some(path.to_string());
    }
    cfg.trace_capacity = args.get_usize("trace-capacity", cfg.trace_capacity)?;
    if args.has("publish-snapshots") {
        cfg.publish_snapshots = true;
    }
    let caps = Value::obj(vec![
        ("workers", Value::Num(cfg.workers as f64)),
        ("max_batch", Value::Num(cfg.max_batch as f64)),
        ("max_inflight", Value::Num(cfg.max_inflight as f64)),
        (
            "publishing",
            Value::Bool(cfg.publish_snapshots || cfg.checkpoint_path.is_some()),
        ),
    ]);
    let handle = Server::bind(cfg)?.spawn()?;
    println!("sadiff server on {} — Ctrl-C to stop", handle.addr);
    if let Some(router_addr) = args.get("register") {
        let line = jsonlite::to_string(&Value::obj(vec![
            ("cmd", Value::Str("register".to_string())),
            ("addr", Value::Str(handle.addr.to_string())),
            ("capabilities", caps),
        ]));
        let mut c = Client::connect(router_addr)?;
        let reply = c.round_trip(&line)?;
        println!("registered with router {router_addr}: {}", reply.trim());
    }
    // Block forever; the handle's workers do the serving.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_router(args: &Args) -> Result<()> {
    use sadiff::coordinator::router::{Router, RouterConfig};
    let mut cfg = if let Some(path) = args.get("config") {
        RouterConfig::from_json(&sadiff::config::load_json_file(path)?)?
    } else {
        RouterConfig::default()
    };
    if let Some(addr) = args.get("addr") {
        cfg.addr = addr.to_string();
    }
    if let Some(list) = args.get("worker-addrs") {
        cfg.workers = list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    if let Some(p) = args.get("placement") {
        cfg.placement = p.to_string();
    }
    cfg.heartbeat_ms = args.get_u64("heartbeat", cfg.heartbeat_ms)?.max(1);
    cfg.heartbeat_timeout_ms = args
        .get_u64("heartbeat-timeout", cfg.heartbeat_timeout_ms)?
        .max(1);
    cfg.reply_timeout_ms = args.get_u64("reply-timeout", cfg.reply_timeout_ms)?.max(1);
    let handle = Router::bind(cfg)?.spawn();
    println!(
        "sadiff router on {} — workers may join via register; Ctrl-C to stop",
        handle.addr()
    );
    // Block forever; the handle's threads do the serving.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_sample(args: &Args) -> Result<()> {
    let wl_name = args.get_str("workload", "latent_analog");
    let wl = workloads::by_name(wl_name)
        .ok_or_else(|| Error::config(format!("unknown workload '{wl_name}'")))?;
    let cfg = sampler_config(args)?;
    let n = args.get_usize("n", 512)?;
    let seed = args.get_u64("seed", 0)?;
    let exec = sadiff::exec::Executor::new(args.get_usize("threads", 0)?);
    let model = wl.model();
    let row = sadiff::coordinator::engine::evaluate_with(&*model, &wl, &cfg, n, seed, &exec);
    println!(
        "workload={wl_name} solver={} nfe={} tau={} n={n} threads={}",
        cfg.solver.name(),
        cfg.nfe,
        cfg.tau,
        exec.threads()
    );
    println!(
        "sim_fid={:.4} sliced_w2={:.4} nfe_used={} wall_s={:.3}",
        row.sim_fid, row.sliced_w2, row.nfe, row.wall_s
    );
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let mut client = Client::connect(addr)?;
    if let Some(action) = args.get("trace") {
        let reply = client.trace(action, args.get("out"))?;
        println!("{}", jsonlite::to_string(&reply));
        return Ok(());
    }
    if args.has("stats") {
        print_stats_table(&client.stats()?);
        return Ok(());
    }
    if let Some(spec) = args.get("resume") {
        let id = if spec == "all" {
            None
        } else {
            Some(spec.parse::<u64>().map_err(|_| {
                Error::config(format!("--resume: '{spec}' is not a request id (or 'all')"))
            })?)
        };
        let reply = client.recover(id)?;
        println!("{}", jsonlite::to_string(&reply));
        return Ok(());
    }
    if let Some(id) = args.get("cancel") {
        let id: u64 = id
            .parse()
            .map_err(|_| Error::config(format!("--cancel: '{id}' is not a request id")))?;
        let reply = client.cancel(id)?;
        println!("{}", jsonlite::to_string(&reply));
        return Ok(());
    }
    let req = SampleRequest {
        id: 1,
        workload: args.get_str("workload", "latent_analog").to_string(),
        model: args.get_str("model", "gmm").to_string(),
        cfg: sampler_config(args)?,
        n: args.get_usize("n", 16)?,
        seed: args.get_u64("seed", 0)?,
        return_samples: false,
        want_metrics: true,
        preset: args.get("preset").map(String::from),
        deadline_ms: None,
        priority: 0,
    };
    let resp = client.request(&req)?;
    println!("{}", resp.to_line());
    let stats = client.stats()?;
    println!("stats: {}", jsonlite::to_string(&stats));
    Ok(())
}

/// `sadiff loadgen`: spin an in-process server (or target `--addr`), run
/// one point per arrival spec, print a summary line per point and write
/// the `BENCH_loadgen.json` artifact.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use sadiff::loadgen::{self, Arrival, LoadgenOptions};
    let quick = args.has("quick");

    // External server via --addr; `--router K` spawns an in-process
    // fleet of K workers behind a router; otherwise one in-process
    // server on an ephemeral port so the run is hermetic (SLO knobs
    // apply to the spawned server/workers).
    let build_cfg = |args: &Args| -> Result<ServerConfig> {
        let mut cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..ServerConfig::default() };
        cfg.workers = args.get_usize("workers", cfg.workers)?;
        cfg.threads = args.get_usize("threads", cfg.threads)?;
        cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
        cfg.max_inflight = args.get_usize("max-inflight", cfg.max_inflight)?.max(1);
        cfg.queue_lane_cap = args.get_usize("queue-lane-cap", cfg.queue_lane_cap)?;
        cfg.reply_timeout_ms = args.get_u64("reply-timeout", cfg.reply_timeout_ms)?.max(1);
        cfg.max_step_lanes = args.get_usize("max-step-lanes", cfg.max_step_lanes)?;
        Ok(cfg)
    };
    let router_k = args.get_usize("router", 0)?;
    let mut handle = None;
    let mut fleet_handles = Vec::new();
    let mut router_handle = None;
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None if router_k > 0 => {
            use sadiff::coordinator::router::{Router, RouterConfig};
            for _ in 0..router_k {
                let mut cfg = build_cfg(args)?;
                cfg.publish_snapshots = true;
                fleet_handles.push(Server::bind(cfg)?.spawn()?);
            }
            let rcfg = RouterConfig {
                addr: "127.0.0.1:0".into(),
                workers: fleet_handles.iter().map(|h| h.addr.to_string()).collect(),
                placement: args.get_str("placement", "least_loaded").to_string(),
                ..RouterConfig::default()
            };
            let rh = Router::bind(rcfg)?.spawn();
            let a = rh.addr().to_string();
            println!("loadgen fleet: router {a} over {router_k} worker(s)");
            router_handle = Some(rh);
            a
        }
        None => {
            let h = Server::bind(build_cfg(args)?)?.spawn()?;
            let a = h.addr.to_string();
            handle = Some(h);
            a
        }
    };

    let mut base = LoadgenOptions::new(Arrival::Closed { concurrency: 4 });
    base.workload = args.get_str("workload", "latent_analog").to_string();
    base.model = args.get_str("model", "gmm").to_string();
    base.nfe = args.get_usize("nfe", if quick { 8 } else { 16 })?;
    base.n = args.get_usize("n", 4)?;
    base.seed = args.get_u64("seed", 0)?;
    base.duration_s = args.get_f64("duration", if quick { 1.5 } else { 5.0 })?;
    base.max_requests = args.get_usize("requests", if quick { 60 } else { 0 })?;
    let deadline = args.get_u64("deadline", 0)?;
    base.deadline_ms = if deadline > 0 { Some(deadline) } else { None };
    base.priority_span = args.get_u64("priorities", 1)?.max(1) as i64;

    // Point list: the primary --arrival point, then a poisson sweep from
    // --rates. --quick defaults to closed:4 plus one modest poisson point.
    let mut points: Vec<LoadgenOptions> = Vec::new();
    let mut first = base.clone();
    first.arrival = Arrival::parse(args.get_str("arrival", "closed:4"))?;
    points.push(first);
    let default_rates: &[f64] = if quick && args.get("arrival").is_none() { &[40.0] } else { &[] };
    for rate_rps in args.get_f64_list("rates", default_rates)? {
        if rate_rps <= 0.0 {
            return Err(Error::config(format!("--rates: rate {rate_rps} must be > 0")));
        }
        let mut p = base.clone();
        p.arrival = Arrival::Poisson { rate_rps };
        points.push(p);
    }

    let out_path = args.get_str("out", "BENCH_loadgen.json");
    let mut reports = Vec::new();
    for opts in &points {
        let report = loadgen::run(&addr, opts)?;
        println!("{}", report.summary_line());
        reports.push(report);
    }
    loadgen::write_bench(out_path, &reports)?;
    println!("wrote {out_path}");
    if let Some(mut r) = router_handle {
        r.shutdown();
    }
    for h in fleet_handles {
        h.shutdown();
    }
    if let Some(h) = handle {
        h.shutdown();
    }
    Ok(())
}

fn cmd_checkpoint(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .get(1)
        .ok_or_else(|| Error::config("usage: sadiff checkpoint <path>"))?;
    let ck = sadiff::coordinator::ServerCheckpoint::load(path)?;
    println!("checkpoint {path}:");
    for line in ck.describe() {
        println!("  {line}");
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .get(1)
        .ok_or_else(|| Error::config("usage: sadiff trace <path>"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::config(format!("cannot read {path}: {e}")))?;
    println!("trace {path}:");
    for line in sadiff::obs::chrome::describe(&text)? {
        println!("  {line}");
    }
    Ok(())
}

/// Render the `stats` snapshot as a table: headline counters, then one
/// row per pipeline stage with interpolated latency percentiles. An
/// overflow-bucket percentile serializes as JSON `null` and prints `inf`.
fn print_stats_table(stats: &Value) {
    let num = |k: &str| stats.get(k).and_then(Value::as_f64).unwrap_or(0.0);
    let ms = |v: &Value, k: &str| match v.get(k).and_then(Value::as_f64) {
        Some(x) => format!("{x:.3}"),
        None => "inf".to_string(),
    };
    println!("requests              {}", num("requests"));
    println!(
        "  ok / err / shed     {} / {} / {}",
        num("responses_ok"),
        num("responses_err"),
        num("shed")
    );
    println!("  timeout / deadline  {} / {}", num("timeouts"), num("deadline_miss"));
    println!("  cancelled           {}", num("cancelled"));
    println!("queued samples        {}", num("queued_samples"));
    println!("inflight groups/lanes {} / {}", num("inflight_groups"), num("inflight_lanes"));
    println!("steps (lane-steps)    {} ({})", num("steps"), num("step_lanes"));
    println!("batches               {}", num("batches"));
    println!("mean batch occupancy  {:.2}", num("mean_batch_occupancy"));
    println!("checkpoints written   {}", num("checkpoints_written"));
    println!("groups recovered      {}", num("groups_recovered"));
    println!(
        "latency ms            p50 {} / p95 {} / p99 {}",
        ms(stats, "latency_p50_ms"),
        ms(stats, "latency_p95_ms"),
        ms(stats, "latency_p99_ms")
    );
    let Some(stages) = stats.get("stages") else {
        return;
    };
    println!();
    println!("{:<18} {:>8} {:>10} {:>10} {:>10}", "stage", "count", "p50 ms", "p90 ms", "p99 ms");
    for stage in sadiff::coordinator::metrics::Stage::ALL {
        let Some(entry) = stages.get(stage.key()) else {
            continue;
        };
        println!(
            "{:<18} {:>8} {:>10} {:>10} {:>10}",
            stage.key(),
            entry.opt_f64("count", 0.0),
            ms(entry, "p50_ms"),
            ms(entry, "p90_ms"),
            ms(entry, "p99_ms")
        );
    }
}

fn cmd_tune(args: &Args) -> Result<()> {
    let wl_arg = args.get_str("workload", "all");
    let names: Vec<String> = if wl_arg == "all" {
        workloads::all_names().iter().map(|s| s.to_string()).collect()
    } else {
        wl_arg.split(',').map(|s| s.trim().to_string()).collect()
    };
    let budgets = args.get_usize_list("budgets", &[5, 10, 20])?;
    let out = args.get_str("out", "presets.json");
    let mut opts = if args.has("quick") { TuneOptions::quick() } else { TuneOptions::default() };
    opts.n = args.get_usize("n", opts.n)?;
    opts.seed = args.get_u64("seed", opts.seed)?;
    opts.refine_rounds = args.get_usize("refine", opts.refine_rounds)?;
    let exec = sadiff::exec::Executor::new(args.get_usize("threads", 0)?);

    let registry = tuner::tune(&names, &budgets, &opts, &exec)?;
    let mut table = Table::new(
        format!(
            "tuned presets (n={}, seed={}, {} evals)",
            opts.n, opts.seed, registry.search.evals
        ),
        &["preset", "solver", "pred", "corr", "tau", "selector", "sim_fid", "sliced_w2"],
    );
    for p in &registry.presets {
        table.row(vec![
            p.name.clone(),
            p.cfg.solver.name().to_string(),
            p.cfg.predictor_steps.to_string(),
            p.cfg.corrector_steps.to_string(),
            fmt_f(p.cfg.tau),
            p.cfg.selector.name().to_string(),
            fmt_f(p.sim_fid),
            fmt_f(p.sliced_w2),
        ]);
    }
    table.print();
    registry.save(out)?;
    println!("\nwrote {} presets to {out}", registry.presets.len());
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positionals
        .get(1)
        .ok_or_else(|| Error::config("usage: sadiff exp <id|list|all>"))?;
    let scale = Scale::from_quick_flag(args.has("quick"));
    match id.as_str() {
        "list" => {
            for id in exps::all_ids() {
                println!("{id}");
            }
            Ok(())
        }
        "all" => {
            for id in exps::all_ids() {
                exps::run_by_name(id, scale);
            }
            Ok(())
        }
        other => {
            if exps::run_by_name(other, scale) {
                Ok(())
            } else {
                Err(Error::config(format!(
                    "unknown experiment '{other}' (try `sadiff exp list`)"
                )))
            }
        }
    }
}

fn cmd_artifacts() -> Result<()> {
    let reg = sadiff::runtime::Registry::open_default()?;
    for name in reg.names() {
        let e = reg.entry(&name).unwrap();
        println!(
            "{name}: file={} inputs={:?} outputs={:?} meta={}",
            e.file,
            e.inputs,
            e.outputs,
            jsonlite::to_string(&e.meta)
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("sadiff {} — SA-Solver (NeurIPS 2023) reproduction", env!("CARGO_PKG_VERSION"));
    println!("workloads: {}", workloads::all_names().join(", "));
    let solvers: Vec<&str> = sadiff::config::SolverKind::all()
        .iter()
        .map(|k| k.name())
        .collect();
    println!("solvers:   {}", solvers.join(", "));
    println!("exps:      {}", exps::all_ids().join(", "));
    let _ = Value::Null; // keep jsonlite linked in info builds
    Ok(())
}
