//! Lagrange interpolation utilities on the log-SNR (λ) grid, plus the
//! stable exponential-polynomial moment integrals
//!
//!   I_k(a, h) = ∫_{-h}^{0} u^k e^{a u} du
//!
//! that make the SA-Solver coefficients b_{i-j} (Eqs. (15)/(18)) *exact*
//! for constant-τ pieces: each Lagrange basis l_j(λ) is expanded into
//! monomials of u = λ - λ_{i+1} and the b's become Σ_k c_{jk} I_k(a, h).

/// Monomial coefficients (ascending powers) of the Lagrange basis
/// polynomials for the given nodes, expressed in the nodes' own coordinate.
/// `coeffs[j][k]` multiplies u^k in l_j(u); l_j(nodes[m]) = δ_{jm}.
pub fn lagrange_basis_coeffs(nodes: &[f64]) -> Vec<Vec<f64>> {
    let s = nodes.len();
    let mut out = Vec::with_capacity(s);
    for j in 0..s {
        // Numerator polynomial Π_{m≠j} (u - nodes[m]), built incrementally.
        let mut poly = vec![0.0; s];
        poly[0] = 1.0;
        let mut deg = 0usize;
        let mut denom = 1.0;
        for m in 0..s {
            if m == j {
                continue;
            }
            denom *= nodes[j] - nodes[m];
            // poly <- poly * (u - nodes[m]); descending k keeps the update
            // in-place correct (poly[k+1] reads the *old* poly[k]).
            for k in (0..=deg).rev() {
                let c = poly[k];
                poly[k + 1] += c;
                poly[k] = -nodes[m] * c;
            }
            deg += 1;
        }
        for c in poly.iter_mut() {
            *c /= denom;
        }
        out.push(poly);
    }
    out
}

/// Evaluate a polynomial with ascending coefficients at `u` (Horner).
pub fn poly_eval(coeffs: &[f64], u: f64) -> f64 {
    let mut acc = 0.0;
    for &c in coeffs.iter().rev() {
        acc = acc * u + c;
    }
    acc
}

/// Lagrange interpolation value at `u` from (nodes, values) directly
/// (barycentric-free reference form; used as an oracle in tests).
pub fn lagrange_interp(nodes: &[f64], values: &[f64], u: f64) -> f64 {
    assert_eq!(nodes.len(), values.len());
    let mut acc = 0.0;
    for j in 0..nodes.len() {
        let mut l = 1.0;
        for m in 0..nodes.len() {
            if m != j {
                l *= (u - nodes[m]) / (nodes[j] - nodes[m]);
            }
        }
        acc += l * values[j];
    }
    acc
}

/// Moments I_k(a, h) = ∫_{-h}^{0} u^k e^{a u} du for k = 0..=kmax.
///
/// Recursion (integration by parts, exact):
///   I_0 = (1 - e^{-a h}) / a
///   I_k = -e^{-a h} (-h)^k / a - (k / a) I_{k-1}
/// with the a→0 limit I_k = -(-h)^{k+1} / (k+1) handled explicitly, and a
/// series fallback for |a h| « 1 where the recursion loses digits.
pub fn exp_moments(a: f64, h: f64, kmax: usize) -> Vec<f64> {
    assert!(h >= 0.0);
    let mut out = vec![0.0; kmax + 1];
    if h == 0.0 {
        return out;
    }
    if a.abs() * h < 1e-3 {
        // Series: I_k = Σ_{m≥0} a^m / m! * ∫_{-h}^0 u^{k+m} du
        //             = Σ_{m≥0} a^m / m! * ( -(-h)^{k+m+1} / (k+m+1) ).
        for (k, slot) in out.iter_mut().enumerate() {
            let mut term; // a^m / m!
            let mut acc = 0.0;
            let mut am = 1.0;
            let mut mfact = 1.0;
            for m in 0..30 {
                term = am / mfact;
                let p = k + m + 1;
                let base = -(-h).powi(p as i32) / p as f64;
                acc += term * base;
                am *= a;
                mfact *= (m + 1) as f64;
                if term.abs() * h.powi(p as i32) < 1e-300 {
                    break;
                }
            }
            *slot = acc;
        }
        return out;
    }
    let emah = (-a * h).exp();
    out[0] = (1.0 - emah) / a;
    for k in 1..=kmax {
        out[k] = -emah * (-h).powi(k as i32) / a - (k as f64 / a) * out[k - 1];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quad::GaussLegendre;
    use crate::util::close;

    #[test]
    fn basis_kronecker_property() {
        let nodes = [-3.0, -1.5, -0.4, 0.0];
        let cs = lagrange_basis_coeffs(&nodes);
        for (j, c) in cs.iter().enumerate() {
            for (m, nm) in nodes.iter().enumerate() {
                let v = poly_eval(c, *nm);
                let want = if j == m { 1.0 } else { 0.0 };
                assert!(close(v, want, 1e-10, 1e-10), "l_{j}({nm}) = {v}");
            }
        }
    }

    #[test]
    fn basis_partition_of_unity() {
        let nodes = [-2.0, -1.0, -0.25];
        let cs = lagrange_basis_coeffs(&nodes);
        for u in [-2.5, -1.7, -0.1, 0.3] {
            let s: f64 = cs.iter().map(|c| poly_eval(c, u)).sum();
            assert!(close(s, 1.0, 1e-12, 0.0), "sum at {u} = {s}");
        }
    }

    #[test]
    fn interp_reproduces_polynomial() {
        // Degree-2 polynomial through 3 points is exact.
        let f = |x: f64| 2.0 * x * x - x + 0.5;
        let nodes = [-1.0, 0.0, 2.0];
        let vals: Vec<f64> = nodes.iter().map(|x| f(*x)).collect();
        for u in [-0.5, 1.0, 3.0] {
            assert!(close(lagrange_interp(&nodes, &vals, u), f(u), 1e-12, 0.0));
        }
    }

    #[test]
    fn exp_moments_vs_quadrature() {
        let gl = GaussLegendre::new(48);
        for &a in &[2.0, 0.5, -1.0, 1e-6, 0.0] {
            for &h in &[0.7, 0.05, 2.0] {
                let ms = exp_moments(a, h, 4);
                for (k, m) in ms.iter().enumerate() {
                    let q = gl.integrate(-h, 0.0, |u| u.powi(k as i32) * (a * u).exp());
                    assert!(
                        close(*m, q, 1e-10, 1e-12),
                        "a={a} h={h} k={k}: exact={m} quad={q}"
                    );
                }
            }
        }
    }

    #[test]
    fn exp_moments_zero_h() {
        let ms = exp_moments(1.5, 0.0, 3);
        assert!(ms.iter().all(|m| *m == 0.0));
    }
}
