//! Workload analogs of the paper's evaluation datasets (DESIGN.md §2) and
//! the request-trace generator for serving benchmarks.
//!
//! Each workload pins (noise schedule, dimension, target distribution) so
//! that the solver-relevant structure of the paper's dataset/model pair is
//! preserved: CIFAR10+EDM-VE ↦ VE schedule; ImageNet64+ADM ↦ VP-cosine;
//! LSUN-Bedroom+ADM ↦ VP-linear; ImageNet256-latent ↦ low-dim VP-linear.

use crate::gmm::Gmm;
use crate::models::{GmmAnalytic, ModelEval};
use crate::rng::Xoshiro256pp;
use crate::schedule::NoiseSchedule;

/// A named workload: schedule + target distribution + metric dimension.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub schedule: NoiseSchedule,
    pub gmm: Gmm,
}

impl Workload {
    /// The exact-score model for this workload.
    pub fn model(&self) -> Box<dyn ModelEval> {
        Box::new(GmmAnalytic::new(self.gmm.clone()))
    }

    pub fn dim(&self) -> usize {
        self.gmm.dim
    }

    /// Ground-truth reference samples (from the prior).
    pub fn reference(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::new(seed ^ 0xfeed_beef);
        self.gmm.sample(&mut rng, n)
    }
}

/// CIFAR10 32×32 analog: EDM baseline-VE regime (paper Fig. 1a/2a, Tab. 2/4/5/11).
pub fn cifar_analog() -> Workload {
    Workload {
        name: "cifar_analog",
        schedule: NoiseSchedule::ve(),
        gmm: Gmm::structured(32, 8, 3.0, 101),
    }
}

/// ImageNet 64×64 analog: ADM VP-cosine regime (Fig. 1b/2b, Tab. 6/7/12).
pub fn imagenet64_analog() -> Workload {
    Workload {
        name: "imagenet64_analog",
        schedule: NoiseSchedule::vp_cosine(),
        gmm: Gmm::structured(64, 10, 3.5, 202),
    }
}

/// LSUN Bedroom 256×256 analog: ADM VP-linear pixel regime (Fig. 1d, Tab. 14).
pub fn bedroom_analog() -> Workload {
    Workload {
        name: "bedroom_analog",
        schedule: NoiseSchedule::vp_linear(),
        gmm: Gmm::structured(48, 6, 2.5, 303),
    }
}

/// ImageNet 256×256 *latent*-diffusion analog: low-dim VP-linear
/// (Fig. 1c/2c, Tab. 1/10/13). Latent spaces are low-dimensional and
/// smoother — fewer, broader modes.
pub fn latent_analog() -> Workload {
    Workload {
        name: "latent_analog",
        schedule: NoiseSchedule::vp_linear(),
        gmm: Gmm::structured(16, 5, 2.0, 404),
    }
}

/// Look up a workload by name.
pub fn by_name(name: &str) -> Option<Workload> {
    match name {
        "cifar_analog" => Some(cifar_analog()),
        "imagenet64_analog" => Some(imagenet64_analog()),
        "bedroom_analog" => Some(bedroom_analog()),
        "latent_analog" => Some(latent_analog()),
        _ => None,
    }
}

/// All workload names.
pub fn all_names() -> &'static [&'static str] {
    &["cifar_analog", "imagenet64_analog", "bedroom_analog", "latent_analog"]
}

/// One request in a serving trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Samples requested.
    pub n: usize,
    /// NFE requested.
    pub nfe: usize,
    pub seed: u64,
}

/// Poisson-arrival request trace with mixed request sizes, for the serving
/// benchmarks (batch-occupancy and latency experiments).
pub fn poisson_trace(
    rate_per_s: f64,
    duration_s: f64,
    n_choices: &[usize],
    nfe_choices: &[usize],
    seed: u64,
) -> Vec<TraceRequest> {
    assert!(rate_per_s > 0.0 && !n_choices.is_empty() && !nfe_choices.is_empty());
    let mut rng = Xoshiro256pp::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    loop {
        t += rng.exponential(rate_per_s);
        if t >= duration_s {
            break;
        }
        out.push(TraceRequest {
            arrival_s: t,
            n: n_choices[rng.below(n_choices.len() as u64) as usize],
            nfe: nfe_choices[rng.below(nfe_choices.len() as u64) as usize],
            seed: rng.next_u64(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn lookup_roundtrip() {
        for name in all_names() {
            let wl = by_name(name).unwrap();
            assert_eq!(wl.name, *name);
            assert!(wl.dim() >= 16);
        }
        assert!(by_name("nope").is_none());
        assert!(by_name("").is_none());
        // all_names is exactly the set by_name accepts (no dangling names,
        // no duplicates).
        let unique: std::collections::BTreeSet<&str> = all_names().iter().copied().collect();
        assert_eq!(unique.len(), all_names().len());
    }

    #[test]
    fn reference_reproducible() {
        let wl = latent_analog();
        assert_eq!(wl.reference(8, 1), wl.reference(8, 1));
        assert_ne!(wl.reference(8, 1), wl.reference(8, 2));
    }

    #[test]
    fn reference_deterministic_for_every_workload() {
        // The tuner scores against `reference`; a nondeterministic
        // reference would make tuned registries irreproducible.
        for name in all_names() {
            let wl = by_name(name).unwrap();
            let a = wl.reference(16, 42);
            let b = wl.reference(16, 42);
            assert_eq!(a, b, "{name}: reference not reproducible");
            assert_eq!(a.len(), 16 * wl.dim(), "{name}: wrong layout");
            assert!(a.iter().all(|v| v.is_finite()), "{name}: non-finite reference");
            assert_ne!(a, wl.reference(16, 43), "{name}: seed ignored");
        }
    }

    #[test]
    fn workload_model_dim_matches() {
        for name in all_names() {
            let wl = by_name(name).unwrap();
            assert_eq!(wl.model().dim(), wl.dim(), "{name}");
        }
    }

    #[test]
    fn trace_statistics() {
        let tr = poisson_trace(50.0, 10.0, &[1, 4], &[10, 20], 7);
        // ~500 expected arrivals.
        assert!((300..700).contains(&tr.len()), "len={}", tr.len());
        for w in tr.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(tr.iter().all(|r| r.arrival_s < 10.0));
        let mean_gap = tr.last().unwrap().arrival_s / tr.len() as f64;
        assert!(close(mean_gap, 0.02, 0.3, 0.0), "gap={mean_gap}");
        // Reproducible.
        assert_eq!(tr, poisson_trace(50.0, 10.0, &[1, 4], &[10, 20], 7));
    }
}
