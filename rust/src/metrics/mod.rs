//! Statistical distances between sample sets — the evaluation layer that
//! stands in for FID (DESIGN.md §2).
//!
//! * `sim_fid` — Fréchet distance between Gaussian fits of the two sets
//!   (identical functional form to FID; the "feature space" is the ambient
//!   space for GMM workloads, random projections for image-like ones).
//! * `sliced_w2` — sliced Wasserstein-2 via random 1-D projections.
//! * `w2_1d` — exact 1-D Wasserstein-2 (sorted quantile coupling).
//! * `mmd_rbf` — RBF-kernel MMD² (unbiased) with a median heuristic.
//! * `energy_distance` — Székely's energy distance.

use crate::linalg::Mat;
use crate::rng::Xoshiro256pp;
use crate::util::error::{Error, Result};

/// Mean vector and covariance matrix of row-major `n × dim` samples.
pub fn mean_cov(samples: &[f64], dim: usize) -> Result<(Vec<f64>, Mat)> {
    if dim == 0 || samples.is_empty() || samples.len() % dim != 0 {
        return Err(Error::numerics("mean_cov: bad sample layout"));
    }
    let n = samples.len() / dim;
    if n < 2 {
        return Err(Error::numerics("mean_cov: need at least 2 samples"));
    }
    let mut mu = vec![0.0; dim];
    for i in 0..n {
        for d in 0..dim {
            mu[d] += samples[i * dim + d];
        }
    }
    for m in mu.iter_mut() {
        *m /= n as f64;
    }
    let mut cov = Mat::zeros(dim, dim);
    for i in 0..n {
        let row = &samples[i * dim..(i + 1) * dim];
        for a in 0..dim {
            let da = row[a] - mu[a];
            for b in a..dim {
                cov[(a, b)] += da * (row[b] - mu[b]);
            }
        }
    }
    let denom = (n - 1) as f64;
    for a in 0..dim {
        for b in a..dim {
            let v = cov[(a, b)] / denom;
            cov[(a, b)] = v;
            cov[(b, a)] = v;
        }
    }
    Ok((mu, cov))
}

/// Fréchet distance² between two Gaussians:
/// |μ₁−μ₂|² + tr(Σ₁ + Σ₂ − 2 (Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2}).
pub fn frechet_gaussian(mu1: &[f64], cov1: &Mat, mu2: &[f64], cov2: &Mat) -> f64 {
    let d2: f64 = mu1
        .iter()
        .zip(mu2)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let s1h = cov1.psd_sqrt();
    let inner = s1h.matmul(cov2).matmul(&s1h);
    let cross = inner.psd_sqrt();
    (d2 + cov1.trace() + cov2.trace() - 2.0 * cross.trace()).max(0.0)
}

/// sim-FID between two row-major sample sets.
pub fn sim_fid(a: &[f64], b: &[f64], dim: usize) -> Result<f64> {
    let (mu_a, cov_a) = mean_cov(a, dim)?;
    let (mu_b, cov_b) = mean_cov(b, dim)?;
    Ok(frechet_gaussian(&mu_a, &cov_a, &mu_b, &cov_b))
}

/// Exact 1-D Wasserstein-2 distance between equal-size samples.
pub fn w2_1d(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut x = a.to_vec();
    let mut y = b.to_vec();
    x.sort_by(|p, q| p.partial_cmp(q).unwrap());
    y.sort_by(|p, q| p.partial_cmp(q).unwrap());
    let n = x.len() as f64;
    (x.iter().zip(&y).map(|(p, q)| (p - q) * (p - q)).sum::<f64>() / n).sqrt()
}

/// Sliced Wasserstein-2: average of 1-D W2 over `n_proj` random directions.
pub fn sliced_w2(a: &[f64], b: &[f64], dim: usize, n_proj: usize, seed: u64) -> f64 {
    assert_eq!(a.len() % dim, 0);
    assert_eq!(b.len() % dim, 0);
    let na = a.len() / dim;
    let nb = b.len() / dim;
    let n = na.min(nb);
    let mut rng = Xoshiro256pp::new(seed);
    let mut total = 0.0;
    let mut pa = vec![0.0; n];
    let mut pb = vec![0.0; n];
    for _ in 0..n_proj {
        let dir = {
            let raw = rng.normals(dim);
            let nz = crate::linalg::norm2(&raw).max(1e-12);
            raw.into_iter().map(|x| x / nz).collect::<Vec<_>>()
        };
        for i in 0..n {
            pa[i] = crate::linalg::dot(&a[i * dim..(i + 1) * dim], &dir);
            pb[i] = crate::linalg::dot(&b[i * dim..(i + 1) * dim], &dir);
        }
        let w = w2_1d(&pa, &pb);
        total += w * w;
    }
    (total / n_proj as f64).sqrt()
}

/// Unbiased RBF-MMD² with bandwidth = median pairwise distance of the
/// pooled set (subsampled for cost). Can be slightly negative by design
/// of the unbiased estimator.
pub fn mmd_rbf(a: &[f64], b: &[f64], dim: usize) -> f64 {
    let na = a.len() / dim;
    let nb = b.len() / dim;
    assert!(na > 1 && nb > 1);
    let bw2 = median_sq_dist(a, b, dim).max(1e-12);
    let k = |x: &[f64], y: &[f64]| {
        let d2: f64 = x.iter().zip(y).map(|(p, q)| (p - q) * (p - q)).sum();
        (-d2 / (2.0 * bw2)).exp()
    };
    fn row(s: &[f64], i: usize, dim: usize) -> &[f64] { &s[i * dim..(i + 1) * dim] }
    let mut kaa = 0.0;
    for i in 0..na {
        for j in 0..na {
            if i != j {
                kaa += k(row(a, i, dim), row(a, j, dim));
            }
        }
    }
    kaa /= (na * (na - 1)) as f64;
    let mut kbb = 0.0;
    for i in 0..nb {
        for j in 0..nb {
            if i != j {
                kbb += k(row(b, i, dim), row(b, j, dim));
            }
        }
    }
    kbb /= (nb * (nb - 1)) as f64;
    let mut kab = 0.0;
    for i in 0..na {
        for j in 0..nb {
            kab += k(row(a, i, dim), row(b, j, dim));
        }
    }
    kab /= (na * nb) as f64;
    kaa + kbb - 2.0 * kab
}

/// Median of squared pairwise distances (subsampled to ≤256 points/side).
fn median_sq_dist(a: &[f64], b: &[f64], dim: usize) -> f64 {
    let na = (a.len() / dim).min(256);
    let nb = (b.len() / dim).min(256);
    let mut d2s = Vec::with_capacity(na * nb);
    for i in 0..na {
        for j in 0..nb {
            let d2: f64 = a[i * dim..(i + 1) * dim]
                .iter()
                .zip(&b[j * dim..(j + 1) * dim])
                .map(|(p, q)| (p - q) * (p - q))
                .sum();
            d2s.push(d2);
        }
    }
    d2s.sort_by(|p, q| p.partial_cmp(q).unwrap());
    d2s[d2s.len() / 2]
}

/// Energy distance: 2 E|X−Y| − E|X−X'| − E|Y−Y'|.
pub fn energy_distance(a: &[f64], b: &[f64], dim: usize) -> f64 {
    let na = a.len() / dim;
    let nb = b.len() / dim;
    assert!(na > 1 && nb > 1);
    fn row(s: &[f64], i: usize, dim: usize) -> &[f64] { &s[i * dim..(i + 1) * dim] }
    let dist = |x: &[f64], y: &[f64]| {
        x.iter()
            .zip(y)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt()
    };
    let mut exy = 0.0;
    for i in 0..na {
        for j in 0..nb {
            exy += dist(row(a, i, dim), row(b, j, dim));
        }
    }
    exy /= (na * nb) as f64;
    let mut exx = 0.0;
    for i in 0..na {
        for j in 0..na {
            exx += dist(row(a, i, dim), row(a, j, dim));
        }
    }
    exx /= (na * na) as f64;
    let mut eyy = 0.0;
    for i in 0..nb {
        for j in 0..nb {
            eyy += dist(row(b, i, dim), row(b, j, dim));
        }
    }
    eyy /= (nb * nb) as f64;
    2.0 * exy - exx - eyy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    fn gaussian_samples(n: usize, dim: usize, mu: f64, sd: f64, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n * dim).map(|_| mu + sd * rng.normal()).collect()
    }

    #[test]
    fn mean_cov_basic() {
        // Two points: mean is midpoint, covariance from the spread.
        let s = vec![0.0, 0.0, 2.0, 2.0];
        let (mu, cov) = mean_cov(&s, 2).unwrap();
        assert_eq!(mu, vec![1.0, 1.0]);
        assert!(close(cov[(0, 0)], 2.0, 1e-12, 0.0));
        assert!(close(cov[(0, 1)], 2.0, 1e-12, 0.0));
        assert!(mean_cov(&s, 3).is_err());
        assert!(mean_cov(&s[..2], 2).is_err());
    }

    #[test]
    fn frechet_identical_zero() {
        let a = gaussian_samples(2000, 3, 0.5, 1.2, 1);
        let f = sim_fid(&a, &a, 3).unwrap();
        assert!(f < 1e-9, "f={f}");
    }

    #[test]
    fn frechet_mean_shift_exact() {
        // Equal covariances ⇒ FD² = |Δμ|² exactly (analytic check).
        let mu1 = vec![0.0, 0.0];
        let mu2 = vec![3.0, 4.0];
        let cov = Mat::eye(2);
        let f = frechet_gaussian(&mu1, &cov, &mu2, &cov);
        assert!(close(f, 25.0, 1e-10, 0.0), "f={f}");
    }

    #[test]
    fn frechet_variance_shift_exact() {
        // 1-D: FD² = (σ1−σ2)².
        let cov1 = Mat::diag(&[4.0]);
        let cov2 = Mat::diag(&[1.0]);
        let f = frechet_gaussian(&[0.0], &cov1, &[0.0], &cov2);
        assert!(close(f, 1.0, 1e-10, 0.0), "f={f}");
    }

    #[test]
    fn sim_fid_detects_shift() {
        let a = gaussian_samples(4000, 4, 0.0, 1.0, 1);
        let b = gaussian_samples(4000, 4, 1.0, 1.0, 2);
        let same = sim_fid(&a, &gaussian_samples(4000, 4, 0.0, 1.0, 3), 4).unwrap();
        let diff = sim_fid(&a, &b, 4).unwrap();
        assert!(diff > 10.0 * same.max(1e-3), "same={same} diff={diff}");
        assert!(close(diff, 4.0, 0.15, 0.0), "diff={diff} (≈|Δμ|²=4)");
    }

    #[test]
    fn w2_1d_analytic() {
        // Point masses: W2 between {0} and {1} (constant shift) is 1.
        let a = vec![0.0; 64];
        let b = vec![1.0; 64];
        assert!(close(w2_1d(&a, &b), 1.0, 1e-12, 0.0));
    }

    #[test]
    fn sliced_w2_shift() {
        let a = gaussian_samples(3000, 3, 0.0, 1.0, 4);
        let b = gaussian_samples(3000, 3, 2.0, 1.0, 5);
        let w = sliced_w2(&a, &b, 3, 32, 0);
        // E[(u·Δμ)²] over unit u = |Δμ|²/d = 4 ⇒ sliced-W2 ≈ 2.
        assert!(close(w, 2.0, 0.2, 0.0), "w={w}");
    }

    #[test]
    fn mmd_discriminates() {
        let a = gaussian_samples(200, 2, 0.0, 1.0, 6);
        let b = gaussian_samples(200, 2, 0.0, 1.0, 7);
        let c = gaussian_samples(200, 2, 3.0, 1.0, 8);
        let same = mmd_rbf(&a, &b, 2);
        let diff = mmd_rbf(&a, &c, 2);
        assert!(same.abs() < 0.05, "same={same}");
        assert!(diff > 0.2, "diff={diff}");
    }

    #[test]
    fn energy_distance_properties() {
        let a = gaussian_samples(300, 2, 0.0, 1.0, 9);
        let b = gaussian_samples(300, 2, 1.5, 1.0, 10);
        let same = energy_distance(&a, &gaussian_samples(300, 2, 0.0, 1.0, 11), 2);
        let diff = energy_distance(&a, &b, 2);
        assert!(diff > same, "same={same} diff={diff}");
        assert!(diff > 0.0);
    }
}
