//! Arrival processes for the load generator.
//!
//! Open-loop processes (Poisson, bursty on/off, diurnal replay) precompute
//! a deterministic schedule of arrival offsets from a seed — offered load
//! is independent of how the server responds, which is what makes latency
//! under overload measurable. The closed-loop process has no schedule: a
//! fixed pool of clients issues the next request as soon as the previous
//! reply lands, so offered load tracks service capacity.

use crate::rng::Xoshiro256pp;
use crate::util::error::{Error, Result};

/// An arrival process driving one loadgen run.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Open loop: Poisson arrivals at a constant rate (requests/second).
    Poisson {
        /// Mean arrival rate, requests per second.
        rate_rps: f64,
    },
    /// Open loop: alternating on/off windows — `burst_rps` during an
    /// on-window of `on_s` seconds, `base_rps` during an off-window of
    /// `off_s` seconds, starting with an on-window.
    Bursty {
        /// Arrival rate inside off-windows, requests per second.
        base_rps: f64,
        /// Arrival rate inside on-windows, requests per second.
        burst_rps: f64,
        /// On-window length, seconds.
        on_s: f64,
        /// Off-window length, seconds.
        off_s: f64,
    },
    /// Open loop: diurnal replay of a rate trace — piecewise-constant
    /// Poisson rates, one per `bin_s`-second bin, cycled over the run.
    Replay {
        /// Per-bin arrival rates, requests per second.
        rates_rps: Vec<f64>,
        /// Bin length, seconds.
        bin_s: f64,
    },
    /// Closed loop: `concurrency` clients, each issuing its next request
    /// the moment the previous reply (or error) lands.
    Closed {
        /// Number of concurrent clients.
        concurrency: usize,
    },
}

impl Arrival {
    /// Parse a CLI arrival spec:
    /// `poisson:<rps>` | `closed:<concurrency>` |
    /// `bursty:<base_rps>,<burst_rps>,<on_s>,<off_s>` |
    /// `replay:<r1>,<r2>,...[@<bin_s>]` (bin length defaults to 1 s).
    pub fn parse(spec: &str) -> Result<Arrival> {
        let (kind, rest) = spec
            .split_once(':')
            .ok_or_else(|| Error::config(format!("arrival '{spec}': expected <kind>:<params>")))?;
        let f = |s: &str| -> Result<f64> {
            s.trim()
                .parse::<f64>()
                .map_err(|_| Error::config(format!("arrival '{spec}': bad number '{s}'")))
        };
        match kind {
            "poisson" => {
                let rate_rps = f(rest)?;
                if rate_rps <= 0.0 {
                    return Err(Error::config(format!("arrival '{spec}': rate must be > 0")));
                }
                Ok(Arrival::Poisson { rate_rps })
            }
            "closed" => {
                let concurrency = rest.trim().parse::<usize>().map_err(|_| {
                    Error::config(format!("arrival '{spec}': bad concurrency '{rest}'"))
                })?;
                if concurrency == 0 {
                    return Err(Error::config(format!(
                        "arrival '{spec}': concurrency must be > 0"
                    )));
                }
                Ok(Arrival::Closed { concurrency })
            }
            "bursty" => {
                let parts: Vec<&str> = rest.split(',').collect();
                if parts.len() != 4 {
                    return Err(Error::config(format!(
                        "arrival '{spec}': bursty needs base_rps,burst_rps,on_s,off_s"
                    )));
                }
                let (base_rps, burst_rps) = (f(parts[0])?, f(parts[1])?);
                let (on_s, off_s) = (f(parts[2])?, f(parts[3])?);
                if burst_rps <= 0.0 || base_rps < 0.0 || on_s <= 0.0 || off_s < 0.0 {
                    return Err(Error::config(format!("arrival '{spec}': bad bursty window")));
                }
                Ok(Arrival::Bursty { base_rps, burst_rps, on_s, off_s })
            }
            "replay" => {
                let (rates, bin_s) = match rest.split_once('@') {
                    Some((r, b)) => (r, f(b)?),
                    None => (rest, 1.0),
                };
                if bin_s <= 0.0 {
                    return Err(Error::config(format!("arrival '{spec}': bin must be > 0")));
                }
                let rates_rps = rates.split(',').map(f).collect::<Result<Vec<f64>>>()?;
                if rates_rps.is_empty() || rates_rps.iter().any(|r| *r < 0.0) {
                    return Err(Error::config(format!("arrival '{spec}': bad rate trace")));
                }
                Ok(Arrival::Replay { rates_rps, bin_s })
            }
            other => Err(Error::config(format!(
                "arrival '{spec}': unknown kind '{other}' (poisson|bursty|replay|closed)"
            ))),
        }
    }

    /// Short mode name for reports.
    pub fn mode(&self) -> &'static str {
        match self {
            Arrival::Poisson { .. } => "poisson",
            Arrival::Bursty { .. } => "bursty",
            Arrival::Replay { .. } => "replay",
            Arrival::Closed { .. } => "closed",
        }
    }

    /// Deterministic open-loop schedule: arrival offsets (seconds from run
    /// start, strictly increasing) over `duration_s`, generated from
    /// `seed`. `None` for the closed-loop process (it has no schedule).
    pub fn schedule(&self, duration_s: f64, seed: u64) -> Option<Vec<f64>> {
        let mut rng = Xoshiro256pp::new(seed ^ 0x10adc0de);
        match self {
            Arrival::Closed { .. } => None,
            Arrival::Poisson { rate_rps } => {
                Some(piecewise(&mut rng, duration_s, |_| (duration_s, *rate_rps)))
            }
            Arrival::Bursty { base_rps, burst_rps, on_s, off_s } => {
                let (on, off) = (*on_s, (*off_s).max(1e-9));
                let (hi, lo) = (*burst_rps, *base_rps);
                Some(piecewise(&mut rng, duration_s, move |i| {
                    if i % 2 == 0 {
                        (on, hi)
                    } else {
                        (off, lo)
                    }
                }))
            }
            Arrival::Replay { rates_rps, bin_s } => {
                let rates = rates_rps.clone();
                let bin = *bin_s;
                Some(piecewise(&mut rng, duration_s, move |i| {
                    (bin, rates[i % rates.len()])
                }))
            }
        }
    }

    /// Planned offered load in requests/second over `duration_s`: the
    /// time-weighted mean rate for open-loop processes, `None` for closed
    /// loop (offered load is whatever the server sustains).
    pub fn offered_rps(&self, duration_s: f64) -> Option<f64> {
        match self {
            Arrival::Closed { .. } => None,
            Arrival::Poisson { rate_rps } => Some(*rate_rps),
            Arrival::Bursty { base_rps, burst_rps, on_s, off_s } => {
                let period = on_s + off_s;
                if period <= 0.0 {
                    return Some(*burst_rps);
                }
                Some((burst_rps * on_s + base_rps * off_s) / period)
            }
            Arrival::Replay { rates_rps, bin_s } => {
                let mut mass = 0.0;
                let mut t = 0.0;
                let mut i = 0usize;
                while t < duration_s {
                    let len = bin_s.min(duration_s - t);
                    mass += rates_rps[i % rates_rps.len()] * len;
                    t += bin_s;
                    i += 1;
                }
                Some(mass / duration_s.max(1e-9))
            }
        }
    }
}

/// Generate Poisson arrivals over piecewise-constant rate segments:
/// `segment(i)` yields the i-th segment's `(length_s, rate_rps)`; the walk
/// stops at `duration_s`. Exponential inter-arrival gaps within a segment,
/// zero-rate segments produce no arrivals.
fn piecewise(
    rng: &mut Xoshiro256pp,
    duration_s: f64,
    segment: impl Fn(usize) -> (f64, f64),
) -> Vec<f64> {
    let mut out = Vec::new();
    let mut seg_start = 0.0f64;
    let mut i = 0usize;
    while seg_start < duration_s {
        let (len, rate) = segment(i);
        let len = len.max(1e-9);
        let seg_end = (seg_start + len).min(duration_s);
        if rate > 0.0 {
            let mut t = seg_start + rng.exponential(rate);
            while t < seg_end {
                out.push(t);
                t += rng.exponential(rate);
            }
        }
        seg_start += len;
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(Arrival::parse("poisson:80").unwrap(), Arrival::Poisson { rate_rps: 80.0 });
        assert_eq!(Arrival::parse("closed:8").unwrap(), Arrival::Closed { concurrency: 8 });
        assert_eq!(
            Arrival::parse("bursty:10,200,0.5,1.5").unwrap(),
            Arrival::Bursty { base_rps: 10.0, burst_rps: 200.0, on_s: 0.5, off_s: 1.5 }
        );
        assert_eq!(
            Arrival::parse("replay:1,5,20@0.5").unwrap(),
            Arrival::Replay { rates_rps: vec![1.0, 5.0, 20.0], bin_s: 0.5 }
        );
        assert_eq!(
            Arrival::parse("replay:2,4").unwrap(),
            Arrival::Replay { rates_rps: vec![2.0, 4.0], bin_s: 1.0 }
        );
        for bad in [
            "poisson", "poisson:0", "poisson:x", "closed:0", "bursty:1,2,3", "replay:@1",
            "replay:-1,2", "warp:9",
        ] {
            assert!(Arrival::parse(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn schedules_are_deterministic_and_ordered() {
        for spec in ["poisson:200", "bursty:20,400,0.25,0.25", "replay:50,300@0.5"] {
            let a = Arrival::parse(spec).unwrap();
            let s1 = a.schedule(2.0, 7).unwrap();
            let s2 = a.schedule(2.0, 7).unwrap();
            assert_eq!(s1, s2, "{spec}: same seed must give the same schedule");
            let s3 = a.schedule(2.0, 8).unwrap();
            assert_ne!(s1, s3, "{spec}: different seed must differ");
            assert!(s1.windows(2).all(|w| w[0] <= w[1]), "{spec}: offsets sorted");
            assert!(s1.iter().all(|t| (0.0..2.0).contains(t)), "{spec}: within horizon");
        }
        assert!(Arrival::parse("closed:4").unwrap().schedule(2.0, 7).is_none());
    }

    #[test]
    fn poisson_rate_is_roughly_met() {
        let a = Arrival::Poisson { rate_rps: 500.0 };
        let n = a.schedule(4.0, 42).unwrap().len() as f64;
        // Poisson(2000): ±5σ ≈ ±224.
        assert!((n - 2000.0).abs() < 250.0, "got {n} arrivals for mean 2000");
        assert_eq!(a.offered_rps(4.0), Some(500.0));
    }

    #[test]
    fn bursty_on_windows_carry_the_mass() {
        let a = Arrival::Bursty { base_rps: 5.0, burst_rps: 500.0, on_s: 0.5, off_s: 0.5 };
        let sched = a.schedule(2.0, 9).unwrap();
        // On-windows are [0,0.5) and [1.0,1.5).
        let on = sched
            .iter()
            .filter(|t| (t.rem_euclid(1.0)) < 0.5)
            .count();
        let off = sched.len() - on;
        assert!(on > 10 * off.max(1), "bursts must dominate: on={on} off={off}");
        let offered = a.offered_rps(2.0).unwrap();
        assert!((offered - 252.5).abs() < 1e-9);
    }

    #[test]
    fn replay_follows_the_trace() {
        let a = Arrival::Replay { rates_rps: vec![0.0, 400.0], bin_s: 0.5 };
        let sched = a.schedule(2.0, 3).unwrap();
        assert!(!sched.is_empty());
        // Zero-rate bins ([0,0.5) and [1.0,1.5)) produce no arrivals.
        assert!(sched.iter().all(|t| t.rem_euclid(1.0) >= 0.5));
        assert_eq!(a.offered_rps(2.0), Some(200.0));
    }
}
