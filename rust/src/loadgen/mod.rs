//! Load generator for the serving path: drives a `sadiff serve` endpoint
//! with open-loop (Poisson, bursty, diurnal replay) or closed-loop
//! (fixed-concurrency) traffic over the newline-delimited line protocol,
//! classifies every reply against the typed error taxonomy
//! (`shed`/`deadline`/`timeout`), and reports latency percentiles,
//! goodput vs. offered load and per-step lane utilization.
//!
//! Open loop measures *latency under offered load* — arrivals do not slow
//! down when the server does, so queueing and shedding become visible.
//! Closed loop measures *capacity* — each of `concurrency` clients keeps
//! exactly one request in flight.

pub mod arrival;
pub mod report;

pub use arrival::Arrival;
pub use report::{bench_json, write_bench, LaneUtil, RunReport};

use crate::config::SamplerConfig;
use crate::coordinator::server::Client;
use crate::coordinator::{SampleRequest, SampleResponse};
use crate::util::error::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How one loadgen request ended, classified from the wire reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Successful sample response.
    Ok,
    /// Typed `shed` reply: admission backpressure, retry later.
    Shed,
    /// Typed `deadline` reply: latency budget expired before admission.
    DeadlineMiss,
    /// Typed `timeout` reply from the server, or a transport failure.
    Timeout,
    /// Any other error reply.
    OtherError,
}

/// Classify a wire reply against the typed error taxonomy.
pub fn classify(resp: &SampleResponse) -> Outcome {
    if resp.ok {
        return Outcome::Ok;
    }
    match resp.kind.as_deref() {
        Some("shed") => Outcome::Shed,
        Some("deadline") => Outcome::DeadlineMiss,
        Some("timeout") => Outcome::Timeout,
        _ => Outcome::OtherError,
    }
}

/// One loadgen run's knobs.
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Arrival process driving the run.
    pub arrival: Arrival,
    /// Run length in seconds (open loop: schedule horizon; closed loop:
    /// wall-clock stop condition, ignored when ≤ 0 and `max_requests` set).
    pub duration_s: f64,
    /// Hard cap on requests issued (0 = no cap; closed loop requires a cap
    /// or a positive duration).
    pub max_requests: usize,
    /// Workload name for every request.
    pub workload: String,
    /// Model name for every request.
    pub model: String,
    /// Solver NFE per request.
    pub nfe: usize,
    /// Lanes (samples) per request.
    pub n: usize,
    /// Optional per-request latency budget, milliseconds.
    pub deadline_ms: Option<u64>,
    /// When > 1, request `i` gets priority `i % priority_span` so the run
    /// exercises priority-aware admission; 1 leaves every request at the
    /// default priority 0.
    pub priority_span: i64,
    /// Base seed: request `i` samples with `seed + i`, and the same seed
    /// drives the arrival schedule.
    pub seed: u64,
}

impl LoadgenOptions {
    /// Sensible defaults around an arrival process: 2 s horizon, GMM
    /// workload, NFE 8, 4 lanes, no deadline, flat priority, seed 0.
    pub fn new(arrival: Arrival) -> LoadgenOptions {
        LoadgenOptions {
            arrival,
            duration_s: 2.0,
            max_requests: 0,
            workload: "latent_analog".into(),
            model: "gmm".into(),
            nfe: 8,
            n: 4,
            deadline_ms: None,
            priority_span: 1,
            seed: 0,
        }
    }
}

/// Build request `i` of a run. Lane-keyed Philox noise makes the returned
/// samples bit-identical for a given `(seed, n, cfg)` regardless of how
/// the scheduler batches or reorders requests, so loadgen runs can double
/// as reproducibility checks.
pub fn make_request(opts: &LoadgenOptions, i: u64) -> SampleRequest {
    SampleRequest {
        id: i + 1,
        workload: opts.workload.clone(),
        model: opts.model.clone(),
        cfg: SamplerConfig { nfe: opts.nfe, ..SamplerConfig::sa_default() },
        n: opts.n,
        seed: opts.seed.wrapping_add(i),
        return_samples: false,
        want_metrics: false,
        preset: None,
        deadline_ms: opts.deadline_ms,
        priority: if opts.priority_span > 1 { (i as i64) % opts.priority_span } else { 0 },
    }
}

/// Pull `(steps, step_lanes)` counters from a `stats` snapshot; zeros on
/// any shape mismatch so a stats hiccup never fails a run.
fn lane_counters(client: &mut Client) -> (u64, u64) {
    match client.stats() {
        Ok(v) => (v.opt_f64("steps", 0.0) as u64, v.opt_f64("step_lanes", 0.0) as u64),
        Err(_) => (0, 0),
    }
}

/// Drive `addr` with `opts` and return the aggregated report. Blocks
/// until every issued request has a reply (or transport error).
pub fn run(addr: &str, opts: &LoadgenOptions) -> Result<RunReport> {
    let before = match Client::connect(addr) {
        Ok(mut c) => lane_counters(&mut c),
        Err(e) => return Err(Error::runtime(format!("loadgen: cannot reach {addr}: {e}"))),
    };

    let mut report = RunReport::new(opts.arrival.mode(), opts.arrival.offered_rps(opts.duration_s));
    let start = Instant::now();
    let outcomes = match opts.arrival.schedule(opts.duration_s, opts.seed) {
        Some(offsets) => run_open(addr, opts, start, offsets),
        None => run_closed(addr, opts, start)?,
    };
    report.duration_s = start.elapsed().as_secs_f64().max(1e-9);

    for (outcome, latency_ms) in outcomes {
        report.sent += 1;
        match outcome {
            Outcome::Ok => {
                report.ok += 1;
                report.latency.observe_ms(latency_ms);
            }
            Outcome::Shed => report.shed += 1,
            Outcome::DeadlineMiss => report.deadline_miss += 1,
            Outcome::Timeout => report.timeout += 1,
            Outcome::OtherError => report.other_error += 1,
        }
    }

    if let Ok(mut c) = Client::connect(addr) {
        let after = lane_counters(&mut c);
        report.lane_util = LaneUtil {
            steps: after.0.saturating_sub(before.0),
            step_lanes: after.1.saturating_sub(before.1),
        };
    }
    Ok(report)
}

/// Issue one request over a fresh connection and classify the reply; a
/// transport failure counts as a timeout (the server may still be working
/// the request, exactly like a real client that gave up).
fn fire_once(addr: &str, req: &SampleRequest) -> (Outcome, f64) {
    let t0 = Instant::now();
    let outcome = match Client::connect(addr).and_then(|mut c| c.request(req)) {
        Ok(resp) => classify(&resp),
        Err(_) => Outcome::Timeout,
    };
    (outcome, t0.elapsed().as_secs_f64() * 1e3)
}

/// Open loop: one sender thread per scheduled arrival, each sleeping
/// until its offset so offered load is independent of server behavior.
fn run_open(
    addr: &str,
    opts: &LoadgenOptions,
    start: Instant,
    offsets: Vec<f64>,
) -> Vec<(Outcome, f64)> {
    let cap = if opts.max_requests > 0 { opts.max_requests } else { usize::MAX };
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for (i, off) in offsets.into_iter().take(cap).enumerate() {
        let tx = tx.clone();
        let addr = addr.to_string();
        let req = make_request(opts, i as u64);
        handles.push(std::thread::spawn(move || {
            let target = Duration::from_secs_f64(off.max(0.0));
            let elapsed = start.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            let _ = tx.send(fire_once(&addr, &req));
        }));
    }
    drop(tx);
    let out: Vec<(Outcome, f64)> = rx.into_iter().collect();
    for h in handles {
        let _ = h.join();
    }
    out
}

/// Closed loop: `concurrency` persistent clients pulling request indices
/// off a shared counter, stopping on the request cap or the wall clock.
fn run_closed(addr: &str, opts: &LoadgenOptions, start: Instant) -> Result<Vec<(Outcome, f64)>> {
    let Arrival::Closed { concurrency } = opts.arrival else {
        return Err(Error::runtime("loadgen: run_closed needs a closed arrival"));
    };
    let total = if opts.max_requests > 0 {
        opts.max_requests
    } else if opts.duration_s > 0.0 {
        usize::MAX
    } else {
        return Err(Error::config(
            "loadgen: closed loop needs --requests or a positive --duration",
        ));
    };
    let stop_at = (opts.duration_s > 0.0).then(|| start + Duration::from_secs_f64(opts.duration_s));
    let counter = Arc::new(AtomicUsize::new(0));
    let shared = Arc::new(opts.clone());
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for _ in 0..concurrency {
        let tx = tx.clone();
        let addr = addr.to_string();
        let counter = counter.clone();
        let shared = shared.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = match Client::connect(&addr) {
                Ok(c) => c,
                Err(_) => return,
            };
            loop {
                if stop_at.is_some_and(|d| Instant::now() >= d) {
                    break;
                }
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let req = make_request(&shared, i as u64);
                let t0 = Instant::now();
                let result = client.request(&req);
                let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
                let outcome = match result {
                    Ok(resp) => classify(&resp),
                    Err(_) => {
                        // The connection is poisoned after a transport
                        // error; reconnect or retire this worker.
                        match Client::connect(&addr) {
                            Ok(c) => client = c,
                            Err(_) => {
                                let _ = tx.send((Outcome::Timeout, latency_ms));
                                break;
                            }
                        }
                        Outcome::Timeout
                    }
                };
                let _ = tx.send((outcome, latency_ms));
            }
        }));
    }
    drop(tx);
    let out: Vec<(Outcome, f64)> = rx.into_iter().collect();
    for h in handles {
        let _ = h.join();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_follows_the_typed_taxonomy() {
        let mut ok = SampleResponse::err(1, "x");
        ok.ok = true;
        ok.error = None;
        assert_eq!(classify(&ok), Outcome::Ok);
        assert_eq!(classify(&SampleResponse::shed(1, 25)), Outcome::Shed);
        assert_eq!(
            classify(&SampleResponse::typed_err(1, "deadline", "late")),
            Outcome::DeadlineMiss
        );
        assert_eq!(
            classify(&SampleResponse::typed_err(1, "timeout", "gone")),
            Outcome::Timeout
        );
        assert_eq!(classify(&SampleResponse::err(1, "boom")), Outcome::OtherError);
        assert_eq!(
            classify(&SampleResponse::typed_err(1, "cancelled", "cancelled")),
            Outcome::OtherError
        );
    }

    #[test]
    fn make_request_spreads_priorities_and_seeds() {
        let mut opts = LoadgenOptions::new(Arrival::Closed { concurrency: 2 });
        opts.priority_span = 3;
        opts.seed = 100;
        opts.deadline_ms = Some(250);
        let reqs: Vec<SampleRequest> = (0..6).map(|i| make_request(&opts, i)).collect();
        assert_eq!(
            reqs.iter().map(|r| r.priority).collect::<Vec<i64>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
        assert_eq!(reqs[4].seed, 104);
        assert_eq!(reqs[4].deadline_ms, Some(250));
        assert_eq!(reqs[0].id, 1);

        opts.priority_span = 1;
        assert!((0..6).all(|i| make_request(&opts, i).priority == 0));
    }

    #[test]
    fn closed_loop_without_stop_condition_is_rejected() {
        let mut opts = LoadgenOptions::new(Arrival::Closed { concurrency: 1 });
        opts.duration_s = 0.0;
        opts.max_requests = 0;
        // Fails fast on option validation before touching the network —
        // 127.0.0.1:1 is only reached when validation passes.
        let err = run_closed("127.0.0.1:1", &opts, Instant::now()).unwrap_err();
        assert!(format!("{err}").contains("closed loop"), "{err}");
    }
}
