//! Loadgen run reports and the `BENCH_loadgen.json` artifact.

use crate::coordinator::metrics::Histogram;
use crate::jsonlite::{to_string, Value};
use crate::util::error::{Error, Result};

/// Per-step lane utilization pulled from the server's `stats` snapshot
/// after a run: total solver steps, total lane·steps, and their ratio
/// (mean lanes per scheduler step — how wide the step-synchronous
/// scheduler actually ran).
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneUtil {
    /// Solver steps executed during the run.
    pub steps: u64,
    /// Lane·steps executed (steps weighted by group width).
    pub step_lanes: u64,
}

impl LaneUtil {
    /// Mean lanes per scheduler step (0 when no steps ran).
    pub fn mean_lanes_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.step_lanes as f64 / self.steps as f64
        }
    }

    /// JSON form for the bench artifact.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("steps", Value::Num(self.steps as f64)),
            ("step_lanes", Value::Num(self.step_lanes as f64)),
            ("mean_lanes_per_step", Value::Num(self.mean_lanes_per_step())),
        ])
    }
}

/// Outcome-by-outcome tally plus latency for one loadgen point (one
/// arrival process at one offered load).
#[derive(Debug)]
pub struct RunReport {
    /// Arrival mode (`poisson`/`bursty`/`replay`/`closed`).
    pub mode: String,
    /// Planned offered load, requests/second (`None` for closed loop).
    pub offered_rps: Option<f64>,
    /// Wall-clock run length, seconds.
    pub duration_s: f64,
    /// Requests sent.
    pub sent: u64,
    /// Successful sample responses.
    pub ok: u64,
    /// Typed `shed` replies (admission backpressure).
    pub shed: u64,
    /// Typed `deadline` replies (latency budget expired pre-admission).
    pub deadline_miss: u64,
    /// Typed `timeout` replies (server reply-wait expired) plus client-side
    /// transport failures.
    pub timeout: u64,
    /// Any other error reply.
    pub other_error: u64,
    /// End-to-end latency of **successful** requests.
    pub latency: Histogram,
    /// Scheduler width observed server-side over the run.
    pub lane_util: LaneUtil,
}

impl RunReport {
    /// Fresh all-zero report for one point.
    pub fn new(mode: &str, offered_rps: Option<f64>) -> RunReport {
        RunReport {
            mode: mode.to_string(),
            offered_rps,
            duration_s: 0.0,
            sent: 0,
            ok: 0,
            shed: 0,
            deadline_miss: 0,
            timeout: 0,
            other_error: 0,
            latency: Histogram::new(),
            lane_util: LaneUtil::default(),
        }
    }

    /// Completed requests per second, all outcomes included.
    pub fn achieved_rps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            0.0
        } else {
            self.sent as f64 / self.duration_s
        }
    }

    /// Successful responses per second — throughput that met the contract.
    pub fn goodput_rps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            0.0
        } else {
            self.ok as f64 / self.duration_s
        }
    }

    /// One point of the bench artifact (`loadgen.points[i]`).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("mode", Value::Str(self.mode.clone())),
            ("offered_rps", self.offered_rps.map_or(Value::Null, Value::Num)),
            ("achieved_rps", Value::Num(self.achieved_rps())),
            ("goodput_rps", Value::Num(self.goodput_rps())),
            ("duration_s", Value::Num(self.duration_s)),
            ("sent", Value::Num(self.sent as f64)),
            ("ok", Value::Num(self.ok as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("deadline_miss", Value::Num(self.deadline_miss as f64)),
            ("timeout", Value::Num(self.timeout as f64)),
            ("other_error", Value::Num(self.other_error as f64)),
            ("latency", self.latency.snapshot()),
            ("lane_util", self.lane_util.to_json()),
        ])
    }

    /// One human-readable summary line for the console.
    pub fn summary_line(&self) -> String {
        let offered = self.offered_rps.map_or("closed".to_string(), |r| format!("{r:.1} rps"));
        format!(
            "{:<8} offered {:<10} achieved {:>7.1} rps  goodput {:>7.1} rps  \
             p50 {:>8.2} ms  p99 {:>8.2} ms  ok {}  shed {}  deadline {}  timeout {}  err {}",
            self.mode,
            offered,
            self.achieved_rps(),
            self.goodput_rps(),
            self.latency.percentile_ms(0.50),
            self.latency.percentile_ms(0.99),
            self.ok,
            self.shed,
            self.deadline_miss,
            self.timeout,
            self.other_error,
        )
    }
}

/// Assemble the full `BENCH_loadgen.json` document from a sweep of points.
pub fn bench_json(points: &[RunReport]) -> Value {
    Value::obj(vec![
        ("schema_version", Value::Num(1.0)),
        (
            "loadgen",
            Value::obj(vec![(
                "points",
                Value::Array(points.iter().map(RunReport::to_json).collect()),
            )]),
        ),
    ])
}

/// Write the bench artifact to `path`.
pub fn write_bench(path: &str, points: &[RunReport]) -> Result<()> {
    std::fs::write(path, format!("{}\n", to_string(&bench_json(points))))
        .map_err(|e| Error::runtime(format!("cannot write {path}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_rates_and_json_shape() {
        let mut r = RunReport::new("poisson", Some(40.0));
        r.duration_s = 2.0;
        r.sent = 80;
        r.ok = 60;
        r.shed = 15;
        r.deadline_miss = 3;
        r.timeout = 1;
        r.other_error = 1;
        r.latency.observe_ms(4.0);
        r.lane_util = LaneUtil { steps: 10, step_lanes: 40 };
        assert!((r.achieved_rps() - 40.0).abs() < 1e-9);
        assert!((r.goodput_rps() - 30.0).abs() < 1e-9);
        assert!((r.lane_util.mean_lanes_per_step() - 4.0).abs() < 1e-9);

        let doc = bench_json(&[r]);
        assert_eq!(doc.req_f64("schema_version").unwrap(), 1.0);
        let points = doc.get("loadgen").unwrap().get("points").unwrap();
        let Value::Array(points) = points else { panic!("points must be an array") };
        assert_eq!(points.len(), 1);
        let p = &points[0];
        assert_eq!(p.req_f64("shed").unwrap(), 15.0);
        assert_eq!(p.req_f64("deadline_miss").unwrap(), 3.0);
        let lat = p.get("latency").unwrap();
        assert_eq!(lat.req_f64("count").unwrap(), 1.0);
        assert!(lat.req_f64("p99_ms").unwrap() > 0.0);
        let text = to_string(&doc);
        assert!(text.contains("\"loadgen\""), "{text}");
    }

    #[test]
    fn closed_loop_offered_is_null() {
        let r = RunReport::new("closed", None);
        let j = r.to_json();
        assert!(matches!(j.get("offered_rps"), Some(Value::Null)));
        assert!(r.summary_line().contains("closed"));
    }
}
