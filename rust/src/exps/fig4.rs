//! Figure 4 / Tables 8–9: effect of stochasticity under inaccurate score
//! estimation. The paper retrains checkpoints to different epochs; we dial
//! the exact GMM score with a controlled seeded perturbation of amplitude ε
//! (larger ε ↔ earlier epoch) — same axis, no training confound.
//!
//! REPRODUCTION NOTE (EXPERIMENTS.md §Deviations): the ε-axis reproduces
//! (every sampler degrades with score error), but with *exogenous* additive
//! error the ODE-vs-SDE ordering REVERSES relative to the paper. This is a
//! property of the substitution, not a bug: the stochastic update weights
//! fresh model outputs by α(1−e^{−(1+τ²)h}) — a (1+τ²)-fold larger mass
//! than the ODE — so injected exogenous error variance scales ≈ (1+τ²)/2·h
//! vs h/2 for τ=0 (verified to first order by these measurements). The
//! paper's advantage arises with *real undertrained networks* whose error
//! is correlated with the sampler's own visited distribution, measured in
//! Inception feature space; none of the four exogenous error structures we
//! tested (persistent field, per-step-decorrelated field, mean regression,
//! off-manifold-gated error) recreates that coupling.

use super::common::{f, Scale, Table};
use crate::config::{SamplerConfig, SolverKind, TauKind};
use crate::coordinator::engine::evaluate;
use crate::models::PerturbedModel;
use crate::workloads;

/// ε values standing in for training epochs (decreasing error ↔ later epoch).
pub fn epsilons(scale: Scale) -> Vec<f64> {
    match scale {
        Scale::Quick => vec![0.6, 0.15, 0.0],
        Scale::Full => vec![0.8, 0.6, 0.4, 0.2, 0.1, 0.0],
    }
}

pub fn methods() -> Vec<(&'static str, SamplerConfig)> {
    let nfe = 31;
    vec![
        ("DDIM", SamplerConfig { nfe, ..SamplerConfig::for_solver(SolverKind::Ddim) }),
        (
            "DPM-Solver++(2M)",
            SamplerConfig { nfe, ..SamplerConfig::for_solver(SolverKind::DpmSolverPp2m) },
        ),
        ("EDM(ODE)", SamplerConfig { nfe, ..SamplerConfig::for_solver(SolverKind::Heun) }),
        (
            "SA-Solver tau=0.6",
            SamplerConfig {
                nfe,
                tau: 0.6,
                // The paper's §E.1 CIFAR setting: τ active on the EDM band
                // σ^{EDM} ∈ [0.05, 1], deterministic outside it.
                tau_kind: TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 },
                ..SamplerConfig::sa_default()
            },
        ),
        (
            "SA-Solver tau=1.0",
            SamplerConfig {
                nfe,
                tau: 1.0,
                tau_kind: TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 },
                ..SamplerConfig::sa_default()
            },
        ),
    ]
}

pub fn run(scale: Scale) -> Table {
    let wl = workloads::cifar_analog();
    let eps = epsilons(scale);
    let mut header = vec!["method \\ score err eps".to_string()];
    header.extend(eps.iter().map(|e| format!("{e:.2}")));
    let mut table = Table::new(
        "Figure 4 — FID(sim) under inaccurate score (eps ↔ early epoch), cifar_analog, NFE=31",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (name, cfg) in methods() {
        let mut cells = vec![name.to_string()];
        for &e in &eps {
            let model = PerturbedModel::new(
                crate::models::GmmAnalytic::new(wl.gmm.clone()),
                e,
                1234,
            );
            let mut acc = 0.0;
            for seed in 0..scale.n_seeds() {
                acc += evaluate(&model, &wl, &cfg, scale.n_samples(), seed as u64).sim_fid;
            }
            cells.push(f(acc / scale.n_seeds() as f64));
        }
        table.row(cells);
    }
    table.note = "epsilon-axis reproduces (all degrade with score error); ODE-vs-SDE ordering reverses under exogenous error — see module docs / EXPERIMENTS.md §Deviations".into();
    table
}
