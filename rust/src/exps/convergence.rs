//! Convergence-order verification for Theorems 5.1 / 5.2.
//!
//! (a) Deterministic component (τ = 0): the strong bound reduces to
//!     O(hˢ) (predictor) / O(h^{ŝ+1}) (corrector). On an exact GMM model we
//!     measure terminal error vs a fine reference and fit log-log slopes.
//! (b) Stochastic component (τ > 0): the O(τ h) term dominates; we measure
//!     the *distributional* terminal error (exact 1-D W2 against dense
//!     reference samples) and check it shrinks ≈ linearly in h.

use super::common::{f, Scale, Table};
use crate::config::Prediction;
use crate::gmm::Gmm;
use crate::models::GmmAnalytic;
use crate::rng::normal::{PhiloxNormal, ZeroNormal};
use crate::rng::Xoshiro256pp;
use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
use crate::solvers::sa::{SaSolver, SaSolverOpts};
use crate::solvers::Grid;
use crate::tau::TauFn;

/// Log-log slope of err vs h by least squares.
pub fn fit_order(hs: &[f64], errs: &[f64]) -> f64 {
    let xs: Vec<f64> = hs.iter().map(|h| h.ln()).collect();
    let ys: Vec<f64> = errs.iter().map(|e| e.max(1e-300).ln()).collect();
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// Deterministic order measurement for a given (s, ŝ).
pub fn ode_orders(sp: usize, sc: usize, ms: &[usize]) -> (Vec<f64>, Vec<f64>) {
    let sch = NoiseSchedule::vp_linear();
    let gmm = Gmm::structured(2, 3, 1.5, 77);
    let model = GmmAnalytic::new(gmm);
    let opts_ref = SaSolverOpts {
        predictor_steps: 3,
        corrector_steps: 3,
        prediction: Prediction::Data,
        tau: TauFn::Constant(0.0),
    };
    let x0 = vec![0.9, -0.4, 0.2, 1.1, -0.8, 0.5];
    let fine = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, 1024));
    let mut x_ref = x0.clone();
    SaSolver::new(opts_ref).solve(&model, &fine, &mut x_ref, 3, &mut ZeroNormal);

    let mut hs = Vec::new();
    let mut errs = Vec::new();
    for &m in ms {
        let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
        let h = (grid.lams[1] - grid.lams[0]).abs();
        let opts = SaSolverOpts {
            predictor_steps: sp,
            corrector_steps: sc,
            prediction: Prediction::Data,
            tau: TauFn::Constant(0.0),
        };
        let mut x = x0.clone();
        SaSolver::new(opts).solve(&model, &grid, &mut x, 3, &mut ZeroNormal);
        let err: f64 = x
            .iter()
            .zip(&x_ref)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        hs.push(h);
        errs.push(err);
    }
    (hs, errs)
}

/// Distributional (1-D exact W2) error at τ for step count m.
pub fn sde_w2(tau: f64, m: usize, n: usize) -> f64 {
    let sch = NoiseSchedule::vp_linear();
    let gmm = Gmm::new(
        vec![0.4, 0.6],
        vec![vec![-1.5], vec![1.2]],
        vec![vec![0.2], vec![0.3]],
    );
    let model = GmmAnalytic::new(gmm.clone());
    let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
    let opts = SaSolverOpts {
        predictor_steps: 3,
        corrector_steps: 1,
        prediction: Prediction::Data,
        tau: TauFn::Constant(tau),
    };
    let mut noise = PhiloxNormal::new(5);
    let mut x = crate::solvers::prior_sample(&grid, 1, n, &mut noise);
    SaSolver::new(opts).solve(&model, &grid, &mut x, n, &mut noise);
    // Exact terminal marginal samples (at t_min) as reference.
    let mut rng = Xoshiro256pp::new(99);
    let reference = gmm.sample_marginal(&mut rng, n, grid.alphas[m], grid.sigmas[m]);
    crate::metrics::w2_1d(&x, &reference)
}

pub fn run(scale: Scale) -> Vec<Table> {
    let ms: Vec<usize> = match scale {
        Scale::Quick => vec![8, 16, 32],
        Scale::Full => vec![8, 16, 32, 64, 128],
    };
    let mut t1 = Table::new(
        "Convergence — deterministic order (tau=0), terminal error vs steps",
        &["(s, s_hat)", "errors (coarse→fine)", "fitted order", "theory"],
    );
    for (sp, sc, theory) in [(1, 0, 1.0), (2, 0, 2.0), (3, 0, 3.0), (1, 1, 2.0), (2, 2, 3.0)] {
        let (hs, errs) = ode_orders(sp, sc, &ms);
        let order = fit_order(&hs, &errs);
        t1.row(vec![
            format!("({sp}, {sc})"),
            errs.iter().map(|e| f(*e)).collect::<Vec<_>>().join(" "),
            f(order),
            f(theory),
        ]);
    }
    t1.note = "Thm 5.1: predictor order s; Thm 5.2: corrector order s+1 (tau=0 component)".into();

    let n = scale.n_samples() * 4;
    let mut t2 = Table::new(
        "Convergence — stochastic component (tau>0), terminal W2 vs steps",
        &["tau", "W2(coarse→fine)", "fitted order"],
    );
    for tau in [0.5, 1.0] {
        let errs: Vec<f64> = ms.iter().map(|m| sde_w2(tau, *m, n)).collect();
        let hs: Vec<f64> = ms.iter().map(|m| 1.0 / *m as f64).collect();
        let order = fit_order(&hs, &errs);
        t2.row(vec![
            format!("{tau:.1}"),
            errs.iter().map(|e| f(*e)).collect::<Vec<_>>().join(" "),
            f(order),
        ]);
    }
    t2.note = "O(tau·h) term dominates: distributional error shrinks with h and floors at MC noise"
        .into();
    vec![t1, t2]
}
