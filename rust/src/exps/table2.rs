//! Table 2: predictor/corrector ablation on the CIFAR10 VE analog.
//! Settings (NFE, τ) ∈ {(15,0.4), (23,0.8), (31,1.0), (47,1.4)}; methods
//! {P1 only, P1+C1, P3 only, P3+C3}. τ is the paper's EDM-style interval
//! function (σ^{EDM} ∈ [0.05, 1], §E.1).
//!
//! Expected shape: multistep ≫ single-step; corrector helps at every order.

use super::common::{f, Scale, Table};
use crate::config::{SamplerConfig, TauKind};
use crate::coordinator::engine::evaluate;
use crate::workloads;

pub const SETTINGS: [(usize, f64); 4] = [(15, 0.4), (23, 0.8), (31, 1.0), (47, 1.4)];
pub const METHODS: [(&str, usize, usize); 4] = [
    ("Predictor 1-step only", 1, 0),
    ("Predictor 1-step, Corrector 1-step", 1, 1),
    ("Predictor 3-steps only", 3, 0),
    ("Predictor 3-steps, Corrector 3-steps", 3, 3),
];

pub fn run(scale: Scale) -> Table {
    let wl = workloads::cifar_analog();
    let model = wl.model();
    let settings: Vec<(usize, f64)> = match scale {
        Scale::Quick => SETTINGS[..2].to_vec(),
        Scale::Full => SETTINGS.to_vec(),
    };
    let mut header = vec!["method \\ (NFE, tau)".to_string()];
    header.extend(settings.iter().map(|(n, t)| format!("{n},{t}")));
    let mut table = Table::new(
        "Table 2 — FID(sim) by predictor/corrector steps, cifar_analog (VE)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (name, sp, sc) in METHODS {
        let mut cells = vec![name.to_string()];
        for &(nfe, tau) in &settings {
            let cfg = SamplerConfig {
                nfe,
                tau,
                tau_kind: TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 },
                predictor_steps: sp,
                corrector_steps: sc,
                ..SamplerConfig::sa_default()
            };
            let mut acc = 0.0;
            for seed in 0..scale.n_seeds() {
                acc += evaluate(&*model, &wl, &cfg, scale.n_samples(), seed as u64).sim_fid;
            }
            cells.push(f(acc / scale.n_seeds() as f64));
        }
        table.row(cells);
    }
    table.note =
        "paper shape: 3-step < 1-step FID; adding the corrector improves both (Tab.2)".into();
    table
}
