//! Figure 1 (and appendix Tables 4–7, 10–14 in condensed form): FID vs NFE
//! for τ ∈ {0, 0.2, …, 1.6} on all four workload analogs.
//!
//! Expected shape: at small NFE small τ wins; at moderate NFE (20–100)
//! larger τ wins; τ=0 (ODE) plateaus above the best SDE setting.

use super::common::{f, Scale, Table};
use crate::config::SamplerConfig;
use crate::coordinator::engine::evaluate;
use crate::workloads;

pub fn taus(scale: Scale) -> Vec<f64> {
    match scale {
        Scale::Quick => vec![0.0, 0.6, 1.2],
        Scale::Full => vec![0.0, 0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6],
    }
}

pub fn nfes(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![10, 30],
        Scale::Full => vec![5, 10, 20, 40, 60, 80, 100],
    }
}

pub fn run(scale: Scale) -> Vec<Table> {
    workloads::all_names()
        .iter()
        .map(|name| run_one(name, scale))
        .collect()
}

pub fn run_one(workload: &str, scale: Scale) -> Table {
    let wl = workloads::by_name(workload).expect("workload");
    let model = wl.model();
    let nfes = nfes(scale);
    let mut header = vec!["tau \\ NFE".to_string()];
    header.extend(nfes.iter().map(|n| n.to_string()));
    let mut table = Table::new(
        format!("Figure 1 — FID(sim) vs NFE × tau, {workload}"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    // τ rows are independent — compute them on the worker pool.
    for cells in super::common::par_rows(&taus(scale), |&tau| {
        let mut cells = vec![format!("{tau:.1}")];
        for &nfe in &nfes {
            let cfg = SamplerConfig { nfe, tau, ..SamplerConfig::sa_default() };
            let mut acc = 0.0;
            for seed in 0..scale.n_seeds() {
                acc += evaluate(&*model, &wl, &cfg, scale.n_samples(), seed as u64).sim_fid;
            }
            cells.push(f(acc / scale.n_seeds() as f64));
        }
        cells
    }) {
        table.row(cells);
    }
    table.note =
        "paper shape: optimal tau grows with NFE; tau=0 dominated at NFE ≥ ~20 (Fig.1, Tab.4–14)"
            .into();
    table
}
