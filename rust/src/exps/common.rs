//! Shared experiment plumbing: run scales, aligned text tables, and
//! row-parallel table construction over the `exec` worker pool.

use crate::exec::Executor;

/// Experiment scale: `Quick` for CI/tests, `Full` for EXPERIMENTS.md runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    /// Samples per evaluation point (the paper uses 50K images; the GMM
    /// metric stabilizes far sooner).
    pub fn n_samples(&self) -> usize {
        match self {
            Scale::Quick => 512,
            // 2048 keeps the sim-FID sampling noise well below the
            // solver-effect sizes while the full 9×7 τ/NFE grids stay
            // rebuildable in minutes on CPU (the paper's 50K-image FID
            // serves the same purpose at its scale).
            Scale::Full => 2048,
        }
    }

    /// Independent seeds averaged per cell.
    pub fn n_seeds(&self) -> usize {
        match self {
            Scale::Quick => 1,
            Scale::Full => 2,
        }
    }

    pub fn from_quick_flag(quick: bool) -> Scale {
        if quick {
            Scale::Quick
        } else {
            Scale::Full
        }
    }
}

/// An aligned text table with a title (mirrors the paper's table style).
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form footnote (expected shape vs. the paper, caveats).
    pub note: String,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            note: String::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        if !self.note.is_empty() {
            out.push_str(&format!("note: {}\n", self.note));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Compute independent table rows in parallel (one row per grid point),
/// preserving row order. Each row's cells are computed by `f(item)`; rows
/// are deterministic per item, so the parallel table equals the sequential
/// one cell for cell. Sizes to the cores; set `SADIFF_THREADS=1` (or use
/// [`par_rows_with`]) to force sequential rows for clean measurements.
/// Every table in the process shares one lazily created executor, so the
/// persistent pool behind it is spawned once, not per table.
pub fn par_rows<I, F>(items: &[I], f: F) -> Vec<Vec<String>>
where
    I: Sync,
    F: Fn(&I) -> Vec<String> + Sync,
{
    static EXEC: std::sync::OnceLock<Executor> = std::sync::OnceLock::new();
    par_rows_with(EXEC.get_or_init(Executor::auto), items, f)
}

/// [`par_rows`] on an explicit executor.
pub fn par_rows_with<I, F>(exec: &Executor, items: &[I], f: F) -> Vec<Vec<String>>
where
    I: Sync,
    F: Fn(&I) -> Vec<String> + Sync,
{
    exec.map(items, |_, item| f(item))
}

/// Format a float for table cells.
pub fn f(x: f64) -> String {
    if x.is_nan() {
        "nan".into()
    } else if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        // Data rows have equal width.
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(f64::NAN), "nan");
        assert_eq!(f(1234.6), "1235");
        assert_eq!(f(12.34), "12.3");
        assert_eq!(f(3.8765), "3.877");
        assert_eq!(f(0.00012), "1.20e-4");
    }

    #[test]
    fn scales() {
        assert!(Scale::Full.n_samples() > Scale::Quick.n_samples());
        assert_eq!(Scale::from_quick_flag(true), Scale::Quick);
    }

    #[test]
    fn par_rows_preserves_order() {
        let items: Vec<usize> = (0..17).collect();
        let rows = par_rows(&items, |i| vec![i.to_string(), (i * i).to_string()]);
        assert_eq!(rows.len(), 17);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row, &vec![i.to_string(), (i * i).to_string()]);
        }
    }
}
