//! Figure 2: solver comparison (DDIM, DPM-Solver, UniPC, EDM-ODE/Heun,
//! EDM-SDE, SA-Solver) vs NFE on the CIFAR10-VE, ImageNet64-cosine and
//! latent analogs.
//!
//! Expected shape: SA-Solver matches the best ODE solvers at small NFE and
//! beats all of them from moderate NFE on; EDM-SDE needs many more steps.

use super::common::{f, Scale, Table};
use crate::config::{SamplerConfig, SolverKind};
use crate::coordinator::engine::evaluate;
use crate::workloads;

pub fn solvers() -> Vec<(&'static str, SolverKind)> {
    vec![
        ("DDIM(eta=0)", SolverKind::Ddim),
        ("DPM-Solver-2", SolverKind::DpmSolver2),
        ("DPM-Solver++(2M)", SolverKind::DpmSolverPp2m),
        ("UniPC", SolverKind::UniPc),
        ("EDM(ODE/Heun)", SolverKind::Heun),
        ("EDM(SDE)", SolverKind::EdmSde),
        ("SA-Solver", SolverKind::Sa),
    ]
}

pub fn nfes(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![11, 31],
        Scale::Full => vec![11, 15, 23, 31, 47, 63, 95],
    }
}

pub fn run(scale: Scale) -> Vec<Table> {
    ["cifar_analog", "imagenet64_analog", "latent_analog"]
        .iter()
        .map(|w| run_one(w, scale))
        .collect()
}

pub fn run_one(workload: &str, scale: Scale) -> Table {
    let wl = workloads::by_name(workload).expect("workload");
    let model = wl.model();
    let nfes = nfes(scale);
    let mut header = vec!["method \\ NFE".to_string()];
    header.extend(nfes.iter().map(|n| n.to_string()));
    let mut table = Table::new(
        format!("Figure 2 — FID(sim) by solver vs NFE, {workload}"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (name, kind) in solvers() {
        let mut cells = vec![name.to_string()];
        for &nfe in &nfes {
            let mut cfg = SamplerConfig { nfe, ..SamplerConfig::for_solver(kind) };
            if kind == SolverKind::Sa {
                // Paper protocol: a proper τ per budget (§E.1); moderate
                // stochasticity at medium NFE.
                cfg.tau = if nfe < 20 { 0.4 } else { 1.0 };
            }
            let mut acc = 0.0;
            for seed in 0..scale.n_seeds() {
                acc += evaluate(&*model, &wl, &cfg, scale.n_samples(), seed as u64).sim_fid;
            }
            cells.push(f(acc / scale.n_seeds() as f64));
        }
        table.row(cells);
    }
    table.note =
        "paper shape: SA-Solver best at moderate+ NFE; EDM(SDE) slow to converge (Fig.2/Tab.4,6,10)"
            .into();
    table
}
