//! Experiment drivers — one per table/figure of the paper (DESIGN.md §5).
//! Each driver returns structured rows and prints a paper-style text table;
//! `rust/benches/*` and `sadiff exp <id>` are thin wrappers over these.

pub mod ablations;
pub mod common;
pub mod convergence;
pub mod equivalence;
pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod tau_grid;

pub use common::{Scale, Table};

/// Run an experiment by id, printing its table(s). Returns false for an
/// unknown id.
pub fn run_by_name(id: &str, scale: Scale) -> bool {
    match id {
        "table1" => table1::run(scale).print(),
        "table2" => table2::run(scale).print(),
        "table3" => table3::run(scale).print(),
        "fig1" => {
            for t in fig1::run(scale) {
                t.print();
            }
        }
        "fig2" => {
            for t in fig2::run(scale) {
                t.print();
            }
        }
        "fig4" => fig4::run(scale).print(),
        "tau_grid" | "tables4_14" => {
            for t in tau_grid::run(scale) {
                t.print();
            }
        }
        "convergence" => {
            for t in convergence::run(scale) {
                t.print();
            }
        }
        "equivalence" => equivalence::run().print(),
        "ablations" => {
            for t in ablations::run(scale) {
                t.print();
            }
        }
        _ => return false,
    }
    true
}

/// All experiment ids.
pub fn all_ids() -> &'static [&'static str] {
    &[
        "table1",
        "table2",
        "table3",
        "fig1",
        "fig2",
        "fig4",
        "tau_grid",
        "convergence",
        "equivalence",
        "ablations",
    ]
}
