//! Appendix Tables 4–14 (condensed): the full τ × NFE FID grids per
//! workload analog, i.e. the data behind Figure 1 at the paper's exact
//! (τ, NFE) lattice.

use super::common::Scale;
use super::fig1;
use crate::exps::Table;

pub fn run(scale: Scale) -> Vec<Table> {
    // Tables 4/5 (CIFAR VE), 6/7+12 (ImageNet64), 13 (latent), 14 (bedroom):
    // one grid per workload, using each workload's NFE lattice.
    crate::workloads::all_names()
        .iter()
        .map(|name| {
            let mut t = fig1::run_one(name, scale);
            t.title = format!("Tables 4–14 — tau × NFE grid, {name}");
            t
        })
        .collect()
}
