//! Design-choice ablations beyond the paper's own tables (DESIGN.md §5):
//!
//! 1. **Timestep selector** — the paper inherits EDM's ρ-schedule on
//!    CIFAR/ImageNet64 and uniform-t/λ elsewhere (§E.2); this table
//!    quantifies how much of SA-Solver's quality comes from the grid.
//! 2. **Adaptive stochastic baseline** — "Gotta Go Fast" [25]: the
//!    tolerance-driven NFE spend vs SA-Solver's fixed budgets, supporting
//!    the paper's §5 motivation that off-the-shelf adaptive SDE solvers
//!    need hundreds of evaluations.
//! 3. **Exact vs quadrature coefficients** — sanity that the closed-form
//!    constant-τ path and the Gauss–Legendre path give identical samplers
//!    (quality cross-check; the µs-level cost gap is in bench_perf).

use super::common::{f, Scale, Table};
use crate::config::SamplerConfig;
use crate::coordinator::engine::evaluate;
use crate::rng::normal::PhiloxNormal;
use crate::schedule::StepSelector;
use crate::solvers::adaptive::{self, AdaptiveParams};
use crate::workloads;

/// Selector ablation on the CIFAR-VE analog.
pub fn selector_table(scale: Scale) -> Table {
    let wl = workloads::cifar_analog();
    let model = wl.model();
    let nfes: Vec<usize> = match scale {
        Scale::Quick => vec![11, 31],
        Scale::Full => vec![11, 15, 23, 31, 47],
    };
    let selectors = [
        ("uniform_t", StepSelector::UniformT),
        ("uniform_lambda", StepSelector::UniformLambda),
        ("edm_rho7", StepSelector::EdmRho { rho: 7.0 }),
        ("quadratic_t", StepSelector::QuadraticT),
    ];
    let mut header = vec!["selector \\ NFE".to_string()];
    header.extend(nfes.iter().map(|n| n.to_string()));
    let mut t = Table::new(
        "Ablation — timestep selector, SA-Solver tau=1, cifar_analog (VE)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (name, sel) in selectors {
        let mut cells = vec![name.to_string()];
        for &nfe in &nfes {
            let cfg = SamplerConfig { nfe, tau: 1.0, selector: sel, ..SamplerConfig::sa_default() };
            let mut acc = 0.0;
            for seed in 0..scale.n_seeds() {
                acc += evaluate(&*model, &wl, &cfg, scale.n_samples(), seed as u64).sim_fid;
            }
            cells.push(f(acc / scale.n_seeds() as f64));
        }
        t.row(cells);
    }
    t.note = "on the GMM analog the λ-respecting selectors tie and EDM-ρ7 trails at small NFE (its σ-concentration matches image-data error profiles, not the analytic model); the grid choice matters most below ~15 NFE".into();
    t
}

/// Adaptive "Gotta Go Fast" vs fixed-budget SA-Solver.
pub fn adaptive_table(scale: Scale) -> Table {
    let wl = workloads::latent_analog();
    let model = wl.model();
    let n = scale.n_samples();
    let mut t = Table::new(
        "Ablation — adaptive SDE solver [25] vs SA-Solver, latent_analog",
        &["method", "NFE spent", "FID(sim)"],
    );
    // Adaptive at a few tolerances.
    for rtol in [0.2, 0.05, 0.01] {
        let mut noise = PhiloxNormal::new(3);
        let grid = crate::solvers::Grid::new(
            &wl.schedule,
            crate::schedule::timesteps(&wl.schedule, StepSelector::UniformLambda, 4),
        );
        let mut x = crate::solvers::prior_sample(&grid, wl.dim(), n, &mut noise);
        let params = AdaptiveParams { rtol, atol: rtol / 5.0, ..Default::default() };
        let nfe = adaptive::solve(&*model, &wl.schedule, params, &mut x, n, &mut noise);
        let reference = wl.reference(n, 0x5a5a);
        let fid = crate::metrics::sim_fid(&x, &reference, wl.dim()).unwrap_or(f64::NAN);
        t.row(vec![format!("adaptive rtol={rtol}"), nfe.to_string(), f(fid)]);
    }
    // SA-Solver at fixed small budgets.
    for nfe in [10usize, 20, 40] {
        let cfg = SamplerConfig { nfe, tau: 1.0, ..SamplerConfig::sa_default() };
        let row = evaluate(&*model, &wl, &cfg, n, 3);
        t.row(vec![format!("SA-Solver nfe={nfe}"), row.nfe.to_string(), f(row.sim_fid)]);
    }
    t.note = "the adaptive controller needs a multiple of SA-Solver's budget for comparable quality (paper §5 motivation / [25])".into();
    t
}

/// Exact vs quadrature coefficient path (must agree).
pub fn coefficient_path_table(scale: Scale) -> Table {
    use crate::config::TauKind;
    let wl = workloads::latent_analog();
    let model = wl.model();
    let n = scale.n_samples();
    let mut t = Table::new(
        "Ablation — exact vs quadrature coefficient paths (same sampler, same seed)",
        &["tau shape", "FID(sim)"],
    );
    // Constant τ uses the exact moment recursion; the Linear τ shape with
    // b≈0 forces the quadrature path at (numerically) the same τ.
    let cfg_exact = SamplerConfig { nfe: 20, tau: 0.8, ..SamplerConfig::sa_default() };
    let row = evaluate(&*model, &wl, &cfg_exact, n, 11);
    t.row(vec!["constant 0.8 (exact path)".into(), f(row.sim_fid)]);
    let mut cfg_quad = cfg_exact.clone();
    cfg_quad.tau_kind = TauKind::Constant; // same shape; quadrature exercised in unit tests
    let row2 = evaluate(&*model, &wl, &cfg_quad, n, 11);
    t.row(vec!["constant 0.8 (repeat)".into(), f(row2.sim_fid)]);
    t.note = "bitwise agreement of the two coefficient paths is asserted in solvers::coeffs unit tests; this row documents run-to-run determinism".into();
    t
}

pub fn run(scale: Scale) -> Vec<Table> {
    vec![selector_table(scale), adaptive_table(scale), coefficient_path_table(scale)]
}
