//! Table 3: the trained-network experiment — DDPM at a large NFE budget vs
//! SA-Solver at a small one, on the build-time-trained tiny DiT artifact
//! (the analog of the paper's DiT-XL/2 rows: DDPM@250 = 2.27 vs
//! SA-Solver@60 = 2.02 on ImageNet-256).
//!
//! Reference samples come from the DiT's training distribution, dumped by
//! `python/compile/aot.py` into `artifacts/dit_reference.json`.

use super::common::{f, Scale, Table};
use crate::config::{SamplerConfig, SolverKind};
use crate::coordinator::engine::sample;
use crate::jsonlite::Value;
use crate::runtime::{HloModel, RuntimeHost};
use crate::util::error::{Error, Result};
use crate::workloads::Workload;

/// Load the DiT reference set (n × dim flattened) from the artifacts dir.
pub fn load_reference(dir: &str) -> Result<(Vec<f64>, usize)> {
    let path = format!("{dir}/dit_reference.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::runtime(format!("read {path} (run `make artifacts`): {e}")))?;
    let v = crate::jsonlite::parse(&text)?;
    let dim = v.req_usize("dim")?;
    let data: Vec<f64> = v
        .get("samples")
        .and_then(Value::as_array)
        .ok_or_else(|| Error::runtime("dit_reference: missing samples"))?
        .iter()
        .filter_map(Value::as_f64)
        .collect();
    Ok((data, dim))
}

/// The schedule the DiT was trained under (fixed by python/compile/train.py).
pub fn dit_workload(dim: usize) -> Workload {
    Workload {
        name: "dit_trained",
        schedule: crate::schedule::NoiseSchedule::vp_linear(),
        gmm: crate::gmm::Gmm::standard(dim), // placeholder target; reference comes from file
    }
}

pub fn run(scale: Scale) -> Table {
    match run_inner(scale) {
        Ok(t) => t,
        Err(e) => {
            let mut t = Table::new("Table 3 — DiT artifact (SKIPPED)", &["status"]);
            t.row(vec![format!("skipped: {e}")]);
            t
        }
    }
}

fn run_inner(scale: Scale) -> Result<Table> {
    let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let host = RuntimeHost::open(&dir)?;
    let model = HloModel::from_manifest(host, "dit_denoiser")?;
    let (reference, dim) = load_reference(&dir)?;
    let wl = dit_workload(dim);

    let (ddpm_nfe, sa_nfe, n) = match scale {
        Scale::Quick => (50, 12, 128),
        Scale::Full => (250, 60, 512),
    };
    let mut table = Table::new(
        "Table 3 — FID(sim) on the trained DiT artifact",
        &["method", "NFE", "FID(sim)"],
    );
    // τ = 0.6: the DiT is deliberately under-trained (build-time CPU
    // budget), and per our Fig-4 analysis moderate stochasticity is the
    // right operating point under residual model error.
    let configs = [
        ("DDPM", SamplerConfig { nfe: ddpm_nfe, ..SamplerConfig::for_solver(SolverKind::Ddpm) }),
        (
            "SA-Solver (ours)",
            SamplerConfig { nfe: sa_nfe, tau: 0.6, ..SamplerConfig::sa_default() },
        ),
    ];
    for (name, cfg) in configs {
        let out = sample(&model, &wl, &cfg, n, 17);
        let n_ref = reference.len() / dim;
        let take = n.min(n_ref) * dim;
        let fid = crate::metrics::sim_fid(&out.samples[..take], &reference[..take], dim)
            .unwrap_or(f64::NAN);
        table.row(vec![name.to_string(), cfg.nfe.to_string(), f(fid)]);
    }
    table.note = format!(
        "paper shape: SA-Solver at {sa_nfe} NFE ≤ DDPM at {ddpm_nfe} NFE (Tab.3: 2.02@60 vs 2.27@250)"
    );
    Ok(table)
}
