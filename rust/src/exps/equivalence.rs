//! §5.3 reductions, verified numerically:
//!
//! * Corollary 5.3 — DDIM-η equals the 1-step SA-Predictor with
//!   τ_η² = −ln(1 − η²(1 − e^{−2h}))/(2h) per step (piecewise-constant τ).
//! * §B.5.2 — DPM-Solver++(2M) equals the 2-step SA-Predictor at τ ≡ 0.
//! * §B.5.3 — UniPC-p equals SA-Solver(p, p) at τ ≡ 0.
//!
//! These run coupled (shared noise / deterministic) and report max |Δ|;
//! `rust/tests/integration_equivalence.rs` asserts the tolerances.

use super::common::{f, Table};
use crate::config::Prediction;
use crate::gmm::Gmm;
use crate::models::GmmAnalytic;
use crate::rng::normal::{NormalSource, PhiloxNormal, ZeroNormal};
use crate::schedule::{timesteps, NoiseSchedule, StepSelector};
use crate::solvers::sa::{SaSolver, SaSolverOpts};
use crate::solvers::{ddim, dpm, unipc, Grid};
use crate::tau::TauFn;

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

fn test_state(grid: &Grid, n: usize, dim: usize) -> Vec<f64> {
    let mut noise = PhiloxNormal::new(1);
    crate::solvers::prior_sample(grid, dim, n, &mut noise)
}

/// DDIM-η vs per-step τ_η 1-step SA-Predictor. Because τ_η varies per step
/// (h varies on a non-uniform grid), we run SA step-by-step with the
/// matching constant τ on each interval.
pub fn ddim_vs_sa(eta: f64, m: usize) -> f64 {
    let sch = NoiseSchedule::vp_linear();
    let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
    let model = GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 55));
    let n = 8;

    let mut x_ddim = test_state(&grid, n, 2);
    let mut noise_a = PhiloxNormal::new(42);
    ddim::solve(&model, &grid, eta, &mut x_ddim, n, &mut noise_a);

    // SA side: one 1-step predictor per interval with the per-step τ_η.
    let mut x_sa = test_state(&grid, n, 2);
    for i in 0..m {
        let h = grid.lams[i + 1] - grid.lams[i];
        let inner = 1.0 - eta * eta * crate::util::one_minus_exp_neg(2.0 * h);
        let tau = if inner <= 0.0 {
            8.0 // η ≥ 1-ish limit; clamp (τ→∞ is the full-noise limit)
        } else {
            (-inner.ln() / (2.0 * h)).max(0.0).sqrt()
        };
        let sub = Grid {
            ts: grid.ts[i..=i + 1].to_vec(),
            alphas: grid.alphas[i..=i + 1].to_vec(),
            sigmas: grid.sigmas[i..=i + 1].to_vec(),
            lams: grid.lams[i..=i + 1].to_vec(),
        };
        let opts = SaSolverOpts {
            predictor_steps: 1,
            corrector_steps: 0,
            prediction: Prediction::Data,
            tau: TauFn::Constant(tau),
        };
        // Same per-step noise as DDIM's step i: replay via an offset source.
        let mut src = OffsetNoise { inner: PhiloxNormal::new(42), offset: i as u64 };
        SaSolver::new(opts).solve(&model, &sub, &mut x_sa, n, &mut src);
    }
    max_abs_diff(&x_ddim, &x_sa)
}

/// Remaps step indices so a sub-grid solve draws the same noise the full
/// DDIM loop drew at the matching global step.
struct OffsetNoise {
    inner: PhiloxNormal,
    offset: u64,
}

impl NormalSource for OffsetNoise {
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]) {
        self.inner.fill(stream, step + self.offset, out);
    }
}

/// DPM-Solver++(2M) vs 2-step SA-Predictor, τ ≡ 0 (deterministic).
pub fn pp2m_vs_sa(m: usize) -> f64 {
    let sch = NoiseSchedule::vp_linear();
    let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
    let model = GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 55));
    let n = 8;
    let mut a = test_state(&grid, n, 2);
    dpm::solve_pp2m(&model, &grid, &mut a, n);
    let mut b = test_state(&grid, n, 2);
    let opts = SaSolverOpts {
        predictor_steps: 2,
        corrector_steps: 0,
        prediction: Prediction::Data,
        tau: TauFn::Constant(0.0),
    };
    SaSolver::new(opts).solve(&model, &grid, &mut b, n, &mut ZeroNormal);
    max_abs_diff(&a, &b)
}

/// UniPC-p vs SA-Solver(p, p), τ ≡ 0 (deterministic; independent
/// quadrature paths cross-validate the coefficient engine).
pub fn unipc_vs_sa(p: usize, m: usize) -> f64 {
    let sch = NoiseSchedule::vp_cosine();
    let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
    let model = GmmAnalytic::new(Gmm::structured(2, 3, 1.5, 55));
    let n = 8;
    let mut a = test_state(&grid, n, 2);
    unipc::solve(&model, &grid, p, p, &mut a, n);
    let mut b = test_state(&grid, n, 2);
    let opts = SaSolverOpts {
        predictor_steps: p,
        corrector_steps: p,
        prediction: Prediction::Data,
        tau: TauFn::Constant(0.0),
    };
    SaSolver::new(opts).solve(&model, &grid, &mut b, n, &mut ZeroNormal);
    max_abs_diff(&a, &b)
}

pub fn run() -> Table {
    let mut t = Table::new(
        "Equivalences (§5.3) — max |Δ| between SA-Solver special cases and independent implementations",
        &["reduction", "setting", "max |delta|"],
    );
    for eta in [0.0, 0.5, 1.0] {
        t.row(vec![
            "DDIM-eta = 1-step SA-Predictor(tau_eta)".into(),
            format!("eta={eta}, M=12"),
            f(ddim_vs_sa(eta, 12)),
        ]);
    }
    t.row(vec![
        "DPM-Solver++(2M) = 2-step SA-Predictor(tau=0)".into(),
        "M=16".into(),
        f(pp2m_vs_sa(16)),
    ]);
    for p in [1usize, 2, 3] {
        t.row(vec![
            "UniPC-p = SA-Solver(p,p)(tau=0)".into(),
            format!("p={p}, M=12"),
            f(unipc_vs_sa(p, 12)),
        ]);
    }
    t.note = "all deltas should be at floating-point / quadrature-tolerance level".into();
    t
}
