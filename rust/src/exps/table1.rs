//! Table 1: data- vs noise-prediction under the SDE solver (τ ≡ 1),
//! ImageNet-256 latent analog, NFE ∈ {20, 40, 60, 80}.
//!
//! Expected shape (paper): noise-prediction catastrophically bad at NFE=20
//! (310.5 vs 3.88) and converging only at large NFE; data-prediction good
//! throughout. The mechanism is Corollary A.2 (noise-param injects strictly
//! more per-step variance), which holds verbatim in our setup.

use super::common::{f, Scale, Table};
use crate::config::{Prediction, SamplerConfig};
use crate::coordinator::engine::evaluate;
use crate::workloads;

pub fn nfes(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![20, 40],
        Scale::Full => vec![20, 40, 60, 80],
    }
}

pub fn run(scale: Scale) -> Table {
    let wl = workloads::latent_analog();
    let model = wl.model();
    let mut t = Table::new(
        "Table 1 — FID(sim) by reparameterization, SA-Solver τ=1, latent_analog",
        &["NFE", "Noise-prediction", "Data-prediction"],
    );
    // Rows (NFE points) are independent — compute them on the worker pool.
    for cells in super::common::par_rows(&nfes(scale), |&nfe| {
        let mut cells = vec![nfe.to_string()];
        for pred in [Prediction::Noise, Prediction::Data] {
            let cfg = SamplerConfig {
                nfe,
                tau: 1.0,
                prediction: pred,
                ..SamplerConfig::sa_default()
            };
            let mut acc = 0.0;
            for seed in 0..scale.n_seeds() {
                acc += evaluate(&*model, &wl, &cfg, scale.n_samples(), seed as u64).sim_fid;
            }
            cells.push(f(acc / scale.n_seeds() as f64));
        }
        cells
    }) {
        t.row(cells);
    }
    t.note = "paper shape: noise-pred diverges at small NFE, data-pred stable (Tab.1: 310.5 vs 3.88 at NFE=20)".into();
    t
}
