//! Numerical quadrature substrate for the SA-Solver coefficient integrals
//! with general τ(t): Gauss–Legendre rules (nodes by Newton iteration on the
//! Legendre recurrence) and adaptive Simpson as a cross-check.

/// A quadrature rule on [-1, 1]: paired nodes and weights.
#[derive(Debug, Clone)]
pub struct GaussLegendre {
    pub nodes: Vec<f64>,
    pub weights: Vec<f64>,
}

impl GaussLegendre {
    /// Build the n-point rule. Nodes are roots of P_n found by Newton from
    /// the Chebyshev-based initial guess; weights w = 2 / ((1-x²) P'_n(x)²).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let mut nodes = vec![0.0; n];
        let mut weights = vec![0.0; n];
        for i in 0..n.div_ceil(2) {
            // Initial guess (Abramowitz & Stegun 25.4.30 neighborhood).
            let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
            let mut dp = 0.0;
            for _ in 0..100 {
                let (p, d) = legendre_and_deriv(n, x);
                dp = d;
                let dx = p / d;
                x -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            let w = 2.0 / ((1.0 - x * x) * dp * dp);
            nodes[i] = -x;
            nodes[n - 1 - i] = x;
            weights[i] = w;
            weights[n - 1 - i] = w;
        }
        GaussLegendre { nodes, weights }
    }

    /// Integrate `f` on [a, b].
    pub fn integrate<F: Fn(f64) -> f64>(&self, a: f64, b: f64, f: F) -> f64 {
        let c = 0.5 * (b - a);
        let m = 0.5 * (a + b);
        let mut s = 0.0;
        for (x, w) in self.nodes.iter().zip(&self.weights) {
            s += w * f(m + c * x);
        }
        c * s
    }
}

/// Evaluate (P_n(x), P_n'(x)) via the three-term recurrence.
fn legendre_and_deriv(n: usize, x: f64) -> (f64, f64) {
    let mut p0 = 1.0;
    let mut p1 = x;
    if n == 0 {
        return (1.0, 0.0);
    }
    for k in 2..=n {
        let kf = k as f64;
        let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
        p0 = p1;
        p1 = p2;
    }
    let d = n as f64 * (x * p1 - p0) / (x * x - 1.0);
    (p1, d)
}

/// Adaptive Simpson quadrature to absolute tolerance `tol`.
pub fn adaptive_simpson<F: Fn(f64) -> f64 + Copy>(f: F, a: f64, b: f64, tol: f64) -> f64 {
    fn simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64) -> f64 {
        let m = 0.5 * (a + b);
        (b - a) / 6.0 * (f(a) + 4.0 * f(m) + f(b))
    }
    fn rec<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, whole: f64, tol: f64, depth: u32) -> f64 {
        let m = 0.5 * (a + b);
        let left = simpson(f, a, m);
        let right = simpson(f, m, b);
        if depth == 0 || (left + right - whole).abs() <= 15.0 * tol {
            return left + right + (left + right - whole) / 15.0;
        }
        rec(f, a, m, left, tol / 2.0, depth - 1) + rec(f, m, b, right, tol / 2.0, depth - 1)
    }
    let whole = simpson(&f, a, b);
    rec(&f, a, b, whole, tol, 40)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn gl_exact_on_polynomials() {
        // n-point GL is exact up to degree 2n-1.
        let gl = GaussLegendre::new(4);
        let got = gl.integrate(0.0, 1.0, |x| x.powi(7));
        assert!(close(got, 1.0 / 8.0, 1e-13, 0.0), "got {got}");
        let got = gl.integrate(-2.0, 3.0, |x| 3.0 * x * x);
        assert!(close(got, 35.0, 1e-12, 0.0), "got {got}");
    }

    #[test]
    fn gl_weights_sum_to_two() {
        for n in [1, 2, 5, 16, 32, 64] {
            let gl = GaussLegendre::new(n);
            let s: f64 = gl.weights.iter().sum();
            assert!(close(s, 2.0, 1e-12, 0.0), "n={n} sum={s}");
            // Nodes sorted and inside (-1, 1).
            for w in gl.nodes.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(gl.nodes[0] > -1.0 && gl.nodes[n - 1] < 1.0);
        }
    }

    #[test]
    fn gl_exponential_accuracy() {
        let gl = GaussLegendre::new(16);
        let got = gl.integrate(0.0, 1.0, f64::exp);
        assert!(close(got, std::f64::consts::E - 1.0, 1e-14, 0.0));
    }

    #[test]
    fn simpson_matches_gl() {
        let f = |x: f64| (3.0 * x).sin() * (-x).exp();
        let gl = GaussLegendre::new(48).integrate(0.0, 2.0, f);
        let si = adaptive_simpson(f, 0.0, 2.0, 1e-12);
        assert!(close(gl, si, 1e-9, 1e-12), "gl={gl} si={si}");
    }
}
