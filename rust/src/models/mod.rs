//! Model-evaluation abstraction: everything a solver knows about the
//! denoiser is `eval_batch(x, ctx) -> x0hat`. Implementations:
//!
//! * [`GmmAnalytic`] — exact GMM posterior mean (native Rust; the fast path
//!   for solver studies where model error must be zero).
//! * [`PerturbedModel`] — wraps a model and injects a smooth, seeded score
//!   error of controlled amplitude (reproduces §6.5's "undertrained" axis).
//! * [`CountingModel`] — wraps a model and counts NFE.
//! * `runtime::HloModel` — PJRT artifact execution (lives in `runtime` to
//!   keep the xla dependency out of this module).

use crate::gmm::Gmm;
use crate::rng::Xoshiro256pp;

/// Evaluation context: the solver's current time point on its schedule.
#[derive(Debug, Clone, Copy)]
pub struct EvalCtx {
    pub t: f64,
    pub alpha: f64,
    pub sigma: f64,
}

/// A batched data-prediction model x_θ(x, t) ≈ E[x₀ | x_t].
pub trait ModelEval: Send + Sync {
    /// Data dimension.
    fn dim(&self) -> usize;

    /// Evaluate the batch `xs` (row-major n×dim) at `ctx`, writing x₀̂ into
    /// `out` (same layout).
    fn eval_batch(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]);

    /// Human-readable name for logs/experiment tables.
    fn name(&self) -> &str {
        "model"
    }
}

/// Exact GMM posterior-mean denoiser.
pub struct GmmAnalytic {
    pub gmm: Gmm,
}

impl GmmAnalytic {
    pub fn new(gmm: Gmm) -> Self {
        GmmAnalytic { gmm }
    }
}

impl ModelEval for GmmAnalytic {
    fn dim(&self) -> usize {
        self.gmm.dim
    }

    fn eval_batch(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]) {
        debug_assert_eq!(xs.len(), out.len());
        let n = xs.len() / self.gmm.dim;
        for i in 0..n {
            let row = &xs[i * self.gmm.dim..(i + 1) * self.gmm.dim];
            let orow = &mut out[i * self.gmm.dim..(i + 1) * self.gmm.dim];
            self.gmm.posterior_mean(row, ctx.alpha, ctx.sigma, orow);
        }
    }

    fn name(&self) -> &str {
        "gmm_analytic"
    }
}

/// Seeded perturbation field: δ_d(x, t) = Σ_j C[d][j] sin(k_j·x + ω_j t + φ_j).
/// Bounded by Σ|C|, Lipschitz in x — satisfies the paper's Assumptions
/// B.4/B.5, so convergence theory still applies to the perturbed model.
///
/// The temporal frequencies ω_j are deliberately *fast* (≈ high-frequency
/// misfit of an undertrained network): along a sampling trajectory the
/// error decorrelates between model evaluations, which is the regime where
/// the paper's §6.5/Appendix-C mechanism operates — the SDE's stronger
/// per-step contraction (c₀ damped by e^{−τ²h}) forgets earlier errors
/// and replaces them with correctly-scaled fresh noise. A slowly varying
/// *bias* field is the opposite regime (no sampler can average it out);
/// `new_with_freq` exposes the knob for the ablation bench.
pub struct PerturbedModel<M: ModelEval> {
    pub inner: M,
    /// Perturbation amplitude ε (0 = exact model; larger ↔ earlier epoch).
    pub eps: f64,
    n_modes: usize,
    freqs: Vec<Vec<f64>>, // n_modes × dim
    omegas: Vec<f64>,
    phases: Vec<f64>,
    coefs: Vec<Vec<f64>>, // dim × n_modes
    label: String,
}

impl<M: ModelEval> PerturbedModel<M> {
    pub fn new(inner: M, eps: f64, seed: u64) -> Self {
        Self::new_with_freq(inner, eps, seed, 60.0)
    }

    /// `time_freq` scales the temporal frequencies ω_j (see type docs):
    /// large ⇒ per-step-decorrelated error (undertrained-network regime),
    /// ~0 ⇒ persistent bias field.
    pub fn new_with_freq(inner: M, eps: f64, seed: u64, time_freq: f64) -> Self {
        let dim = inner.dim();
        let n_modes = 6;
        let mut rng = Xoshiro256pp::new(seed ^ 0x5eed_1234);
        let freqs = (0..n_modes)
            .map(|_| (0..dim).map(|_| rng.uniform_in(-1.2, 1.2)).collect())
            .collect();
        let omegas = (0..n_modes)
            .map(|_| rng.uniform_in(0.5, 1.0) * time_freq)
            .collect();
        let phases = (0..n_modes)
            .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI))
            .collect();
        // Normalize so the worst-case |δ| per dim is exactly eps.
        let raw: Vec<Vec<f64>> = (0..dim)
            .map(|_| (0..n_modes).map(|_| rng.uniform_in(-1.0, 1.0)).collect())
            .collect();
        let coefs = raw
            .into_iter()
            .map(|row: Vec<f64>| {
                let s: f64 = row.iter().map(|c| c.abs()).sum::<f64>().max(1e-12);
                row.into_iter().map(|c| c / s).collect()
            })
            .collect();
        let label = format!("perturbed(eps={eps})");
        PerturbedModel { inner, eps, n_modes, freqs, omegas, phases, coefs, label }
    }
}

impl<M: ModelEval> ModelEval for PerturbedModel<M> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_batch(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]) {
        self.inner.eval_batch(xs, ctx, out);
        if self.eps == 0.0 {
            return;
        }
        let dim = self.dim();
        let n = xs.len() / dim;
        let mut mode_vals = vec![0.0; self.n_modes];
        for i in 0..n {
            let row = &xs[i * dim..(i + 1) * dim];
            for j in 0..self.n_modes {
                let kx = crate::linalg::dot(&self.freqs[j], row);
                mode_vals[j] = (kx + self.omegas[j] * ctx.t + self.phases[j]).sin();
            }
            let orow = &mut out[i * dim..(i + 1) * dim];
            for d in 0..dim {
                let delta: f64 = self.coefs[d]
                    .iter()
                    .zip(&mode_vals)
                    .map(|(c, m)| c * m)
                    .sum();
                orow[d] += self.eps * delta;
            }
        }
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// NFE-counting wrapper (one "function evaluation" = one batched call,
/// matching the paper's per-sample NFE accounting).
pub struct CountingModel<'a> {
    pub inner: &'a dyn ModelEval,
    count: std::sync::atomic::AtomicUsize,
}

impl<'a> CountingModel<'a> {
    pub fn new(inner: &'a dyn ModelEval) -> Self {
        CountingModel { inner, count: std::sync::atomic::AtomicUsize::new(0) }
    }

    pub fn count(&self) -> usize {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<'a> ModelEval for CountingModel<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_batch(&self, xs: &[f64], ctx: &EvalCtx, out: &mut [f64]) {
        self.count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.eval_batch(xs, ctx, out);
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gmm_model() -> GmmAnalytic {
        GmmAnalytic::new(Gmm::structured(4, 3, 2.0, 7))
    }

    #[test]
    fn gmm_analytic_matches_gmm() {
        let m = gmm_model();
        let mut rng = Xoshiro256pp::new(1);
        let xs = m.gmm.sample_marginal(&mut rng, 5, 0.8, 0.5);
        let ctx = EvalCtx { t: 0.3, alpha: 0.8, sigma: 0.5 };
        let mut out = vec![0.0; xs.len()];
        m.eval_batch(&xs, &ctx, &mut out);
        let want = m.gmm.posterior_mean_batch(&xs, 0.8, 0.5);
        assert_eq!(out, want);
    }

    #[test]
    fn perturbation_bounded_and_seeded() {
        let m = PerturbedModel::new(gmm_model(), 0.3, 99);
        let m2 = PerturbedModel::new(gmm_model(), 0.3, 99);
        let base = gmm_model();
        let ctx = EvalCtx { t: 0.5, alpha: 0.7, sigma: 0.7 };
        let mut rng = Xoshiro256pp::new(2);
        let xs = base.gmm.sample_marginal(&mut rng, 16, 0.7, 0.7);
        let mut a = vec![0.0; xs.len()];
        let mut b = vec![0.0; xs.len()];
        let mut clean = vec![0.0; xs.len()];
        m.eval_batch(&xs, &ctx, &mut a);
        m2.eval_batch(&xs, &ctx, &mut b);
        base.eval_batch(&xs, &ctx, &mut clean);
        assert_eq!(a, b, "same seed must give identical perturbation");
        let max_dev = a
            .iter()
            .zip(&clean)
            .map(|(p, c)| (p - c).abs())
            .fold(0.0f64, f64::max);
        assert!(max_dev <= 0.3 + 1e-12, "max_dev={max_dev}");
        assert!(max_dev > 0.01, "perturbation should be non-trivial");
    }

    #[test]
    fn eps_zero_is_exact() {
        let m = PerturbedModel::new(gmm_model(), 0.0, 99);
        let base = gmm_model();
        let ctx = EvalCtx { t: 0.5, alpha: 0.7, sigma: 0.7 };
        let xs = vec![0.1; 8];
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        m.eval_batch(&xs, &ctx, &mut a);
        base.eval_batch(&xs, &ctx, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn counting_counts() {
        let base = gmm_model();
        let counting = CountingModel::new(&base);
        let ctx = EvalCtx { t: 0.5, alpha: 0.7, sigma: 0.7 };
        let xs = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        assert_eq!(counting.count(), 0);
        counting.eval_batch(&xs, &ctx, &mut out);
        counting.eval_batch(&xs, &ctx, &mut out);
        assert_eq!(counting.count(), 2);
    }

    #[test]
    fn perturbed_close_at_small_sigma() {
        // The perturbation is additive and bounded; sanity that outputs stay
        // finite and deterministic across calls.
        let m = PerturbedModel::new(gmm_model(), 1.0, 3);
        let ctx = EvalCtx { t: 0.01, alpha: 0.99, sigma: 0.05 };
        let xs = vec![0.5; 16];
        let mut out = vec![0.0; 16];
        m.eval_batch(&xs, &ctx, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
