//! τ(t) — the stochasticity-scale function of the variance-controlled
//! diffusion SDEs (Prop. 4.1). τ ≡ 0 recovers the probability-flow ODE,
//! τ ≡ 1 the vanilla reverse SDE; the paper's §E uses constants and an
//! EDM-style *interval* function (τ on a σ^{EDM} band, 0 outside).
//!
//! All solver integrals live on the λ = log-SNR axis, so the trait is
//! expressed in λ. Exact ∫τ²dλ is provided for every built-in; solvers use
//! `const_pieces` to get piecewise-constant decompositions for the exact
//! coefficient path and fall back to quadrature otherwise.

/// τ as a function of λ.
#[derive(Debug, Clone, PartialEq)]
pub enum TauFn {
    /// τ(λ) ≡ c.
    Constant(f64),
    /// τ(λ) = c on [lam_lo, lam_hi], 0 elsewhere (EDM-style band; the paper
    /// activates τ for σ^{EDM} ∈ [0.05, 1] on CIFAR10, §E.1).
    Interval { tau: f64, lam_lo: f64, lam_hi: f64 },
    /// τ(λ) = (a + b·λ) clamped to ≥ 0 — exercises the quadrature path.
    Linear { a: f64, b: f64 },
}

impl TauFn {
    /// Deterministic (ODE) limit.
    pub fn ode() -> Self {
        TauFn::Constant(0.0)
    }

    /// The paper's EDM-style band given in σ^{EDM} units: active where
    /// σ^{EDM} = e^{−λ} ∈ [sigma_lo, sigma_hi].
    pub fn interval_from_sigma(tau: f64, sigma_lo: f64, sigma_hi: f64) -> Self {
        assert!(sigma_lo > 0.0 && sigma_hi > sigma_lo);
        TauFn::Interval { tau, lam_lo: -sigma_hi.ln(), lam_hi: -sigma_lo.ln() }
    }

    /// τ(λ).
    pub fn value(&self, lam: f64) -> f64 {
        match *self {
            TauFn::Constant(c) => c,
            TauFn::Interval { tau, lam_lo, lam_hi } => {
                if (lam_lo..=lam_hi).contains(&lam) {
                    tau
                } else {
                    0.0
                }
            }
            TauFn::Linear { a, b } => (a + b * lam).max(0.0),
        }
    }

    /// Largest τ over [l0, l1] (used by error-bound diagnostics).
    pub fn max_on(&self, l0: f64, l1: f64) -> f64 {
        match *self {
            TauFn::Constant(c) => c,
            TauFn::Interval { tau, lam_lo, lam_hi } => {
                if l1 >= lam_lo && l0 <= lam_hi {
                    tau
                } else {
                    0.0
                }
            }
            TauFn::Linear { .. } => self.value(l0).max(self.value(l1)),
        }
    }

    /// Exact ∫_{l0}^{l1} τ²(λ) dλ, l0 ≤ l1.
    pub fn int_tau2(&self, l0: f64, l1: f64) -> f64 {
        debug_assert!(l1 >= l0);
        match *self {
            TauFn::Constant(c) => c * c * (l1 - l0),
            TauFn::Interval { tau, lam_lo, lam_hi } => {
                let a = l0.max(lam_lo);
                let b = l1.min(lam_hi);
                if b > a {
                    tau * tau * (b - a)
                } else {
                    0.0
                }
            }
            TauFn::Linear { a, b } => {
                if b == 0.0 {
                    return (a.max(0.0)).powi(2) * (l1 - l0);
                }
                // τ = max(a+bλ, 0): integrate (a+bλ)² over the sub-interval
                // where it is positive.
                let root = -a / b;
                let (lo, hi) = if b > 0.0 {
                    (l0.max(root), l1)
                } else {
                    (l0, l1.min(root))
                };
                if hi <= lo {
                    return 0.0;
                }
                let g = |x: f64| (a + b * x).powi(3) / (3.0 * b);
                g(hi) - g(lo)
            }
        }
    }

    /// Piecewise-constant decomposition of τ on [l0, l1] if one exists:
    /// list of (start, end, τ) covering the interval in order. `None` for
    /// genuinely non-constant shapes (quadrature path).
    pub fn const_pieces(&self, l0: f64, l1: f64) -> Option<Vec<(f64, f64, f64)>> {
        match *self {
            TauFn::Constant(c) => Some(vec![(l0, l1, c)]),
            TauFn::Interval { tau, lam_lo, lam_hi } => {
                let mut pieces = Vec::new();
                let mut cursor = l0;
                if lam_lo > cursor && lam_lo < l1 {
                    pieces.push((cursor, lam_lo, 0.0));
                    cursor = lam_lo;
                }
                let band_end = l1.min(lam_hi);
                if band_end > cursor {
                    let inside = cursor >= lam_lo && cursor <= lam_hi;
                    pieces.push((cursor, band_end, if inside { tau } else { 0.0 }));
                    cursor = band_end;
                }
                if cursor < l1 {
                    pieces.push((cursor, l1, 0.0));
                }
                if pieces.is_empty() {
                    pieces.push((l0, l1, self.value(l0)));
                }
                Some(pieces)
            }
            TauFn::Linear { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quad::GaussLegendre;
    use crate::util::close;

    #[test]
    fn constant_integral() {
        let t = TauFn::Constant(0.8);
        assert!(close(t.int_tau2(-2.0, 3.0), 0.64 * 5.0, 1e-14, 0.0));
        assert_eq!(t.value(0.0), 0.8);
    }

    #[test]
    fn interval_from_sigma_band() {
        // Active for σ ∈ [0.05, 1] ⇒ λ ∈ [0, ln 20].
        let t = TauFn::interval_from_sigma(1.0, 0.05, 1.0);
        assert_eq!(t.value(-0.5), 0.0);
        assert_eq!(t.value(0.5), 1.0);
        assert_eq!(t.value(20f64.ln() + 0.1), 0.0);
    }

    #[test]
    fn integrals_match_quadrature() {
        let gl = GaussLegendre::new(64);
        let fns = [
            TauFn::Constant(1.3),
            TauFn::interval_from_sigma(0.9, 0.05, 1.0),
            TauFn::Linear { a: 0.5, b: 0.25 },
            TauFn::Linear { a: 0.2, b: -0.4 },
        ];
        for f in &fns {
            for (l0, l1) in [(-3.0, -1.0), (-1.0, 0.5), (0.0, 4.0), (-5.0, 5.0)] {
                let exact = f.int_tau2(l0, l1);
                // Fine panel quadrature so kinks inside panels are benign.
                let panels = 512;
                let mut q = 0.0;
                for p in 0..panels {
                    let a = l0 + (l1 - l0) * p as f64 / panels as f64;
                    let b = l0 + (l1 - l0) * (p + 1) as f64 / panels as f64;
                    q += gl.integrate(a, b, |x| f.value(x).powi(2));
                }
                assert!(
                    close(exact, q, 1e-3, 1e-4),
                    "{f:?} on [{l0},{l1}]: exact={exact} quad={q}"
                );
            }
        }
    }

    #[test]
    fn const_pieces_cover_and_match() {
        let f = TauFn::interval_from_sigma(0.7, 0.05, 1.0);
        let (l0, l1) = (-2.0, 5.0);
        let pieces = f.const_pieces(l0, l1).unwrap();
        // Cover the interval exactly, in order.
        assert!(close(pieces[0].0, l0, 1e-14, 0.0));
        assert!(close(pieces.last().unwrap().1, l1, 1e-14, 0.0));
        for w in pieces.windows(2) {
            assert!(close(w[0].1, w[1].0, 1e-14, 0.0));
        }
        // Values agree with `value` at piece midpoints.
        for (a, b, tau) in &pieces {
            let mid = 0.5 * (a + b);
            assert_eq!(*tau, f.value(mid), "piece [{a},{b}]");
        }
        // Summed integral matches.
        let s: f64 = pieces.iter().map(|(a, b, t)| t * t * (b - a)).sum();
        assert!(close(s, f.int_tau2(l0, l1), 1e-12, 0.0));
    }

    #[test]
    fn linear_has_no_const_pieces() {
        assert!(TauFn::Linear { a: 1.0, b: 0.1 }.const_pieces(0.0, 1.0).is_none());
    }

    #[test]
    fn linear_clamped_integral() {
        // b < 0, root inside: only [l0, root] contributes.
        let f = TauFn::Linear { a: 1.0, b: -1.0 }; // τ = 1-λ for λ<1
        let got = f.int_tau2(0.0, 2.0);
        assert!(close(got, 1.0 / 3.0, 1e-12, 0.0), "got {got}");
    }
}
