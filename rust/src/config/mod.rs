//! Typed configuration for samplers, serving, and workloads, loadable from
//! JSON files (`--config path`) with CLI overrides.

use crate::jsonlite::{parse, Value};
use crate::schedule::StepSelector;
use crate::tau::TauFn;
use crate::util::error::{Error, Result};

/// Which sampling algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// The paper's SA-Solver (Alg. 1).
    Sa,
    /// DDIM with η (Song et al. 2021).
    Ddim,
    /// Ancestral DDPM sampling.
    Ddpm,
    /// Euler–Maruyama on the reverse SDE (τ from config).
    EulerMaruyama,
    /// DPM-Solver-2 (singlestep midpoint, noise prediction).
    DpmSolver2,
    /// DPM-Solver++(2M) (multistep, data prediction).
    DpmSolverPp2m,
    /// UniPC p-step predictor-corrector (ODE).
    UniPc,
    /// EDM deterministic Heun.
    Heun,
    /// EDM stochastic (churn) sampler.
    EdmSde,
}

impl SolverKind {
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "sa" | "sa_solver" => SolverKind::Sa,
            "ddim" => SolverKind::Ddim,
            "ddpm" => SolverKind::Ddpm,
            "euler_maruyama" | "em" => SolverKind::EulerMaruyama,
            "dpm_solver2" => SolverKind::DpmSolver2,
            "dpm_solver_pp_2m" | "dpm++2m" => SolverKind::DpmSolverPp2m,
            "unipc" => SolverKind::UniPc,
            "heun" => SolverKind::Heun,
            "edm_sde" => SolverKind::EdmSde,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Sa => "sa",
            SolverKind::Ddim => "ddim",
            SolverKind::Ddpm => "ddpm",
            SolverKind::EulerMaruyama => "euler_maruyama",
            SolverKind::DpmSolver2 => "dpm_solver2",
            SolverKind::DpmSolverPp2m => "dpm_solver_pp_2m",
            SolverKind::UniPc => "unipc",
            SolverKind::Heun => "heun",
            SolverKind::EdmSde => "edm_sde",
        }
    }

    /// Every solver, for zoo-style sweeps.
    pub fn all() -> &'static [SolverKind] {
        &[
            SolverKind::Sa,
            SolverKind::Ddim,
            SolverKind::Ddpm,
            SolverKind::EulerMaruyama,
            SolverKind::DpmSolver2,
            SolverKind::DpmSolverPp2m,
            SolverKind::UniPc,
            SolverKind::Heun,
            SolverKind::EdmSde,
        ]
    }
}

/// Score-model reparameterization (paper §3 / Remark 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prediction {
    /// x_θ(x, t) ≈ E[x₀|x_t] — the paper's recommended choice.
    Data,
    /// ε_θ(x, t) — shown inferior for SDE solving (Table 1, §A.2.4).
    Noise,
}

/// Shape of τ(t).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TauKind {
    Constant,
    /// EDM-style band in σ^{EDM} units (paper §E.1).
    IntervalSigma { sigma_lo: f64, sigma_hi: f64 },
}

/// Full sampler configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplerConfig {
    pub solver: SolverKind,
    /// Number of model evaluations (the paper's NFE).
    pub nfe: usize,
    /// τ magnitude (stochasticity scale).
    pub tau: f64,
    pub tau_kind: TauKind,
    /// SA predictor steps s (Eq. 14).
    pub predictor_steps: usize,
    /// SA corrector steps ŝ (Eq. 17); 0 disables the corrector.
    pub corrector_steps: usize,
    pub prediction: Prediction,
    pub selector: StepSelector,
    /// DDIM η.
    pub eta: f64,
    /// EDM stochastic sampler hyperparameters {S_churn, S_noise, S_tmin, S_tmax}.
    pub churn: f64,
    pub s_noise: f64,
    pub s_tmin: f64,
    pub s_tmax: f64,
}

impl SamplerConfig {
    /// SA-Solver defaults per the paper's §E.1: 3-step predictor, 3-step
    /// corrector, uniform-λ steps, constant τ = 1.
    pub fn sa_default() -> Self {
        SamplerConfig {
            solver: SolverKind::Sa,
            nfe: 20,
            tau: 1.0,
            tau_kind: TauKind::Constant,
            predictor_steps: 3,
            corrector_steps: 3,
            prediction: Prediction::Data,
            selector: StepSelector::UniformLambda,
            eta: 0.0,
            churn: 0.0,
            s_noise: 1.0,
            s_tmin: 0.05,
            s_tmax: 50.0,
        }
    }

    /// Defaults for a given solver family.
    pub fn for_solver(kind: SolverKind) -> Self {
        let mut c = Self::sa_default();
        c.solver = kind;
        match kind {
            SolverKind::Ddim => {
                c.tau = 0.0;
                c.eta = 0.0;
            }
            SolverKind::Heun | SolverKind::UniPc | SolverKind::DpmSolverPp2m
            | SolverKind::DpmSolver2 => {
                c.tau = 0.0;
            }
            SolverKind::EdmSde => {
                c.churn = 40.0;
                c.s_noise = 1.003;
            }
            _ => {}
        }
        c
    }

    /// The τ(λ) function this config denotes.
    pub fn tau_fn(&self) -> TauFn {
        match self.tau_kind {
            TauKind::Constant => TauFn::Constant(self.tau),
            TauKind::IntervalSigma { sigma_lo, sigma_hi } => {
                TauFn::interval_from_sigma(self.tau, sigma_lo, sigma_hi)
            }
        }
    }

    /// Number of solver *steps* M for this NFE budget. SA-Solver (and the
    /// other multistep methods here) spend one model evaluation per step
    /// plus one to initialize the buffer at t₀, so M = NFE − 1.
    /// DPM-Solver-2 spends two evaluations per step; Heun/EDM two per step
    /// (minus the trailing Euler step).
    pub fn steps_for_nfe(&self) -> usize {
        match self.solver {
            SolverKind::DpmSolver2 => (self.nfe / 2).max(1),
            SolverKind::Heun | SolverKind::EdmSde => ((self.nfe + 1) / 2).max(1),
            SolverKind::Sa | SolverKind::UniPc => self.nfe.saturating_sub(1).max(1),
            // One eval per step, no warm-up eval needed.
            _ => self.nfe.max(1),
        }
    }

    /// Parse from a JSON object; missing fields take defaults.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = if let Some(name) = v.get("solver").and_then(Value::as_str) {
            let kind = SolverKind::by_name(name)
                .ok_or_else(|| Error::config(format!("unknown solver '{name}'")))?;
            Self::for_solver(kind)
        } else {
            Self::sa_default()
        };
        c.nfe = v.opt_usize("nfe", c.nfe);
        c.tau = v.opt_f64("tau", c.tau);
        c.predictor_steps = v.opt_usize("predictor_steps", c.predictor_steps);
        c.corrector_steps = v.opt_usize("corrector_steps", c.corrector_steps);
        c.eta = v.opt_f64("eta", c.eta);
        c.churn = v.opt_f64("churn", c.churn);
        c.s_noise = v.opt_f64("s_noise", c.s_noise);
        c.s_tmin = v.opt_f64("s_tmin", c.s_tmin);
        c.s_tmax = v.opt_f64("s_tmax", c.s_tmax);
        match v.opt_str("prediction", "data") {
            "data" => c.prediction = Prediction::Data,
            "noise" => c.prediction = Prediction::Noise,
            other => return Err(Error::config(format!("unknown prediction '{other}'"))),
        }
        if let Some(sel) = v.get("selector").and_then(Value::as_str) {
            c.selector = StepSelector::by_name(sel)
                .ok_or_else(|| Error::config(format!("unknown selector '{sel}'")))?;
            if let StepSelector::EdmRho { .. } = c.selector {
                c.selector = StepSelector::EdmRho { rho: v.opt_f64("selector_rho", 7.0) };
            }
        }
        match v.opt_str("tau_kind", "constant") {
            "constant" => c.tau_kind = TauKind::Constant,
            "interval" => {
                c.tau_kind = TauKind::IntervalSigma {
                    sigma_lo: v.opt_f64("tau_sigma_lo", 0.05),
                    sigma_hi: v.opt_f64("tau_sigma_hi", 1.0),
                }
            }
            other => return Err(Error::config(format!("unknown tau_kind '{other}'"))),
        }
        c.validate()?;
        Ok(c)
    }

    /// Serialize to JSON (inverse of `from_json`).
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("solver", Value::Str(self.solver.name().into())),
            ("nfe", Value::Num(self.nfe as f64)),
            ("tau", Value::Num(self.tau)),
            ("predictor_steps", Value::Num(self.predictor_steps as f64)),
            ("corrector_steps", Value::Num(self.corrector_steps as f64)),
            (
                "prediction",
                Value::Str(
                    match self.prediction {
                        Prediction::Data => "data",
                        Prediction::Noise => "noise",
                    }
                    .into(),
                ),
            ),
            ("eta", Value::Num(self.eta)),
            ("churn", Value::Num(self.churn)),
            ("s_noise", Value::Num(self.s_noise)),
            ("s_tmin", Value::Num(self.s_tmin)),
            ("s_tmax", Value::Num(self.s_tmax)),
            ("selector", Value::Str(self.selector.name().into())),
        ];
        if let StepSelector::EdmRho { rho } = self.selector {
            fields.push(("selector_rho", Value::Num(rho)));
        }
        match self.tau_kind {
            TauKind::Constant => fields.push(("tau_kind", Value::Str("constant".into()))),
            TauKind::IntervalSigma { sigma_lo, sigma_hi } => {
                fields.push(("tau_kind", Value::Str("interval".into())));
                fields.push(("tau_sigma_lo", Value::Num(sigma_lo)));
                fields.push(("tau_sigma_hi", Value::Num(sigma_hi)));
            }
        }
        Value::obj(fields)
    }

    /// Sanity checks; called by from_json and the server.
    pub fn validate(&self) -> Result<()> {
        if self.nfe == 0 || self.nfe > 10_000 {
            return Err(Error::config(format!("nfe {} out of range", self.nfe)));
        }
        if !(0.0..=16.0).contains(&self.tau) || !self.tau.is_finite() {
            return Err(Error::config(format!("tau {} out of range", self.tau)));
        }
        if self.solver == SolverKind::Sa {
            if self.predictor_steps == 0 || self.predictor_steps > 6 {
                return Err(Error::config("predictor_steps must be 1..=6"));
            }
            if self.corrector_steps > 6 {
                return Err(Error::config("corrector_steps must be 0..=6"));
            }
        }
        if !(0.0..=2.0).contains(&self.eta) {
            return Err(Error::config("eta must be in [0,2]"));
        }
        // ρ shapes the EDM grid as σ^{1/ρ}: ρ ≤ 0 (or non-finite) collapses
        // the grid to a point and the solver steps divide by h = 0. This
        // surface takes untrusted values since `selector_rho` joined the
        // wire format.
        if let StepSelector::EdmRho { rho } = self.selector {
            if !rho.is_finite() || !(0.1..=100.0).contains(&rho) {
                return Err(Error::config(format!(
                    "selector_rho {rho} out of range (0.1..=100)"
                )));
            }
        }
        Ok(())
    }
}

/// Serving configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    pub addr: String,
    /// Max requests merged into one model batch.
    pub max_batch: usize,
    /// Flush deadline for a partially filled batch, milliseconds.
    pub batch_deadline_ms: u64,
    /// Worker threads executing solver loops.
    pub workers: usize,
    /// Upper bound on queued requests before shedding load.
    pub queue_cap: usize,
    /// Lane-parallel executor threads *within* one batch's solver loop
    /// (`exec::Executor`); `0` = auto (one per available core). Output is
    /// bit-identical for any value. Distinct from `workers`, which
    /// parallelizes across independent batches. The default is `0`
    /// (auto): the server owns one persistent parked pool shared by all
    /// workers, and the pool serializes concurrent dispatches, so the
    /// active thread count is bounded by the pool width — `workers ×
    /// threads` oversubscription cannot happen, which is what used to
    /// force the sequential default back when executors scoped-spawned
    /// fresh threads per call (see `exec` and the `exec` section of
    /// `BENCH_perf.json` for the per-dispatch numbers behind the flip).
    pub threads: usize,
    /// Lane groups a worker may hold in flight at once. The step-
    /// synchronous scheduler interleaves steps across its in-flight groups
    /// and admits newly queued compatible requests at step boundaries, so
    /// values > 1 let fresh requests start making progress while a long
    /// solve is still running (continuous batching). `1` reproduces the
    /// old run-to-completion behavior.
    pub max_inflight: usize,
    /// Path to a tuner preset registry (`sadiff tune` output) to load at
    /// bind time; enables the request `"preset"` field and the `presets`
    /// protocol command.
    pub presets_path: Option<String>,
    /// Path to the serving checkpoint file. When set, every worker rewrites
    /// the in-flight set at step boundaries (see `checkpoint_every`), and a
    /// restarting server resumes the checkpointed groups — their results
    /// land in the `{"cmd":"recover"}` store since the original connections
    /// are gone. `None` disables checkpointing entirely.
    pub checkpoint_path: Option<String>,
    /// Scheduler steps between checkpoint rewrites, per worker (the file is
    /// also rewritten whenever the in-flight set changes — admission,
    /// retirement, cancellation). Clamped to ≥ 1; only meaningful with
    /// `checkpoint_path` set.
    pub checkpoint_every: u64,
    /// Default path for trace dumps (`obs::chrome` Chrome Trace Event
    /// JSON). When set, the span recorder starts capturing at bind time
    /// and `{"cmd":"trace","action":"dump"}` writes here unless the
    /// command carries its own `"path"`. `None` leaves tracing off until
    /// a client sends `{"cmd":"trace","action":"start"}`.
    pub trace_path: Option<String>,
    /// Per-thread trace ring capacity, in events (`obs::trace`). Applied
    /// at bind time; the recorder clamps it to ≥ 16.
    pub trace_capacity: usize,
    /// Queued-lane shed cap: a request is shed when admitting it would push
    /// the batcher past this many queued *lanes* (samples), in addition to
    /// the `queue_cap` request-count check. `0` derives the cap as
    /// `queue_cap × max_batch` — without it a single `n=100000` request
    /// occupies one queue slot while swamping the lane budget. An empty
    /// queue always admits, so one oversized request stays servable.
    pub queue_lane_cap: usize,
    /// How long a connection waits for its reply before giving up, in
    /// milliseconds. On expiry the ticket is cancelled through the normal
    /// cancel path (queued: removed; in flight: lanes freed at the owning
    /// worker's next step boundary) so abandoned work stops burning NFEs.
    pub reply_timeout_ms: u64,
    /// Per-worker budget of in-flight lanes: a worker admits a fresh group
    /// only while its active lanes plus the group's seed request stay
    /// within the budget (a group is always admitted when the worker is
    /// idle, so one oversized request cannot starve). `0` = unlimited.
    pub max_step_lanes: usize,
    /// Keep per-worker in-flight snapshots in memory even without a
    /// `checkpoint_path`, so the `snapshot` protocol command (the router's
    /// heartbeat) can report live group checkpoints for failover. Implied
    /// by `checkpoint_path`; this flag enables the snapshot sink without
    /// paying for the file writes.
    pub publish_snapshots: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            max_batch: 8,
            batch_deadline_ms: 5,
            workers: 2,
            queue_cap: 256,
            threads: 0,
            max_inflight: 4,
            presets_path: None,
            checkpoint_path: None,
            checkpoint_every: 16,
            trace_path: None,
            trace_capacity: crate::obs::trace::DEFAULT_CAPACITY,
            queue_lane_cap: 0,
            reply_timeout_ms: 120_000,
            max_step_lanes: 0,
            publish_snapshots: false,
        }
    }
}

impl ServerConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Self::default();
        let deadline_ms = v.opt_usize("batch_deadline_ms", d.batch_deadline_ms as usize);
        Ok(ServerConfig {
            addr: v.opt_str("addr", &d.addr).to_string(),
            max_batch: v.opt_usize("max_batch", d.max_batch),
            batch_deadline_ms: deadline_ms as u64,
            workers: v.opt_usize("workers", d.workers).max(1),
            queue_cap: v.opt_usize("queue_cap", d.queue_cap),
            threads: v.opt_usize("threads", d.threads),
            max_inflight: v.opt_usize("max_inflight", d.max_inflight).max(1),
            presets_path: v.get("presets").and_then(Value::as_str).map(String::from),
            checkpoint_path: v.get("checkpoint").and_then(Value::as_str).map(String::from),
            checkpoint_every: v
                .opt_usize("checkpoint_every", d.checkpoint_every as usize)
                .max(1) as u64,
            trace_path: v.get("trace").and_then(Value::as_str).map(String::from),
            trace_capacity: v.opt_usize("trace_capacity", d.trace_capacity),
            queue_lane_cap: v.opt_usize("queue_lane_cap", d.queue_lane_cap),
            reply_timeout_ms: v
                .opt_usize("reply_timeout_ms", d.reply_timeout_ms as usize)
                .max(1) as u64,
            max_step_lanes: v.opt_usize("max_step_lanes", d.max_step_lanes),
            publish_snapshots: v.opt_bool("publish_snapshots", d.publish_snapshots),
        })
    }

    /// The effective queued-lane shed cap: `queue_lane_cap`, or the derived
    /// default `queue_cap × max_batch` when unset (0).
    pub fn effective_queue_lane_cap(&self) -> usize {
        if self.queue_lane_cap > 0 {
            self.queue_lane_cap
        } else {
            self.queue_cap.saturating_mul(self.max_batch.max(1))
        }
    }
}

/// Load any config JSON from a file path.
pub fn load_json_file(path: &str) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::config(format!("cannot read {path}: {e}")))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite;

    #[test]
    fn defaults_valid() {
        SamplerConfig::sa_default().validate().unwrap();
        for k in SolverKind::all() {
            SamplerConfig::for_solver(*k).validate().unwrap();
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut c = SamplerConfig::sa_default();
        c.nfe = 47;
        c.tau = 1.4;
        c.tau_kind = TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 };
        c.prediction = Prediction::Noise;
        c.selector = StepSelector::EdmRho { rho: 5.0 };
        let j = c.to_json();
        let c2 = SamplerConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn json_roundtrip_every_selector() {
        // The tuner persists configs with tuned grid kinds; serialization
        // must not lose the selector (or its ρ) for any of them.
        for sel in StepSelector::all() {
            let c = SamplerConfig { selector: *sel, ..SamplerConfig::sa_default() };
            let c2 = SamplerConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(c, c2, "selector {sel:?} lost in round-trip");
        }
    }

    #[test]
    fn from_json_partial_defaults() {
        let v = jsonlite::parse(r#"{"solver": "ddim", "eta": 1.0}"#).unwrap();
        let c = SamplerConfig::from_json(&v).unwrap();
        assert_eq!(c.solver, SolverKind::Ddim);
        assert_eq!(c.eta, 1.0);
        assert_eq!(c.nfe, 20);
    }

    #[test]
    fn from_json_rejects_bad() {
        for bad in [
            r#"{"solver": "bogus"}"#,
            r#"{"nfe": 0}"#,
            r#"{"tau": -1}"#,
            r#"{"prediction": "wat"}"#,
            r#"{"predictor_steps": 9}"#,
            r#"{"selector": "edm_rho", "selector_rho": 0}"#,
            r#"{"selector": "edm_rho", "selector_rho": -7}"#,
            r#"{"selector": "edm_rho", "selector_rho": 1e9}"#,
        ] {
            let v = jsonlite::parse(bad).unwrap();
            assert!(SamplerConfig::from_json(&v).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn nfe_to_steps_accounting() {
        let mut c = SamplerConfig::sa_default();
        c.nfe = 20;
        assert_eq!(c.steps_for_nfe(), 19); // warm-up eval + 1/step
        c.solver = SolverKind::Ddim;
        assert_eq!(c.steps_for_nfe(), 20);
        c.solver = SolverKind::Heun;
        assert_eq!(c.steps_for_nfe(), 10); // 2 evals/step, last step Euler
        c.solver = SolverKind::DpmSolver2;
        assert_eq!(c.steps_for_nfe(), 10);
    }

    #[test]
    fn tau_fn_shapes() {
        let mut c = SamplerConfig::sa_default();
        c.tau = 0.8;
        assert_eq!(c.tau_fn(), crate::tau::TauFn::Constant(0.8));
        c.tau_kind = TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 };
        match c.tau_fn() {
            crate::tau::TauFn::Interval { tau, .. } => assert_eq!(tau, 0.8),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn server_config_parse() {
        let v = jsonlite::parse(r#"{"max_batch": 16, "workers": 0}"#).unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.workers, 1); // clamped
        assert_eq!(c.addr, ServerConfig::default().addr);
        assert_eq!(c.threads, 0); // default: auto — the shared server pool sizes to the host

        let v = jsonlite::parse(r#"{"threads": 3}"#).unwrap();
        assert_eq!(ServerConfig::from_json(&v).unwrap().threads, 3);

        assert_eq!(c.max_inflight, ServerConfig::default().max_inflight);
        let v = jsonlite::parse(r#"{"max_inflight": 0}"#).unwrap();
        assert_eq!(ServerConfig::from_json(&v).unwrap().max_inflight, 1); // clamped
        let v = jsonlite::parse(r#"{"max_inflight": 7}"#).unwrap();
        assert_eq!(ServerConfig::from_json(&v).unwrap().max_inflight, 7);

        assert_eq!(c.presets_path, None);
        let v = jsonlite::parse(r#"{"presets": "presets.json"}"#).unwrap();
        assert_eq!(
            ServerConfig::from_json(&v).unwrap().presets_path,
            Some("presets.json".to_string())
        );

        assert_eq!(c.checkpoint_path, None);
        assert_eq!(c.checkpoint_every, ServerConfig::default().checkpoint_every);
        let v = jsonlite::parse(r#"{"checkpoint": "ck.json", "checkpoint_every": 0}"#).unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.checkpoint_path, Some("ck.json".to_string()));
        assert_eq!(c.checkpoint_every, 1); // clamped
    }

    #[test]
    fn server_config_slo_fields() {
        let d = ServerConfig::default();
        assert_eq!(d.queue_lane_cap, 0);
        assert_eq!(d.reply_timeout_ms, 120_000);
        assert_eq!(d.max_step_lanes, 0);
        // Derived lane cap: queue_cap × max_batch when unset.
        assert_eq!(d.effective_queue_lane_cap(), d.queue_cap * d.max_batch);

        let v = jsonlite::parse(
            r#"{"queue_lane_cap": 512, "reply_timeout_ms": 250, "max_step_lanes": 64}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.queue_lane_cap, 512);
        assert_eq!(c.effective_queue_lane_cap(), 512);
        assert_eq!(c.reply_timeout_ms, 250);
        assert_eq!(c.max_step_lanes, 64);

        // reply_timeout_ms 0 would make every request time out instantly —
        // clamped to 1.
        let v = jsonlite::parse(r#"{"reply_timeout_ms": 0}"#).unwrap();
        assert_eq!(ServerConfig::from_json(&v).unwrap().reply_timeout_ms, 1);
    }

    #[test]
    fn server_config_publish_snapshots() {
        assert!(!ServerConfig::default().publish_snapshots);
        let v = jsonlite::parse(r#"{"publish_snapshots": true}"#).unwrap();
        assert!(ServerConfig::from_json(&v).unwrap().publish_snapshots);
    }
}
