//! Random number generation substrate.
//!
//! Reproducibility is a serving invariant here: a request's samples must not
//! depend on how it was batched or which worker ran it. We therefore use a
//! *counter-based* generator (Philox4x32-10, Salmon et al. 2011 — the same
//! family JAX uses) keyed by `(seed, request_id)` and indexed by
//! `(step, lane)`, so any (request, step) noise block can be generated
//! independently, in any order, on any thread.
//!
//! `SplitMix64` seeds things; `Xoshiro256++` is the cheap sequential PRNG for
//! workload generation and tests.

pub mod normal;
pub mod philox;
pub mod xoshiro;

pub use normal::{NormalSource, SplitNoise};
pub use philox::Philox4x32;
pub use xoshiro::Xoshiro256pp;

/// SplitMix64 step — the standard seed expander (Steele et al.).
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Convert a u32 to a uniform f64 in [0, 1) with 32 bits of resolution.
pub fn u32_to_unit_f64(x: u32) -> f64 {
    (x as f64) * (1.0 / 4294967296.0)
}

/// Convert a u64 to a uniform f64 in [0, 1) with 53 bits of resolution.
pub fn u64_to_unit_f64(x: u64) -> f64 {
    ((x >> 11) as f64) * (1.0 / 9007199254740992.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic_and_distinct() {
        let mut s1 = 42u64;
        let mut s2 = 42u64;
        let a = splitmix64(&mut s1);
        let b = splitmix64(&mut s2);
        assert_eq!(a, b);
        let c = splitmix64(&mut s1);
        assert_ne!(a, c);
    }

    #[test]
    fn unit_floats_in_range() {
        for x in [0u64, 1, u64::MAX, 0xDEADBEEF] {
            let f = u64_to_unit_f64(x);
            assert!((0.0..1.0).contains(&f));
        }
        for x in [0u32, 1, u32::MAX] {
            let f = u32_to_unit_f64(x);
            assert!((0.0..1.0).contains(&f));
        }
    }
}
