//! Xoshiro256++ — fast sequential PRNG (Blackman & Vigna 2019) for workload
//! generation, tests and the property harness. Not used for request noise
//! (that is Philox, see module docs).

use super::splitmix64;

/// Xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256pp { s }
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0,1).
    pub fn uniform(&mut self) -> f64 {
        super::u64_to_unit_f64(self.next_u64())
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (one value; the pair's twin is dropped
    /// for simplicity — this generator is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Exponential with rate `lambda` (for Poisson arrival traces).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Choose an index according to (unnormalized, non-negative) weights.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive mass");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{close, mean, std_dev};

    #[test]
    fn deterministic_per_seed() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(1);
        let mut c = Xoshiro256pp::new(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let cv: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Xoshiro256pp::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(4);
        let xs = r.normals(20_000);
        assert!(close(mean(&xs), 0.0, 0.0, 0.03));
        assert!(close(std_dev(&xs), 1.0, 0.03, 0.0));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256pp::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.exponential(2.0)).collect();
        assert!(close(mean(&xs), 0.5, 0.05, 0.0), "mean={}", mean(&xs));
    }

    #[test]
    fn choose_weighted_props() {
        let mut r = Xoshiro256pp::new(6);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!(close(ratio, 3.0, 0.1, 0.0), "ratio={ratio}");
    }
}
