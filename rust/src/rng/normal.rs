//! A trait-object-friendly source of Gaussian noise, so solvers can take
//! either the Philox counter stream (production) or a recorded/shared path
//! (tests that need coupled Brownian increments across solvers).

use super::Philox4x32;

/// Source of per-step standard-normal vectors.
pub trait NormalSource {
    /// Fill `out` with N(0, I) noise for `(stream, step)`.
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]);
}

/// A noise source that the lane-chunked executor (`exec`) can split by
/// lane range: `split_lanes(lane0)` yields an owned source whose *local*
/// stream `l` draws exactly what the parent draws for *global* stream
/// `lane0 + l`. Counter-based generators satisfy this for free, which is
/// what makes parallel solves bit-identical to sequential ones.
pub trait SplitNoise: Sync {
    /// An owned per-worker source offset to global lane `lane0`.
    fn split_lanes(&self, lane0: usize) -> Box<dyn NormalSource + Send>;
}

/// Wraps a source so local stream `l` maps to global stream `lane0 + l`.
pub struct LaneOffsetNormal<S> {
    pub inner: S,
    pub lane0: u64,
}

impl<S: NormalSource> NormalSource for LaneOffsetNormal<S> {
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]) {
        self.inner.fill(self.lane0 + stream, step, out);
    }
}

/// Production source: Philox counter RNG (stateless, order-independent).
pub struct PhiloxNormal {
    gen: Philox4x32,
}

impl PhiloxNormal {
    pub fn new(seed: u64) -> Self {
        PhiloxNormal { gen: Philox4x32::new(seed) }
    }
}

impl NormalSource for PhiloxNormal {
    fn fill(&mut self, stream: u64, step: u64, out: &mut [f64]) {
        self.gen.normals_into(stream, step, out);
    }
}

impl SplitNoise for PhiloxNormal {
    fn split_lanes(&self, lane0: usize) -> Box<dyn NormalSource + Send> {
        // Philox4x32 is Copy: the worker gets the same keyed generator,
        // addressed at offset streams.
        Box::new(LaneOffsetNormal { inner: PhiloxNormal { gen: self.gen }, lane0: lane0 as u64 })
    }
}

/// Test source: replays a fixed table of noise vectors keyed by step
/// (stream ignored), so two different solvers can share one Brownian path.
pub struct RecordedNormal {
    pub table: Vec<Vec<f64>>,
}

impl NormalSource for RecordedNormal {
    fn fill(&mut self, _stream: u64, step: u64, out: &mut [f64]) {
        let row = &self.table[step as usize % self.table.len()];
        for (o, v) in out.iter_mut().zip(row.iter()) {
            *o = *v;
        }
    }
}

impl SplitNoise for RecordedNormal {
    fn split_lanes(&self, _lane0: usize) -> Box<dyn NormalSource + Send> {
        // Streams are ignored by replay, so the offset is irrelevant.
        Box::new(RecordedNormal { table: self.table.clone() })
    }
}

/// Zero noise — turns any stochastic solver into its deterministic mean path.
pub struct ZeroNormal;

impl NormalSource for ZeroNormal {
    fn fill(&mut self, _stream: u64, _step: u64, out: &mut [f64]) {
        out.fill(0.0);
    }
}

impl SplitNoise for ZeroNormal {
    fn split_lanes(&self, _lane0: usize) -> Box<dyn NormalSource + Send> {
        Box::new(ZeroNormal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn philox_source_reproducible() {
        let mut a = PhiloxNormal::new(9);
        let mut b = PhiloxNormal::new(9);
        let mut x = vec![0.0; 16];
        let mut y = vec![0.0; 16];
        a.fill(2, 5, &mut x);
        b.fill(2, 5, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn recorded_replays() {
        let mut r = RecordedNormal { table: vec![vec![1.0, 2.0], vec![3.0, 4.0]] };
        let mut out = vec![0.0; 2];
        r.fill(0, 0, &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
        r.fill(7, 3, &mut out);
        assert_eq!(out, vec![3.0, 4.0]);
    }

    #[test]
    fn split_lanes_matches_offset_streams() {
        // Worker-local stream l must reproduce global stream lane0 + l.
        let parent = PhiloxNormal::new(42);
        let mut split = parent.split_lanes(5);
        let mut direct = PhiloxNormal::new(42);
        let mut a = vec![0.0; 12];
        let mut b = vec![0.0; 12];
        for (local, step) in [(0u64, 0u64), (2, 3), (7, u64::MAX)] {
            split.fill(local, step, &mut a);
            direct.fill(5 + local, step, &mut b);
            assert_eq!(a, b, "local={local} step={step}");
        }
    }

    #[test]
    fn zero_zeroes() {
        let mut z = ZeroNormal;
        let mut out = vec![5.0; 4];
        z.fill(0, 0, &mut out);
        assert!(out.iter().all(|v| *v == 0.0));
    }
}
