//! Philox4x32-10 counter-based RNG (Salmon, Moraes, Dror, Shaw, SC'11).
//!
//! Stateless: `block(counter)` maps a 128-bit counter + 64-bit key to four
//! independent uniform u32s through 10 rounds of multiply-bijections. Used
//! for per-(request, step) noise so batching order cannot change samples.

const PHILOX_M0: u32 = 0xD2511F53;
const PHILOX_M1: u32 = 0xCD9E8D57;
const PHILOX_W0: u32 = 0x9E3779B9; // golden-ratio Weyl constants
const PHILOX_W1: u32 = 0xBB67AE85;

/// Philox4x32-10 keyed generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Philox4x32 {
    key: [u32; 2],
}

#[inline]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

impl Philox4x32 {
    /// Construct from a 64-bit key (e.g. a request seed).
    pub fn new(key: u64) -> Self {
        Philox4x32 { key: [key as u32, (key >> 32) as u32] }
    }

    /// The 64-bit key this generator was constructed with. Philox is
    /// counter-based, so the key IS the whole stream state: together with a
    /// `(stream, step)` coordinate it fully determines every draw — which
    /// is what makes noise streams checkpointable without any mutable
    /// cursor to serialize.
    pub fn key_u64(&self) -> u64 {
        self.key[0] as u64 | ((self.key[1] as u64) << 32)
    }

    /// One 10-round Philox block: counter -> 4 random u32.
    pub fn block(&self, counter: [u32; 4]) -> [u32; 4] {
        let mut c = counter;
        let mut k = self.key;
        for _ in 0..10 {
            let (hi0, lo0) = mulhilo(PHILOX_M0, c[0]);
            let (hi1, lo1) = mulhilo(PHILOX_M1, c[2]);
            c = [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0];
            k[0] = k[0].wrapping_add(PHILOX_W0);
            k[1] = k[1].wrapping_add(PHILOX_W1);
        }
        c
    }

    /// Fill `out` with standard normals for logical stream coordinates
    /// `(stream, step)`. Pairs are produced by Box–Muller over two uniforms;
    /// element `i` of the block is addressed by counter word 0 so arbitrary
    /// slices are reproducible regardless of call pattern.
    pub fn normals_into(&self, stream: u64, step: u64, out: &mut [f64]) {
        let mut i = 0usize;
        let mut blk = 0u32;
        while i < out.len() {
            let ctr = [
                blk,
                (step as u32) ^ ((stream >> 32) as u32).rotate_left(16),
                step.wrapping_shr(32) as u32,
                stream as u32,
            ];
            let r = self.block(ctr);
            // 4 u32 -> 2 f64 uniforms -> 2 normals
            let u1 = to_open_unit(((r[0] as u64) << 32) | r[1] as u64);
            let u2 = super::u64_to_unit_f64(((r[2] as u64) << 32) | r[3] as u64);
            let mag = (-2.0 * u1.ln()).sqrt();
            // Box–Muller with one transcendental saved: sin derived from
            // cos via √(1−c²) with the sign read off the angle's half-turn
            // (bench_perf: the noise path is transcendental-bound).
            let ang = 2.0 * std::f64::consts::PI * u2;
            let c = ang.cos();
            out[i] = mag * c;
            i += 1;
            if i < out.len() {
                let s_abs = (1.0 - c * c).max(0.0).sqrt();
                let s = if u2 < 0.5 { s_abs } else { -s_abs };
                out[i] = mag * s;
                i += 1;
            }
            blk = blk.wrapping_add(1);
        }
    }

    /// Vector of standard normals (see [`Self::normals_into`]).
    pub fn normals(&self, stream: u64, step: u64, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.normals_into(stream, step, &mut v);
        v
    }

    /// One uniform u64 for coordinates (stream, step, idx).
    pub fn uniform_u64(&self, stream: u64, step: u64, idx: u32) -> u64 {
        let ctr = [idx, step as u32, (step >> 32) as u32, stream as u32];
        let r = self.block(ctr);
        ((r[0] as u64) << 32) | r[1] as u64
    }
}

/// u64 -> f64 in (0, 1] so `ln` is always finite.
fn to_open_unit(x: u64) -> f64 {
    let f = super::u64_to_unit_f64(x);
    if f <= 0.0 {
        f64::MIN_POSITIVE
    } else {
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{close, mean, std_dev};

    #[test]
    fn block_deterministic() {
        let p = Philox4x32::new(123);
        assert_eq!(p.block([0, 0, 0, 0]), p.block([0, 0, 0, 0]));
        assert_ne!(p.block([0, 0, 0, 0]), p.block([1, 0, 0, 0]));
        assert_ne!(
            Philox4x32::new(1).block([0; 4]),
            Philox4x32::new(2).block([0; 4])
        );
    }

    #[test]
    fn key_roundtrips() {
        for key in [0u64, 1, 0xDEAD_BEEF, u64::MAX, 1 << 53, (1 << 53) + 1] {
            assert_eq!(Philox4x32::new(key).key_u64(), key);
        }
    }

    #[test]
    fn known_avalanche() {
        // Flipping one counter bit should flip roughly half the output bits.
        let p = Philox4x32::new(0xABCDEF);
        let a = p.block([5, 6, 7, 8]);
        let b = p.block([4, 6, 7, 8]);
        let flipped: u32 = (0..4).map(|i| (a[i] ^ b[i]).count_ones()).sum();
        assert!((40..=88).contains(&flipped), "flipped={flipped}");
    }

    #[test]
    fn normals_moments() {
        let p = Philox4x32::new(7);
        let xs = p.normals(0, 0, 20_000);
        assert!(close(mean(&xs), 0.0, 0.0, 0.03), "mean={}", mean(&xs));
        assert!(close(std_dev(&xs), 1.0, 0.03, 0.0), "std={}", std_dev(&xs));
    }

    #[test]
    fn normals_independent_of_chunking() {
        // Same (stream, step) must give the same prefix regardless of length.
        let p = Philox4x32::new(99);
        let a = p.normals(3, 11, 17);
        let b = p.normals(3, 11, 64);
        assert_eq!(&a[..], &b[..17]);
    }

    #[test]
    fn streams_and_steps_decorrelated() {
        let p = Philox4x32::new(5);
        let a = p.normals(0, 0, 1000);
        let b = p.normals(1, 0, 1000);
        let c = p.normals(0, 1, 1000);
        let corr = |x: &[f64], y: &[f64]| {
            let n = x.len() as f64;
            x.iter().zip(y).map(|(a, b)| a * b).sum::<f64>() / n
        };
        assert!(corr(&a, &b).abs() < 0.05);
        assert!(corr(&a, &c).abs() < 0.05);
    }
}
