//! `cargo bench --bench bench_table2` — regenerates Table 2 (predictor/
//! corrector ablation on the CIFAR10-VE analog).

use sadiff::exps::{table2, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    table2::run(scale).print();
}
