//! `cargo bench --bench bench_table1` — regenerates Table 1 (data- vs
//! noise-prediction FID under the SDE solver) at full scale.
//! In-repo harness (`harness = false`): criterion is not in the offline
//! vendor set; see DESIGN.md §2.

use sadiff::exps::{table1, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    table1::run(scale).print();
}
