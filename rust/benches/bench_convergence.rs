//! `cargo bench --bench bench_convergence` — measures the convergence
//! orders of Theorems 5.1/5.2 (deterministic hˢ / h^{ŝ+1} component and
//! the O(τh) stochastic component).

use sadiff::exps::{convergence, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    for t in convergence::run(scale) {
        t.print();
    }
}
