//! `cargo bench --bench bench_table3` — regenerates Table 3 (DDPM at a
//! large NFE budget vs SA-Solver at a small one, on the trained DiT
//! artifact). Requires `make artifacts`.

use sadiff::exps::{table3, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    table3::run(scale).print();
}
