//! `cargo bench --bench bench_exec_smoke` — deterministic perf smoke for
//! the lane-parallel executor: times a 256-lane SA-Solver solve
//! sequentially and on the auto-sized worker pool, asserts the outputs are
//! bit-identical, and writes a `BENCH_exec_smoke.json` artifact for the
//! perf trajectory (CI uploads it per run).
//!
//! Flags: `--quick` (smaller solve), `--out <path>` (default
//! `BENCH_exec_smoke.json`). Exits non-zero if parallel output diverges
//! from sequential — the determinism invariant is the bench's correctness
//! gate, while the speedup number is reported, not asserted (CI runners
//! have noisy neighbours).

use sadiff::config::SamplerConfig;
use sadiff::exec::Executor;
use sadiff::gmm::Gmm;
use sadiff::jsonlite::{to_string, Value};
use sadiff::models::GmmAnalytic;
use sadiff::schedule::NoiseSchedule;
use sadiff::solvers::{run, run_parallel};
use sadiff::util::timing::time_it;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_exec_smoke.json")
        .to_string();

    let (lanes, dim, nfe, iters) =
        if quick { (64usize, 16usize, 8usize, 3usize) } else { (256, 16, 20, 5) };
    let model = GmmAnalytic::new(Gmm::structured(dim, 5, 2.0, 404));
    let sch = NoiseSchedule::vp_linear();
    let cfg = SamplerConfig { nfe, tau: 1.0, ..SamplerConfig::sa_default() };
    let par_exec = Executor::auto();
    let threads = par_exec.threads();

    // Determinism gate first (also warms both paths).
    let seq_out = run(&model, &sch, &cfg, lanes, 7);
    let par_out = run_parallel(&model, &sch, &cfg, lanes, 7, &par_exec);
    let identical = seq_out.samples == par_out.samples;

    let (seq_mean, seq_min) = time_it(iters, || {
        std::hint::black_box(run(&model, &sch, &cfg, lanes, 7));
    });
    let (par_mean, par_min) = time_it(iters, || {
        std::hint::black_box(run_parallel(&model, &sch, &cfg, lanes, 7, &par_exec));
    });
    let speedup = seq_min / par_min.max(1e-12);

    println!(
        "exec smoke: {lanes} lanes, dim {dim}, NFE {nfe}, {threads} threads: \
         seq {:.2} ms, par {:.2} ms → {:.2}x (identical: {identical})",
        seq_mean * 1e3,
        par_mean * 1e3,
        speedup
    );

    let report = Value::obj(vec![
        ("bench", Value::Str("exec_smoke".into())),
        ("lanes", Value::Num(lanes as f64)),
        ("dim", Value::Num(dim as f64)),
        ("nfe", Value::Num(nfe as f64)),
        ("threads", Value::Num(threads as f64)),
        ("seq_mean_ms", Value::Num(seq_mean * 1e3)),
        ("seq_min_ms", Value::Num(seq_min * 1e3)),
        ("par_mean_ms", Value::Num(par_mean * 1e3)),
        ("par_min_ms", Value::Num(par_min * 1e3)),
        ("speedup_min", Value::Num(speedup)),
        ("identical", Value::Bool(identical)),
    ]);
    if let Err(e) = std::fs::write(&out_path, format!("{}\n", to_string(&report))) {
        eprintln!("cannot write {out_path}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out_path}");

    if !identical {
        eprintln!("FAIL: parallel output is not bit-identical to sequential");
        std::process::exit(1);
    }
}
